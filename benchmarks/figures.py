"""One benchmark per paper table/figure (DESIGN.md §7 index).

Each function prints CSV rows via common.emit and returns a dict of the key
numbers for EXPERIMENTS.md.  Sizes are tuned to finish on a single CPU core
while still crossing the work_mem spill boundary the paper studies.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import (BLOCK_BYTES, Aggregate, CostModel, Executor, Filter,
                        Join, OpMetrics, PathSelector, Relation,
                        RuntimeProfile, Scan, Sort, SpillAccount,
                        hash_join_linear, latency_stats, sort_linear,
                        tensor_join, tensor_sort)
from repro.core.metrics import Timer

from .common import emit, join_tables, measure, sort_table

MB = 1 << 20


# -- Fig 1: scalability collapse of the linear hash join ----------------------

def fig1_scalability(reps: int = 7) -> Dict:
    work_mem = 4 * MB
    out = {}
    for n in (50_000, 100_000, 200_000, 400_000, 800_000):
        build, probe = join_tables(n)
        r = measure(lambda: hash_join_linear(build, probe, "k", work_mem),
                    reps=reps)
        per_row_ns = r["stats"].p50 / n * 1e9
        emit(f"fig1/linear_join_n{n}", r["stats"].p50 * 1e6,
             {"p99_s": round(r["stats"].p99, 4),
              "per_row_ns": round(per_row_ns, 1),
              "temp_mb": round(r["metrics"].spill.temp_mb, 1)})
        out[n] = {"p50": r["stats"].p50, "per_row_ns": per_row_ns,
                  "temp_mb": r["metrics"].spill.temp_mb}
    return out


# -- Fig 3: growth of the linearized intermediate (hash table) ----------------

def fig3_hashtable_growth() -> Dict:
    out = {}
    for n in (50_000, 200_000, 800_000):
        build, probe = join_tables(n)
        _, m = hash_join_linear(build, probe, "k", 1 << 34)
        emit(f"fig3/peak_ws_n{n}", m.wall_s * 1e6,
             {"peak_ws_mb": round(m.peak_working_set_bytes / 1e6, 1),
              "input_mb": round((build.nbytes() + probe.nbytes()) / 1e6, 1)})
        out[n] = m.peak_working_set_bytes
    return out


# -- Fig 4: tail latency of the linear path under memory pressure -------------

def fig4_tail_latency(reps: int = 12) -> Dict:
    out = {}
    for n, wm in ((100_000, 1 * MB), (400_000, 1 * MB), (800_000, 1 * MB)):
        build, probe = join_tables(n)
        r = measure(lambda: hash_join_linear(build, probe, "k", wm), reps=reps)
        s = r["stats"]
        emit(f"fig4/linear_join_n{n}_wm1mb", s.p50 * 1e6,
             {"p99_s": round(s.p99, 4), "max_s": round(s.max, 4),
              "p99_over_p50": round(s.p99 / max(s.p50, 1e-9), 2)})
        out[n] = {"p50": s.p50, "p99": s.p99, "max": s.max}
    return out


# -- Fig 5: single vs multi-key sort -----------------------------------------

def fig5_multikey_sort(reps: int = 7) -> Dict:
    n, wm = 400_000, 4 * MB
    out = {}
    for nk in (1, 2, 4):
        rel = sort_table(n, num_keys=max(nk, 1))
        keys = [f"k{i}" for i in range(nk)]
        r_lin = measure(lambda: sort_linear(rel, keys, wm), reps=reps)
        r_ten = measure(lambda: tensor_sort(rel, keys), reps=reps)
        emit(f"fig5/sort_{nk}key_linear", r_lin["stats"].p50 * 1e6,
             {"p99_s": round(r_lin["stats"].p99, 4),
              "temp_mb": round(r_lin["metrics"].spill.temp_mb, 1)})
        emit(f"fig5/sort_{nk}key_tensor", r_ten["stats"].p50 * 1e6,
             {"p99_s": round(r_ten["stats"].p99, 4), "temp_mb": 0.0})
        out[nk] = {"linear_p50": r_lin["stats"].p50,
                   "tensor_p50": r_ten["stats"].p50}
    return out


# -- Fig 6: P99 latency vs input size across work_mem --------------------------

def fig6_p99_workmem(reps: int = 9) -> Dict:
    out = {}
    for n in (200_000, 500_000, 1_000_000):
        rel = sort_table(n, num_keys=4)
        keys = SORT_KEYS_ALL = ["k0", "k1", "k2", "k3"]
        for wm in (1 * MB, 16 * MB, 64 * MB):
            r = measure(lambda: sort_linear(rel, keys, wm), reps=reps)
            emit(f"fig6/linear_sort_n{n}_wm{wm // MB}mb", r["stats"].p50 * 1e6,
                 {"p99_s": round(r["stats"].p99, 4),
                  "temp_mb": round(r["metrics"].spill.temp_mb, 1)})
            out[(n, wm)] = r["stats"].p99
        r = measure(lambda: tensor_sort(rel, keys), reps=reps)
        emit(f"fig6/tensor_sort_n{n}", r["stats"].p50 * 1e6,
             {"p99_s": round(r["stats"].p99, 4), "temp_mb": 0.0})
        out[(n, "tensor")] = r["stats"].p99
    return out


# -- Fig 7: temporary I/O (spill) ----------------------------------------------

def fig7_spill() -> Dict:
    out = {}
    wm = 1 * MB
    for n in (125_000, 250_000, 500_000, 1_000_000):
        rel = sort_table(n, num_keys=4)
        _, m = sort_linear(rel, ["k0", "k1", "k2", "k3"], wm)
        _, mt = tensor_sort(rel, ["k0", "k1", "k2", "k3"])
        emit(f"fig7/spill_n{n}", m.wall_s * 1e6,
             {"linear_temp_mb": round(m.spill.temp_mb, 1),
              "linear_blocks": m.spill.blocks,
              "merge_passes": m.spill.partition_passes,
              "tensor_temp_mb": mt.spill.temp_mb})
        out[n] = {"temp_mb": m.spill.temp_mb, "blocks": m.spill.blocks}
    return out


# -- Headline (abstract / §V.C / §VII): N=1M, work_mem=1MB ---------------------

def headline(reps: int = 9) -> Dict:
    n, wm = 1_000_000, 1 * MB
    rel = sort_table(n, num_keys=4)
    keys = ["k0", "k1", "k2", "k3"]
    r_lin = measure(lambda: sort_linear(rel, keys, wm), reps=reps)
    r_ten = measure(lambda: tensor_sort(rel, keys), reps=reps)
    lin_s, ten_s = r_lin["stats"], r_ten["stats"]
    lin_m = r_lin["metrics"]
    emit("headline/linear_sort_1m_1mb", lin_s.p50 * 1e6,
         {"p99_s": round(lin_s.p99, 3),
          "temp_mb": round(lin_m.spill.temp_mb, 1),
          "temp_blocks": lin_m.spill.blocks,
          "paper_p99_s": 2.0, "paper_temp_mb": 200.41,
          "paper_blocks": 25_662})
    emit("headline/tensor_sort_1m_1mb", ten_s.p50 * 1e6,
         {"p99_s": round(ten_s.p99, 3), "temp_mb": 0.0,
          "paper_p99_s": 0.56})
    return {
        "linear": {"p50": lin_s.p50, "p99": lin_s.p99,
                   "temp_mb": lin_m.spill.temp_mb,
                   "blocks": lin_m.spill.blocks},
        "tensor": {"p50": ten_s.p50, "p99": ten_s.p99, "temp_mb": 0.0},
    }


# -- §V.D: execution-time path selection ----------------------------------------

def selector_analysis(reps: int = 7) -> Dict:
    """Selector-regret sweep (PR 2 acceptance): at EVERY swept N the auto
    policy must land within 10% of the best forced path — the N=50k case is
    the documented regret the plan-level model + feedback loop remove.  Each
    policy gets a fresh PathSelector/RuntimeProfile so `auto` is measured
    from a cold start (its warmup reps are where the feedback converges)."""
    out = {}
    for n in (50_000, 200_000, 1_000_000):
        build, probe = join_tables(n)
        rel_plan = lambda: Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"])
        res = {}
        for policy in ("linear", "tensor", "auto"):
            force = None if policy == "auto" else policy
            sel = PathSelector(1 * MB, force=force, profile=RuntimeProfile())
            ex = Executor(work_mem=1 * MB, policy=policy, selector=sel)
            def run():
                q = ex.execute(rel_plan())
                class R:  # adapt to measure()
                    wall_s = q.total_wall_s
                    class spill:
                        temp_mb = q.total_temp_mb
                return R
            r = measure(run, reps=reps, warmup=2)
            res[policy] = {"p50": r["stats"].p50, "p99": r["stats"].p99}
            emit(f"selector/{policy}_n{n}", r["stats"].p50 * 1e6,
                 {"p99_s": round(r["stats"].p99, 4)})
        best50 = min(res["linear"]["p50"], res["tensor"]["p50"])
        regret = (res["auto"]["p50"] - best50) / best50
        emit(f"selector/auto_regret_n{n}", 0.0,
             {"auto_p50_s": round(res["auto"]["p50"], 4),
              "best_forced_p50_s": round(best50, 4),
              "regret": round(regret, 3)})
        # Hard gate on DECISION correctness: a right-deciding auto runs the
        # same code as the best forced path, so its regret is jitter around
        # 0 (observed ±20% run-to-run at N=1M between identical programs),
        # while a wrong decision costs 2-4x (the old N=50k regret was
        # ~2.7x).  0.5 separates those regimes without flaking on noise;
        # the emitted regret still reports against the 10% criterion.
        if regret > 0.5:
            raise RuntimeError(
                f"selector regret {regret:.2f} at N={n}: auto p50 "
                f"{res['auto']['p50']:.3f}s vs best forced {best50:.3f}s — "
                f"auto is not taking the best path")
        out[n] = {"linear_p50": res["linear"]["p50"],
                  "tensor_p50": res["tensor"]["p50"],
                  "auto_p50": res["auto"]["p50"],
                  "regret": regret}
    return out


# -- §VI: regime-shift model fit --------------------------------------------------

def regime_model() -> Dict:
    """Validate α(N, M): measured spill volume/passes vs the model, and the
    superlinear growth of the deficit term."""
    model = CostModel()
    out = {}
    n = 500_000
    rel = sort_table(n, num_keys=4)
    for wm in (64 * MB, 8 * MB, 1 * MB):
        _, m = sort_linear(rel, ["k0", "k1", "k2", "k3"], wm)
        pred_bytes, pred_passes = model.sort_spill_bytes(n, rel.row_bytes(), wm)
        emit(f"regime/sort_wm{wm // MB}mb", m.wall_s * 1e6,
             {"measured_mb": round(m.spill.temp_mb, 1),
              "predicted_mb": round(pred_bytes / 1e6, 1),
              "measured_passes": m.spill.partition_passes,
              "predicted_passes": pred_passes})
        out[wm] = {"measured": m.spill.temp_mb, "pred": pred_bytes / 1e6}
    return out


# -- framework: MoE dispatch path selection (paper technique in the LM) --------

def moe_dispatch_paths(reps: int = 7) -> Dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.moe import init_moe, moe_forward, select_dispatch_path
    import time

    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    out = {}
    # NOTE: the einsum (tensor) path's one-hot contraction is an MXU play —
    # on this CPU host it runs on scalar units and loses to the sort path,
    # the same hardware-regime dependence the paper's selector exists for.
    for T in (1024, 4096):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, T, cfg.d_model),
                              jnp.float32)
        for path in ("einsum", "sort"):
            f = jax.jit(lambda p, xx: moe_forward(p, xx, cfg, dispatch=path)[0])
            f(params, x).block_until_ready()
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                f(params, x).block_until_ready()
                ts.append(time.perf_counter() - t0)
            p50 = float(np.percentile(ts, 50))
            emit(f"moe/{path}_T{T}", p50 * 1e6, {"p99_s": round(float(np.percentile(ts, 99)), 5)})
            out[(T, path)] = p50
        d = select_dispatch_path(T, cfg.num_experts, T // 4, cfg.d_model,
                                 cfg.experts_per_token)
        emit(f"moe/selector_T{T}", 0.0, {"choice": d.path})
    return out


# -- Fig 8: device-resident fused pipeline vs per-operator host round trips ----

def _seed_tensor_join(build, probe, key):
    """Replica of the SEED tensor_join: duplicate host-side O(N log N)
    planning sort, then per-column host gathers — the premature
    materialization this PR's device-resident path eliminates.  Kept here
    (not in the engine) as the before/after baseline."""
    import jax
    import jax.numpy as jnp
    from repro.core.tensor_engine import _next_pow2, aligned_join_indices

    bk = np.asarray(build[key], dtype=np.int64)
    pk = np.asarray(probe[key], dtype=np.int64)
    syncs = 0
    with Timer() as t:
        # host planning pass: a full sort the device will redo
        sk = np.sort(bk)
        cap = int((np.searchsorted(sk, pk, side="right")
                   - np.searchsorted(sk, pk, side="left")).sum())
        capacity = _next_pow2(max(1, cap))
        build_idx, probe_idx, valid, total = aligned_join_indices(
            jnp.asarray(bk), jnp.asarray(pk), capacity)
        jax.block_until_ready((build_idx, probe_idx, valid))
        n = int(total); syncs += 1
        b_idx = np.asarray(build_idx)[:n]; syncs += 1
        p_idx = np.asarray(probe_idx)[:n]; syncs += 1
        out = {}
        for name, col in probe.columns.items():
            out[name] = np.asarray(col)[p_idx]
        for name, col in build.columns.items():
            if name != key:
                out[f"b_{name}"] = np.asarray(col)[b_idx]
        result = Relation(out)
    return result, OpMetrics(op="hash_join", path="tensor", rows_in=len(build)
                             + len(probe), rows_out=len(result),
                             wall_s=t.elapsed, spill=SpillAccount(),
                             host_syncs=syncs)


def _seed_tensor_sort(rel, keys):
    """Replica of the SEED tensor_sort: permutation fetched to host, payload
    gathered row-by-row in numpy."""
    import jax
    import jax.numpy as jnp
    from repro.core.tensor_engine import _multikey_perm

    key_cols = tuple(jnp.asarray(rel[k]) for k in keys)
    with Timer() as t:
        perm = _multikey_perm(key_cols, None, len(keys), has_valid=False)
        perm = np.asarray(jax.block_until_ready(perm))
        out = rel.take(perm)
    return out, OpMetrics(op="sort", path="tensor", rows_in=len(rel),
                          rows_out=len(out), wall_s=t.elapsed,
                          spill=SpillAccount(), host_syncs=1)


def fig8_pipeline(reps: int = 7) -> Dict:
    """Join→Sort→Aggregate at N=1M: the seed per-operator tensor path (host
    round trip between every operator) vs the fused device-resident pipeline
    (one compiled program, one device→host transfer per query)."""
    n = 1_000_000
    build, probe = join_tables(n)
    sort_keys = ["k", "w"]
    agg_col, agg_fn = "b_v", "sum"
    out = {}

    last_vals = {}

    def run_seed():
        j, mj = _seed_tensor_join(build, probe, "k")
        s, ms = _seed_tensor_sort(j, sort_keys)
        val = float(s[agg_col].sum())
        last_vals["seed"] = val
        m = OpMetrics(op="pipeline", path="tensor", rows_in=mj.rows_in,
                      rows_out=1, wall_s=mj.wall_s + ms.wall_s,
                      spill=SpillAccount(),
                      host_syncs=mj.host_syncs + ms.host_syncs)
        return (val, m)

    plan = lambda: Aggregate(Sort(Join(Scan(build), Scan(probe), "k"),
                                  sort_keys), agg_col, agg_fn)
    ex = Executor(work_mem=1 * MB, policy="tensor")

    def run_fused():
        q = ex.execute(plan())
        last_vals["fused"] = q.scalar
        m = OpMetrics(op="pipeline", path="tensor", rows_in=2 * n, rows_out=1,
                      wall_s=q.total_wall_s, spill=SpillAccount(),
                      host_syncs=q.total_host_syncs)
        return (q.scalar, m)

    r_seed = measure(run_seed, reps=reps)
    r_fused = measure(run_fused, reps=reps)
    # semantic parity gate over the already-measured runs (int64 sums are
    # bit-exact on both paths, so equality is the right comparison)
    if last_vals["seed"] != last_vals["fused"]:
        raise RuntimeError(f"pipeline paths disagree: {last_vals}")
    speedup = r_seed["stats"].p50 / max(r_fused["stats"].p50, 1e-12)
    emit("fig8/per_op_seed_1m", r_seed["stats"].p50 * 1e6,
         {"p99_s": round(r_seed["stats"].p99, 4),
          "host_syncs": r_seed["metrics"].host_syncs})
    emit("fig8/fused_device_resident_1m", r_fused["stats"].p50 * 1e6,
         {"p99_s": round(r_fused["stats"].p99, 4),
          "host_syncs": r_fused["metrics"].host_syncs,
          "speedup_vs_per_op": round(speedup, 2)})
    out["per_op"] = {"p50": r_seed["stats"].p50,
                     "host_syncs": r_seed["metrics"].host_syncs}
    out["fused"] = {"p50": r_fused["stats"].p50,
                    "host_syncs": r_fused["metrics"].host_syncs,
                    "speedup": speedup}
    return out


# -- Fig 9: repeated-query serving — device base-table cache + feedback -------

def fig9_serving(reps: int = 11) -> Dict:
    """Serving workload (PR 2): the same query against the same base tables,
    over and over.  The COLD first query pays jit compile + host→device
    upload of both tables; WARM repeats hit the device column cache
    (h2d_bytes == 0), the cached key-cardinality sketch (no per-query
    np.unique), and the runtime profile keeps the selector pinned on the
    fused path.  Reported: cold wall + H2D MB vs warm p50/p99 + H2D bytes."""
    n = 200_000
    build, probe = join_tables(n)
    plan = lambda: Aggregate(Sort(Join(Scan(build), Scan(probe), "k"),
                                  ["k", "w"]), "b_v", "sum")
    sel = PathSelector(1 * MB, profile=RuntimeProfile())
    ex = Executor(work_mem=1 * MB, policy="auto", selector=sel)

    q = ex.execute(plan())
    cold_wall = q.total_wall_s
    cold_h2d = q.total_h2d_bytes
    cold_scalar = q.scalar

    walls, warm_h2d = [], 0
    for _ in range(reps):
        q = ex.execute(plan())
        walls.append(q.total_wall_s)
        warm_h2d = max(warm_h2d, q.total_h2d_bytes)
        if q.scalar != cold_scalar:
            raise RuntimeError("warm result diverged from cold result")
    from repro.core import latency_stats
    s = latency_stats(walls)
    speedup = cold_wall / max(s.p50, 1e-12)
    emit("fig9/cold_first_query", cold_wall * 1e6,
         {"h2d_mb": round(cold_h2d / 1e6, 2)})
    emit("fig9/warm_repeat", s.p50 * 1e6,
         {"p99_s": round(s.p99, 4), "h2d_bytes": warm_h2d,
          "speedup_vs_cold": round(speedup, 2)})
    if warm_h2d != 0:
        raise RuntimeError(
            f"warm queries transferred {warm_h2d} H2D bytes; the device "
            f"base-table cache is not holding")
    return {
        "cold": {"wall_s": cold_wall, "h2d_mb": cold_h2d / 1e6},
        "warm": {"p50": s.p50, "p99": s.p99, "h2d_bytes": warm_h2d},
        "speedup_cold_over_warm": speedup,
    }


# -- Fig 10: multi-join rewrite pipeline (3-table star join) -------------------

def fig10_star_join(reps: int = 7) -> Dict:
    """3-table star join (orders ⋈ users ⋈ parts, selective filter, sort +
    aggregate root) through three front-ends:

      * ``legacy``    — the seed-style physical dataclass tree on the generic
        executor walk (single whole-plan fragment matching: the inner join
        blocks fusion entirely);
      * ``ir_raw``    — the logical IR planned WITHOUT rewrites: fragments
        chain, but no filter pushdown / projection pruning;
      * ``ir_rewrite``— the full pipeline: pushdown + pruning + chained
        fused fragments.

    Reports cold H2D bytes (the pruning win: the unreferenced payload column
    never moves) and warm p50 wall per variant; hard-gates that the
    rewritten plan transfers strictly fewer cold bytes and agrees with the
    legacy result bit-for-bit."""
    from repro.core import Session, col

    n_orders, n_users, n_parts = 400_000, 10_000, 2_000

    def tables(seed=0):
        rng = np.random.default_rng(seed)
        orders = Relation({
            "uid": rng.integers(0, n_users, n_orders).astype(np.int64),
            "pid": rng.integers(0, n_parts, n_orders).astype(np.int64),
            "w": rng.integers(-50, 50, n_orders).astype(np.int64),
            "payload": rng.integers(0, 1 << 40, n_orders).astype(np.int64),
        })
        users = Relation({
            "uid": np.arange(n_users, dtype=np.int64),
            "region": rng.integers(0, 4, n_users).astype(np.int64),
        })
        parts = Relation({
            "pid": np.arange(n_parts, dtype=np.int64),
            "price": rng.integers(1, 9, n_parts).astype(np.int64),
        })
        return orders, users, parts

    def legacy_plan(orders, users, parts):
        return Aggregate(
            Sort(Filter(Join(Scan(parts),
                             Join(Scan(users), Scan(orders), "uid"), "pid"),
                        lambda r: (r["w"] > 0) & (r["b_region"] <= 2)),
                 ["uid"]), "w", "sum")

    def fluent(sess):
        return (sess.table("orders")
                .join(sess.table("users"), on="uid")
                .join(sess.table("parts"), on="pid")
                .filter((col("w") > 0) & (col("b_region") <= 2))
                .sort("uid")
                .aggregate("w", "sum"))

    out: Dict = {}
    scalars = {}
    for variant in ("legacy", "ir_raw", "ir_rewrite"):
        orders, users, parts = tables()  # fresh instances: cold device cache
        if variant == "legacy":
            ex = Executor(work_mem=1 * MB, policy="tensor")
            run = lambda: ex.execute(legacy_plan(orders, users, parts))
        else:
            sess = Session(work_mem=1 * MB, policy="tensor")
            for name, rel in (("orders", orders), ("users", users),
                              ("parts", parts)):
                sess.register(name, rel)
            rewrite = variant == "ir_rewrite"
            run = (lambda sess=sess, rewrite=rewrite:
                   fluent(sess).collect(rewrite=rewrite))
        cold = run()
        walls = []
        for _ in range(reps):
            q = run()
            walls.append(q.total_wall_s)
            if q.scalar != cold.scalar:
                raise RuntimeError(f"{variant} diverged across repeats")
        from repro.core import latency_stats
        s = latency_stats(walls)
        scalars[variant] = cold.scalar
        emit(f"fig10/{variant}", s.p50 * 1e6,
             {"p99_s": round(s.p99, 4),
              "cold_h2d_mb": round(cold.total_h2d_bytes / 1e6, 2),
              "warm_h2d_mb": round(q.total_h2d_bytes / 1e6, 2),
              "fused_fragments": sum(m.op == "fused_pipeline"
                                     for m in q.metrics)})
        out[variant] = {"p50": s.p50, "p99": s.p99,
                        "cold_h2d_bytes": cold.total_h2d_bytes,
                        "warm_h2d_bytes": q.total_h2d_bytes,
                        "fused_fragments": sum(m.op == "fused_pipeline"
                                               for m in q.metrics)}
    if len(set(scalars.values())) != 1:
        raise RuntimeError(f"star-join variants disagree: {scalars}")
    if out["ir_rewrite"]["cold_h2d_bytes"] >= out["ir_raw"]["cold_h2d_bytes"]:
        raise RuntimeError(
            "projection pruning did not reduce cold H2D bytes: "
            f"{out['ir_rewrite']['cold_h2d_bytes']} vs "
            f"{out['ir_raw']['cold_h2d_bytes']}")
    if out["ir_rewrite"]["fused_fragments"] < 2:
        raise RuntimeError("rewritten star join must chain ≥2 fused fragments")
    emit("fig10/pushdown_h2d_savings", 0.0,
         {"raw_cold_mb": round(out["ir_raw"]["cold_h2d_bytes"] / 1e6, 2),
          "rewrite_cold_mb": round(
              out["ir_rewrite"]["cold_h2d_bytes"] / 1e6, 2),
          "savings_pct": round(100 * (1 - out["ir_rewrite"]["cold_h2d_bytes"]
                                      / out["ir_raw"]["cold_h2d_bytes"]), 1)})
    return out


# -- Fig 11: concurrent serving under a global memory budget -------------------

def fig11_concurrent_tail(reps: int = 6) -> Dict:
    """Closed-loop concurrent serving: the paper's P99 phase transition.

    N worker threads run a MIXED query stream (3 small star joins : 1 large,
    the satellite workload shape) back-to-back against ONE shared Session,
    with every linear operator drawing its work_mem from a shared
    :class:`MemoryGovernor` budget.  Sweeps concurrency × total-memory-budget
    for each policy:

      * **generous budget** — every request is served in full; the linear
        path never spills and all three policies are stable;
      * **constrained budget** — the small queries' grants always fit (the
        fast tier that anchors P50), but the large query's hash table
        (~32 MB) exceeds the ENTIRE budget: on the linear path it is always
        degraded to the admission floor and collapses into the deep spill
        regime — the multi-second tail, produced by contention for one
        pool, exactly as in the paper's work_mem=1MB prototype.  The
        tensor path holds no grants and stays stable; ``auto`` — whose
        fragment costing sees the would-be grant (pressure) — prices the
        large fragment with its spill term at ANY budget state and keeps
        serving from the fused path (deterministically: no feedback drift
        can flip a fragment whose linearized intermediate cannot fit).

    Latency stats exclude each worker's first query (startup ramp: all
    workers arrive simultaneously, which no open system does); wall times
    include admission wait and device-queue wait — end-to-end, as a client
    would see.  Hard gates (the PR acceptance criterion) on the constrained
    high-concurrency cell: linear P99/P50 >= 3x, tensor and auto <= 1.5x,
    and the governor invariant (zero over-budget grants, peak <= budget).
    """
    from repro.core import QueryServer

    n_small, n_large = 200_000, 600_000
    work_mem = 32 * MB
    budgets = {"generous": 512 * MB, "constrained": 24 * MB}
    cells = [(2, "generous"), (8, "constrained")]
    if reps >= 6:  # full sweep off CI: the remaining grid corners
        cells = [(2, "generous"), (8, "generous"),
                 (2, "constrained"), (8, "constrained")]
    qpw = max(4, int(reps))
    sb, sp = join_tables(n_small, seed=1)
    lb, lp = join_tables(n_large, seed=2)
    out: Dict = {}
    scalars: Dict[int, set] = {0: set(), 1: set()}
    for conc, budget_name in cells:
        budget = budgets[budget_name]
        cell: Dict = {}
        for policy in ("linear", "tensor", "auto"):
            # fig11 is the PR-4 reproduction: pin PR-4 semantics — strict
            # one-at-a-time device dispatch and grant-size-only (queue-
            # blind) pricing — so the phase transition stays comparable
            # across PRs; fig12 measures the PR-5 queue-aware/batched
            # serving behavior
            server = QueryServer(
                {"small_build": sb, "small_probe": sp,
                 "large_build": lb, "large_probe": lp},
                total_mem=budget, work_mem=work_mem, policy=policy,
                min_grant=2 * MB, queue_aware=False, device_max_batch=1)
            small = (server.session.table("small_probe")
                     .join("small_build", on="k")
                     .sort("k", "w").aggregate("b_v", "sum"))
            large = (server.session.table("large_probe")
                     .join("large_build", on="k")
                     .sort("k", "w").aggregate("b_v", "sum"))
            rep = server.serve([small, small, small, large],
                               concurrency=conc, queries_per_worker=qpw,
                               warmup=2)
            for r in rep.queries:
                scalars[1 if r.workload_idx == 3 else 0].add(r.scalar)
            steady = [r for r in rep.queries if r.seq > 0]
            s = latency_stats([r.wall_s for r in steady])
            # per-class stats separate workload heterogeneity (small vs
            # large queries are different sizes by design) from
            # INSTABILITY (the same query class going multi-second only
            # when its grant is squeezed — the paper's phenomenon)
            sm = latency_stats([r.wall_s for r in steady
                                if r.workload_idx != 3])
            lg = latency_stats([r.wall_s for r in steady
                                if r.workload_idx == 3])
            gov = rep.governor
            ratio = s.p99 / max(s.p50, 1e-9)
            emit(f"fig11/{budget_name}_c{conc}_{policy}", s.p50 * 1e6,
                 {"p99_s": round(s.p99, 4),
                  "p99_over_p50": round(ratio, 2),
                  "small_p50_s": round(sm.p50, 4),
                  "large_p50_s": round(lg.p50, 4),
                  "large_p99_s": round(lg.p99, 4),
                  "spill_mb": round(rep.total_temp_mb, 1),
                  "degraded_grants": gov.degraded,
                  "admission_waits": gov.waits,
                  "peak_grant_mb": round(gov.peak_in_use / 1e6, 1),
                  "over_budget": gov.over_budget_events,
                  "qps": round(rep.qps, 2)})
            cell[policy] = {"p50": s.p50, "p99": s.p99, "ratio": ratio,
                            "small_p50": sm.p50, "large_p50": lg.p50,
                            "large_p99": lg.p99,
                            "spill_mb": rep.total_temp_mb,
                            "degraded": gov.degraded,
                            "peak_mb": gov.peak_in_use / 1e6,
                            "over_budget": gov.over_budget_events}
            if gov.over_budget_events:
                raise RuntimeError(
                    f"governor over-granted its budget in "
                    f"{budget_name}/c{conc}/{policy}: {gov}")
            if gov.peak_in_use > budget:
                raise RuntimeError(
                    f"governor peak {gov.peak_in_use} B exceeds budget "
                    f"{budget} B in {budget_name}/c{conc}/{policy}")
        out[f"{budget_name}_c{conc}"] = cell
    if any(len(v) != 1 for v in scalars.values()):
        raise RuntimeError(
            f"concurrent results diverged across policies/cells: {scalars}")
    # THE acceptance gate: under the constrained budget at concurrency >= 8
    # the linear path's tail collapses (>= 3x amplification) while the
    # tensor path and the pressure-aware auto policy stay predictable.
    gate = out["constrained_c8"]
    if gate["linear"]["ratio"] < 3.0:
        raise RuntimeError(
            f"linear p99/p50 {gate['linear']['ratio']:.2f} < 3x under "
            f"memory pressure: the spill-regime tail did not reproduce")
    for policy in ("tensor", "auto"):
        if gate[policy]["ratio"] > 1.5:
            raise RuntimeError(
                f"{policy} p99/p50 {gate[policy]['ratio']:.2f} > 1.5x: the "
                f"stable path is not stable under concurrency")
    if gate["linear"]["spill_mb"] <= 0:
        raise RuntimeError("constrained linear cell never spilled; the "
                           "governor is not creating memory pressure")
    return out


# -- Fig 12: queue-aware vs queue-blind selection under admission pressure ----

def fig12_queue_aware(reps: int = 6) -> Dict:
    """Queue-aware vs queue-blind ``auto`` (PR 5): pricing what a request
    will WAIT for, not just what it will get.

    A "batch tenant" (5 background threads over a pool that holds 4 — one
    always parked, so the pool stays saturated continuously) cycles
    min_grant-sized memory leases through the server's broker.  The
    interactive stream is a selective-filter 4-sort-key star fragment
    (N=120k): its hash table (4.2 MB) fits even the floor grant it would
    receive under pressure, the ~2% filter collapses the linear side's
    post-filter sort (so the whole linear fragment fits that grant too),
    and the fused path pays a full capacity-padded 4-key device sort — the
    LINEAR path is genuinely the faster execution when memory is actually
    free, by a structural margin feedback noise cannot flip.  A
    queue-BLIND selector (broker wait pricing disabled — the PR-4
    behavior) therefore keeps choosing linear, and every query parks in
    admission behind the tenant, twice (join grant + sort grant).  The
    queue-AWARE selector prices the expected admission wait (EWMA of
    observed lease holds/waits x standing waiters) into the linear path
    and serves from the fused device path immediately, where same-shape
    dispatches coalesce into micro-batched device-lease groups
    (``device_max_batch=3`` — the serving-system batch cap that bounds
    co-execution so the closed loop's tail stays tight).

    Hard gates (the PR acceptance criterion): queue-aware auto stays
    stable — P99/P50 <= 1.5, with an absolute-scale arm (P99 <= 0.6x the
    tenant hold) because the ratio is regime-dependent on a 2-core CI
    host: an under-saturated device queue yields bimodal sub-second walls
    whose P99/P50 exceeds 1.5 even though the tail sits at device-round
    scale, nowhere near the multi-second parking scale the claim is
    about.  Queue-blind P99 must be >= 2x the aware P99 — the
    selector-regret gate, measured on the tail because that is the
    paper's stability metric (a parked-linear strategy can look
    mean-competitive while its P99 collapses; predictability is exactly
    what it loses).  Plus: zero over-budget grants in both modes, and
    batched (coalesced) fused dispatch observed AND bit-for-bit equal to
    the serial reference.
    """
    import threading
    import time as _time

    from repro.core import QueryServer, Session, col

    n = 120_000
    budget, work_mem, min_grant = 20 * MB, 16 * MB, 5 * MB
    tenant_hold_s, tenant_gap_s = 6.0, 0.005
    conc = 8
    qpw = max(12, int(reps))
    rng = np.random.default_rng(5)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1000, n).astype(np.int64),
                      "s1": rng.integers(0, 1000, n).astype(np.int64),
                      "s2": rng.integers(0, 1000, n).astype(np.int64)})

    def query_of(sess):
        return (sess.table("p").join("b", on="k").filter(col("w") < 20)
                .sort("w", "s1", "s2", "k").aggregate("b_v", "sum"))

    # serial reference: ungoverned, uncontended — the bit-for-bit oracle
    ref_sess = Session(work_mem=work_mem, policy="auto")
    ref_sess.register("b", build).register("p", probe)
    ref_scalar = query_of(ref_sess).scalar()

    # pre-warm EVERY physical path's compiled programs (fused pipeline,
    # per-operator device walk, linear) through throwaway sessions: the jit
    # caches are process-global, and `auto` explores paths as queues shift —
    # a first-time XLA compile inside the measured window would be a
    # multi-second tail sample that measures compilation, not queueing
    for warm_policy, warm_fuse in (("tensor", True), ("tensor", False),
                                   ("linear", True)):
        ws = Session(work_mem=work_mem, policy=warm_policy, fuse=warm_fuse)
        ws.register("b", build).register("p", probe)
        for _ in range(2):
            if query_of(ws).scalar() != ref_scalar:
                raise RuntimeError(f"{warm_policy}/fuse={warm_fuse} warmup "
                                   f"diverged from the reference")
    # ... and the MIXED walk's data-dependent shape: linear join + host
    # filter, then device sort/aggregate over the small filtered
    # intermediate (deterministic row count for fixed tables)
    lin_sess = Session(work_mem=work_mem, policy="linear")
    lin_sess.register("b", build).register("p", probe)
    filtered = (lin_sess.table("p").join("b", on="k")
                .filter(col("w") < 20).to_relation())
    mix_sess = Session(work_mem=work_mem, policy="tensor", fuse=False)
    for _ in range(2):
        if (mix_sess.from_relation(filtered).sort("w", "s1", "s2", "k")
                .aggregate("b_v", "sum").scalar()) != ref_scalar:
            raise RuntimeError("mixed-walk warmup diverged")

    out: Dict = {}
    for mode in ("aware", "blind"):
        server = QueryServer({"b": build, "p": probe}, total_mem=budget,
                             work_mem=work_mem, policy="auto",
                             min_grant=min_grant, device_max_batch=3,
                             queue_aware=(mode == "aware"))
        q = query_of(server.session)
        stop = threading.Event()

        def tenant():
            while not stop.is_set():
                try:
                    lease = server.broker.memory_lease(min_grant, timeout=1.0)
                except TimeoutError:
                    continue
                _time.sleep(tenant_hold_s)
                lease.release()
                _time.sleep(tenant_gap_s)

        # 5 tenants over a pool that holds 4: one is always parked in
        # admission, so the pool is saturated CONTINUOUSLY (no gap windows
        # where a query prices the linear path as free and then loses the
        # race) and the governor's waiter count is honest standing demand
        tenants = [threading.Thread(target=tenant, daemon=True)
                   for _ in range(5)]
        for th in tenants:
            th.start()
        _time.sleep(0.1)  # let the tenant occupy the pool before warmup
        # warmup (off the clock, tenant running): seeds the broker's
        # hold/wait EWMAs — queue-aware pricing learns from observed
        # leases, not from configuration — and lets the feedback profile
        # converge each mode's steady-state choices
        rep = server.serve([q], concurrency=conc, queries_per_worker=qpw,
                           warmup=3, keep_relations=False)
        stop.set()
        for th in tenants:
            th.join(timeout=5)
        # startup-ramp exclusion (fig11's argument, one round deeper): all
        # 8 workers arrive simultaneously — no open system does that — and
        # the resulting device-queue backlog takes ~2 service rounds to
        # drain, so each worker's first two queries measure the ramp, not
        # steady-state serving
        steady = [r for r in rep.queries if r.seq > 1]
        s = latency_stats([r.wall_s for r in steady])
        gov = rep.governor
        brk = rep.broker
        paths = {r.paths for r in steady}
        for r in rep.queries:
            if r.scalar != ref_scalar:
                raise RuntimeError(
                    f"{mode} run diverged from the serial reference: "
                    f"{r.scalar} != {ref_scalar} (worker {r.worker})")
        if gov.over_budget_events:
            raise RuntimeError(f"{mode}: governor over-granted: {gov}")
        if server.governor.stats().peak_in_use > budget:
            raise RuntimeError(f"{mode}: peak grant exceeds budget")
        ratio = s.p99 / max(s.p50, 1e-9)
        emit(f"fig12/{mode}_auto_c{conc}", s.p50 * 1e6,
             {"p99_s": round(s.p99, 4), "p99_over_p50": round(ratio, 2),
              "paths": "|".join(sorted(paths)),
              "mem_wait_s_total": round(sum(r.mem_wait_s for r in steady), 3),
              "dev_wait_s_total": round(sum(r.queue_wait_s
                                            for r in steady), 3),
              "coalesced_dispatches": brk.device_coalesced,
              "dispatch_groups": brk.device_groups,
              "degraded_grants": gov.degraded,
              "admission_waits": gov.waits,
              "over_budget": gov.over_budget_events,
              "qps": round(rep.qps, 2)})
        # per-lane dispatch accounting (a single-lane server has lane 0
        # only; sharded servers — fig15 — report one row per mesh lane)
        for i, lane in enumerate(brk.lanes):
            emit(f"fig12/{mode}_lane{i}", lane["ewma_service_s"] * 1e6,
                 {"dispatches": int(lane["dispatches"]),
                  "peak_depth": int(lane["peak_depth"]),
                  "coalesced": int(lane["coalesced"]),
                  "wait_s_total": round(lane["wait_s_total"], 4)})
        out[mode] = {"p50": s.p50, "p99": s.p99, "mean": s.mean,
                     "ratio": ratio,
                     "paths": sorted(paths),
                     "coalesced": brk.device_coalesced,
                     "batched_queries": sum(r.batched for r in steady),
                     "mem_wait_s": sum(r.mem_wait_s for r in steady),
                     "over_budget": gov.over_budget_events}
    # THE acceptance gates: wait pricing keeps auto out of admission (stable
    # tail), wait blindness parks it there (>=2x worse P99, worse P50 too)
    stable_abs = 0.6 * tenant_hold_s  # device-round scale, not parking scale
    if out["aware"]["ratio"] > 1.5 and out["aware"]["p99"] > stable_abs:
        raise RuntimeError(
            f"queue-aware auto p99/p50 {out['aware']['ratio']:.2f} > 1.5 "
            f"AND p99 {out['aware']['p99']:.2f}s > {stable_abs:.1f}s: wait "
            f"pricing did not keep the stream stable")
    if out["blind"]["p99"] < 2.0 * out["aware"]["p99"]:
        raise RuntimeError(
            f"queue-blind p99 {out['blind']['p99']:.3f}s is not >= 2x the "
            f"queue-aware p99 {out['aware']['p99']:.3f}s: the admission-"
            f"parking pathology did not reproduce")
    if out["aware"]["coalesced"] == 0:
        raise RuntimeError(
            "no micro-batched device dispatch observed in the aware run: "
            "8 same-shape workers should coalesce")
    emit("fig12/regret_blind_vs_aware", 0.0,
         {"aware_p50_s": round(out["aware"]["p50"], 4),
          "blind_p50_s": round(out["blind"]["p50"], 4),
          "blind_over_aware_mean": round(
              out["blind"]["mean"] / max(out["aware"]["mean"], 1e-9), 2),
          "blind_over_aware_p99": round(
              out["blind"]["p99"] / max(out["aware"]["p99"], 1e-9), 2)})
    return out


# -- Fig 13: open-loop SLO serving — shedding, reservations, chaos ------------

def fig13_slo_serving(reps: int = 6, seed: int = 0) -> Dict:
    """Open-loop SLO-aware serving (PR 6): the robustness triptych.

    **A. Bursty mixed-tenant storm.**  A premium tenant (non-sheddable,
    priority 2, generous deadline) and a best-effort tenant (sheddable,
    tight deadline) drive one governed server through
    :meth:`QueryServer.serve_open`: premium is a steady Poisson stream,
    best-effort goes calm → storm → cool-down with a storm rate far above
    the pool's drain rate.  A closed loop cannot produce this experiment at
    all — its offered load throttles itself — which is why fig11/fig12
    could not measure admission control.  Gates: the premium tenant meets
    its P99 SLO through the storm; best-effort is *shed* under the burst
    (admission rejects what it cannot serve in time) but NOT starved (it
    still gets real service); every arrival is accounted exactly once
    (served + shed + failed = submitted).

    **B. Price-and-hold vs quote-only (decide-then-lose).**  N churn
    threads race price→decide→acquire cycles over a pool that holds ~2
    full grants.  With reservations (the default), the quoted bytes are
    committed behind a short-TTL hold at decision time, so conversion is
    exact and waitless: zero decide-then-lose incidents, zero leaked holds
    (every hold converts, expires, or cancels).  With ``reservations=
    False`` (the quote-only ablation — the PR-5 behavior), the same race
    loses repeatedly: a quote that promised an unblocked full grant is
    stale by acquisition time, and the decision runs on a degraded grant
    it never priced.  Gates: reservations → 0 incidents AND hold
    conservation; quote-only → incidents > 0.

    **C. Chaos.**  The same serving paths run with every fault injector
    armed (spill I/O errors, device dispatch failures and slowdowns,
    memory-grant timeouts): a linear spilling stream plus an auto
    open-loop stream share one seeded injector.  Retry-with-backoff and
    path fallback absorb what they can; what they cannot becomes a
    *failed sample*, never a poisoned result.  Gates: faults actually
    fired (spill I/O and device sites both — "survived chaos" must not
    mean "chaos never happened"), every served result is bit-for-bit
    equal to the serial reference, zero over-budget grants, zero leaked
    reservations, and exact served/shed/failed accounting.

    ``seed`` threads through table generation, arrival schedules, and the
    fault injector — the committed baseline records it, and re-running
    with the same seed replays the same storm and the same fault schedule.
    """
    import threading as _threading
    import time as _time

    from repro.core import (ArrivalProcess, FaultInjector, MemoryGovernor,
                            QueryServer, ResourceBroker, ResourceRequest,
                            Session, TenantClass)

    fast = reps < 6
    out: Dict = {}

    # -- A. bursty mixed-tenant storm ----------------------------------------
    n = 120_000
    work_mem = 16 * MB
    build, probe = join_tables(n, seed=seed)
    server = QueryServer({"b": build, "p": probe},
                         total_mem=64 * MB, work_mem=work_mem,
                         policy="auto", full_grant_wait_s=0.02)
    q_small = (server.session.table("p").join("b", on="k")
               .aggregate("b_v", "sum"))
    q_sort = (server.session.table("p").join("b", on="k")
              .sort("k", "w").aggregate("b_v", "sum"))
    premium = TenantClass("premium", deadline_s=3.0, priority=2,
                          sheddable=False)
    calm, storm = (6.0, 120.0) if fast else (6.0, 150.0)
    besteffort = TenantClass("besteffort", deadline_s=0.3, priority=0)
    duration = 3.5 if fast else 5.0
    rep = server.serve_open(
        workloads={"premium": [q_small, q_sort],
                   "besteffort": [q_sort, q_small]},
        arrivals={"premium": ArrivalProcess(rate_qps=8, seed=seed + 1),
                  "besteffort": ArrivalProcess(
                      phases=[(1.0, calm), (1.5, storm), (2.5, calm)],
                      seed=seed + 2)},
        duration_s=duration, tenants=[premium, besteffort],
        workers=4, warmup=2)
    prem_lat = rep.tenant_latency("premium")
    prem = rep.tenant_counts("premium")
    be = rep.tenant_counts("besteffort")
    counts = rep.counts
    emit("fig13/storm", (prem_lat.p50 if prem_lat else 0.0) * 1e6,
         {"premium_p99_s": round(prem_lat.p99, 4) if prem_lat else None,
          "premium_slo": round(rep.slo_attainment("premium"), 3),
          "premium_served": prem["served"],
          "be_served": be["served"], "be_shed": be["shed"],
          "be_failed": be["failed"],
          "submitted": counts["submitted"],
          "preemptions": rep.broker.preemptions,
          "decide_then_lose": rep.broker.decide_then_lose,
          "over_budget": rep.governor.over_budget_events})
    out["storm"] = {
        "premium_p50": prem_lat.p50 if prem_lat else 0.0,
        "premium_p99": prem_lat.p99 if prem_lat else 0.0,
        "premium_slo": rep.slo_attainment("premium"),
        "premium": prem, "besteffort": be, "counts": counts,
        "preemptions": rep.broker.preemptions,
        "decide_then_lose": rep.broker.decide_then_lose}
    if counts["submitted"] != (counts["served"] + counts["shed"]
                               + counts["failed"]):
        raise RuntimeError(f"arrival accounting leaked: {counts}")
    if prem["served"] == 0 or prem["shed"] or prem["failed"]:
        raise RuntimeError(
            f"premium (non-sheddable) must serve everything: {prem}")
    if prem_lat.p99 > premium.deadline_s \
            or rep.slo_attainment("premium") < 0.95:
        raise RuntimeError(
            f"premium missed its SLO through the storm: p99 "
            f"{prem_lat.p99:.3f}s vs deadline {premium.deadline_s}s, "
            f"attainment {rep.slo_attainment('premium'):.3f}")
    if be["shed"] == 0:
        raise RuntimeError(
            f"the storm never triggered load shedding ({be}); the burst "
            f"is not overloading the pool")
    if be["served"] == 0:
        raise RuntimeError(f"best-effort starved: {be}")
    if rep.governor.over_budget_events:
        raise RuntimeError("governor over-granted during the storm")

    # -- B. price-and-hold vs quote-only (decide-then-lose) -------------------
    need = 8 * MB
    iters = 30 if fast else 60
    churners = 6
    ablate: Dict = {}
    for mode, reserve_on in (("reserved", True), ("quote_only", False)):
        gov = MemoryGovernor(2 * need + need // 2, min_grant=1 * MB,
                             full_grant_wait_s=0.005)
        broker = ResourceBroker(gov, reservations=reserve_on)
        stop = _threading.Event()

        def churn():
            for _ in range(iters):
                if stop.is_set():
                    return
                rsv = broker.reserve(ResourceRequest("memory",
                                                     need_bytes=need))
                try:
                    # the decide window: selector pricing + plan bookkeeping
                    _time.sleep(0.0005)
                    with broker.memory_lease(need, timeout=5.0,
                                             reservation=rsv):
                        _time.sleep(0.001)
                finally:
                    rsv.cancel()

        threads = [_threading.Thread(target=churn, daemon=True)
                   for _ in range(churners)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120)
        stop.set()
        stats = broker.stats()
        gstats = gov.stats()
        leaked = gstats.holds - (gstats.holds_converted
                                 + gstats.holds_expired
                                 + gstats.holds_cancelled)
        ablate[mode] = {"decide_then_lose": stats.decide_then_lose,
                        "reservations": stats.reservations,
                        "holds": gstats.holds, "leaked_holds": leaked,
                        "held_bytes": gov.held_bytes,
                        "over_budget": gstats.over_budget_events}
        emit(f"fig13/ablation_{mode}", 0.0, ablate[mode])
        if gstats.over_budget_events:
            raise RuntimeError(f"{mode}: holds broke the budget invariant")
        if leaked or gov.held_bytes:
            raise RuntimeError(
                f"{mode}: leaked reservations: {leaked} holds unaccounted, "
                f"{gov.held_bytes} B still held")
    if ablate["reserved"]["decide_then_lose"] != 0:
        raise RuntimeError(
            f"price-and-hold still lost decisions: "
            f"{ablate['reserved']['decide_then_lose']}")
    if ablate["quote_only"]["decide_then_lose"] == 0:
        raise RuntimeError(
            "quote-only churn produced zero decide-then-lose incidents; "
            "the race the reservation closes did not manifest")
    out["ablation"] = ablate

    # -- C. chaos: all injectors armed, results bit-for-bit -------------------
    inj = FaultInjector(seed=seed, spill_io_p=0.02, device_fail_p=0.03,
                        device_slow_p=0.05, device_slow_s=0.005,
                        grant_timeout_p=0.01)
    ref = Session(work_mem=work_mem)
    ref.register("b", build).register("p", probe)
    ref_scalars = {
        0: ref.table("p").join("b", on="k").aggregate("b_v", "sum").scalar(),
        1: (ref.table("p").join("b", on="k").sort("k", "w")
            .aggregate("b_v", "sum").scalar())}

    # linear spilling stream: exercises the spill-I/O and grant fault sites
    # (the budget holds well under one hash table, so every worker's grant
    # degrades toward the floor and genuinely spills — fig11's regime)
    lin = QueryServer({"b": build, "p": probe}, total_mem=10 * MB,
                      work_mem=work_mem, policy="linear", min_grant=1 * MB,
                      faults=inj)
    lq = (lin.session.table("p").join("b", on="k").sort("k", "w")
          .aggregate("b_v", "sum"))
    lin_rep = lin.serve([lq], concurrency=4,
                        queries_per_worker=3 if fast else 5, warmup=1)
    # auto open-loop stream: exercises the device fault sites + fallback
    chaos = QueryServer({"b": build, "p": probe}, total_mem=64 * MB,
                        work_mem=work_mem, policy="auto", faults=inj)
    cq0 = (chaos.session.table("p").join("b", on="k")
           .aggregate("b_v", "sum"))
    cq1 = (chaos.session.table("p").join("b", on="k").sort("k", "w")
           .aggregate("b_v", "sum"))
    chaos_rep = chaos.serve_open(
        workloads={"t": [cq0, cq1]},
        arrivals={"t": ArrivalProcess(rate_qps=30 if fast else 40,
                                      seed=seed + 3)},
        duration_s=2.0 if fast else 3.0,
        tenants=[TenantClass("t", deadline_s=5.0)], workers=4, warmup=1)
    fired = inj.counts()
    for name, srv, rep_ in (("linear", lin, lin_rep),
                            ("auto", chaos, chaos_rep)):
        c = rep_.counts
        if c["submitted"] != c["served"] + c["shed"] + c["failed"]:
            raise RuntimeError(f"chaos/{name} accounting leaked: {c}")
        if rep_.governor.over_budget_events:
            raise RuntimeError(f"chaos/{name}: over-budget under faults")
        g = srv.governor.stats()
        if g.holds != (g.holds_converted + g.holds_expired
                       + g.holds_cancelled) or srv.governor.held_bytes:
            raise RuntimeError(f"chaos/{name}: leaked reservations: {g}")
    for r in lin_rep.queries:
        if r.scalar != ref_scalars[1]:
            raise RuntimeError(
                f"chaos/linear diverged: {r.scalar} != {ref_scalars[1]}")
    for r in chaos_rep.queries:
        if r.scalar != ref_scalars[r.workload_idx]:
            raise RuntimeError(
                f"chaos/auto diverged on item {r.workload_idx}: "
                f"{r.scalar} != {ref_scalars[r.workload_idx]}")
    if fired["spill_io"] == 0:
        raise RuntimeError(
            f"chaos ran but the spill I/O injector never fired: {fired}")
    if fired["device_fail"] == 0 and fired["device_slow"] == 0:
        raise RuntimeError(
            f"chaos ran but no device fault ever fired: {fired}")
    out["chaos"] = {
        "faults": fired,
        "linear": {"counts": lin_rep.counts,
                   "fault_counts": lin_rep.faults},
        "auto": {"counts": chaos_rep.counts,
                 "p99_s": chaos_rep.latency.p99,
                 "fault_counts": chaos_rep.faults},
        "seed": seed}
    emit("fig13/chaos", 0.0,
         {"faults_injected": sum(fired.values()),
          "spill_io": fired["spill_io"],
          "device_fail": fired["device_fail"],
          "grant_timeout": fired["grant_timeout"],
          "linear_served": lin_rep.counts["served"],
          "linear_failed": lin_rep.counts["failed"],
          "auto_served": chaos_rep.counts["served"],
          "auto_failed": chaos_rep.counts["failed"],
          "bit_for_bit": True, "seed": seed})
    return out


# -- Fig 14: robustness map — mid-query adaptive re-planning -------------------

def fig14_robustness_map(reps: int = 3) -> Dict:
    """Per-cell regret of the auto policy, guards ON vs OFF, against the
    best forced path over a (probe selectivity x memory budget) grid.

    Every auto session is built with deliberately stale cost constants
    (linear priced ~50x too optimistic) so the one-shot decision picks the
    linear path even where it will hit the spill cliff — the premature
    lock-in failure mode the paper's robustness maps chart.  Guards-off
    rides the mispriced path to the end; guards-on observes the drift at
    Grace-join partition boundaries and switches to the tensor path
    mid-query, reusing the already-spilled build/probe partitions.  Each
    rep runs in a FRESH session: the map measures the one-shot decision,
    not the feedback loop (fig9 covers that), and every policy sees an
    untimed warmup first so device compiles never land in a cell.

    Hard gates (PR 9 acceptance): all four policies bit-for-bit equal in
    every cell; guards-on never regresses a cell beyond run-to-run noise;
    the worst guards-off cell regret improves >= 2x with guards on; at
    least one switch actually fires across the map; and a governed+tiered
    re-check of the worst cell finishes with balanced tier books and zero
    over-budget grants (a switch is loss-free on the resource ledgers,
    not just on results).
    """
    from repro.core import QueryServer, Session, TierConfig

    n = 250_000
    STALE = 0.02  # mis-calibration factor applied to the auto sessions
    budgets = (("tight", 256 * 1024), ("mid", 1 * MB), ("ample", 32 * MB))
    sels = (0.2, 1.0)  # fraction of probe rows that find a build match

    def tables(sel):
        rng = np.random.default_rng(14)
        build = Relation({
            "k": rng.permutation(n).astype(np.int64),
            "v": rng.integers(0, 1 << 40, n).astype(np.int64)})
        probe = Relation({
            "k": rng.integers(0, int(n / sel), n).astype(np.int64),
            "w": rng.integers(0, 1 << 40, n).astype(np.int64)})
        return build, probe

    def fresh(policy, wm, build, probe):
        if policy in ("linear", "tensor"):
            s = Session(work_mem=wm, policy=policy)
        else:
            s = Session(work_mem=wm, policy="auto",
                        guards=(policy == "on"))
            s.selector.model.c.linear_row_cost *= STALE
            s.selector.model.c.io_byte_cost *= STALE
        s.register("b", build)
        s.register("p", probe)
        return s

    out: Dict = {}
    switches = 0
    worst = {"off": 0.0, "on": 0.0}
    worst_cell = None
    for sel in sels:
        build, probe = tables(sel)
        for label, wm in budgets:
            cell = f"{label}_sel{sel}"
            walls: Dict[str, float] = {}
            scalars = set()
            for policy in ("linear", "tensor", "off", "on"):
                ts = []
                for rep in range(reps + 1):  # rep 0 is the untimed warmup
                    s = fresh(policy, wm, build, probe)
                    res = (s.table("p").join("b", on="k")
                           .aggregate("b_v", "sum")).collect()
                    scalars.add(res.scalar)
                    if rep > 0:
                        ts.append(res.total_wall_s)
                        if policy == "on":
                            switches += sum(m.switched for m in res.metrics)
                walls[policy] = float(np.median(ts))
            if len(scalars) != 1:
                raise RuntimeError(f"fig14/{cell}: paths diverged: {scalars}")
            best = min(walls["linear"], walls["tensor"])
            regret = {p: walls[p] / best - 1.0 for p in ("off", "on")}
            # noise tolerance: identical programs jitter ~20% run-to-run;
            # a true missed switch in a spill cell costs 2-4x
            if walls["on"] > walls["off"] * 1.3 + 0.005:
                raise RuntimeError(
                    f"fig14/{cell}: guards-on regressed the cell: "
                    f"{walls['on']:.3f}s vs guards-off {walls['off']:.3f}s")
            if regret["off"] > worst["off"]:
                worst_cell = (label, wm, sel)
            for p in ("off", "on"):
                worst[p] = max(worst[p], regret[p])
            emit(f"fig14/{cell}", walls["on"] * 1e6,
                 {"linear_p50_s": round(walls["linear"], 4),
                  "tensor_p50_s": round(walls["tensor"], 4),
                  "auto_off_p50_s": round(walls["off"], 4),
                  "auto_on_p50_s": round(walls["on"], 4),
                  "regret_off": round(regret["off"], 3),
                  "regret_on": round(regret["on"], 3)})
            out[cell] = {"linear_p50": walls["linear"],
                         "tensor_p50": walls["tensor"],
                         "off_p50": walls["off"], "on_p50": walls["on"],
                         "regret_off": regret["off"],
                         "regret_on": regret["on"]}
    if switches < 1:
        raise RuntimeError("fig14: no guard ever fired — the map never "
                           "entered the mispriced spill regime")
    improvement = worst["off"] / max(worst["on"], 1e-9)
    emit("fig14/worst_cell_improvement", improvement,
         {"worst_regret_off": round(worst["off"], 3),
          "worst_regret_on": round(worst["on"], 3),
          "switches": switches})
    out["worst_regret_off"] = worst["off"]
    out["worst_regret_on"] = worst["on"]
    out["improvement"] = improvement
    out["switches"] = switches
    if improvement < 2.0:
        raise RuntimeError(
            f"fig14: worst static-decision regret {worst['off']:.2f} only "
            f"improved to {worst['on']:.2f} with guards "
            f"({improvement:.2f}x; gate: >= 2x)")

    # -- governed + tiered re-check of the worst cell ------------------------
    # a switch must be loss-free on the resource ledgers too: balanced
    # tier books, zero over-budget grants, same bits
    label, wm, sel = worst_cell
    build, probe = tables(sel)
    ref = Session(work_mem=64 * MB, policy="linear")
    ref.register("b", build)
    ref.register("p", probe)
    expect = (ref.table("p").join("b", on="k")
              .aggregate("b_v", "sum")).scalar()
    srv = QueryServer({"b": build, "p": probe}, total_mem=48 * MB,
                      work_mem=wm, tiers=TierConfig())
    c = srv.session.selector.model.c
    c.linear_row_cost *= STALE
    c.io_byte_cost *= STALE
    # eager hysteresis: with spill held in memory tiers the staircase is
    # fast enough that a switch is often not priced profitable; the
    # ledger gates below must hold for ANY hysteresis policy, so take
    # the switch eagerly here
    c.guard_hysteresis = 0.5
    got = srv.submit(srv.session.table("p").join("b", on="k")
                     .aggregate("b_v", "sum")).scalar
    if got != expect:
        raise RuntimeError(f"fig14/governed: switched run diverged from "
                           f"the linear reference: {got} != {expect}")
    srv.session.tier_ledger.verify_balanced()
    gov = srv.governor.stats()
    if gov.over_budget_events:
        raise RuntimeError(f"fig14/governed: governor over-granted: {gov}")
    emit("fig14/governed_worst_cell", 0.0,
         {"cell": f"{label}_sel{sel}", "bit_for_bit": True,
          "over_budget": gov.over_budget_events,
          "switches": srv.broker.stats().switches})
    out["governed"] = {"cell": f"{label}_sel{sel}",
                       "switches": srv.broker.stats().switches,
                       "over_budget": gov.over_budget_events}
    return out


# -- Fig 15: partition-parallel sharded fragment scaling ----------------------

def fig15_sharded_scaling(reps: int = 7, seed: int = 0) -> Dict:
    """Sharded fused execution over the device mesh (PR 7): the same fused
    Join→Filter→Aggregate fragment, FIXED total rows, executed single-device
    and partition-parallel over 2/4/8 broker lanes.

    The sharded path hash/radix co-partitions both sides by the join key
    (the build side cached as key-sorted runs on the Relation), runs the
    fragment per partition under ``shard_map``, and combines per-partition
    aggregates on device — one gang dispatch, ONE device→host sync.  On a
    serial host the win is NOT core parallelism: each shard probes a
    cache-resident pre-sorted run via searchsorted, so the per-query device
    argsort of the build side (the dominant term of the single-device
    fragment at this scale) disappears from the steady-state path.

    Hard gates (the PR acceptance criteria): sharded(8) p50 >= 2x the
    single-device p50 at fixed total rows; every shard count bit-for-bit
    equal to single-device AND to an independent numpy oracle; warm sharded
    queries keep <= 1 host sync and 0 H2D bytes (partition caches holding);
    every gang lane records dispatches and queue waits; the governed
    closed-loop serve (max_shards=8) finishes with ZERO over-budget grants.
    """
    from repro.core import QueryServer, ResourceBroker, Session, col
    from repro.core.fused import FusedSpec, run_fused
    from repro.distributed.sharding import available_partitions

    avail = available_partitions()
    if avail < 8:
        raise RuntimeError(
            f"fig15 needs an 8-way host mesh (have {avail} device(s)); set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
            f"initializes (benchmarks/run.py and tests/conftest.py do this)")

    fast = reps < 6
    n = 512_000 if fast else 1_000_000  # FIXED total rows for every cell
    rng = np.random.default_rng(seed)
    # unique build keys (PK-FK, §V.A) over a SPARSE int64 domain — the
    # paper's high-dimensional key space.  A dense [0, n) domain would let
    # the single-device program take its sort-free coordinate-join core and
    # the comparison would measure the wrong regime: the sharded path's win
    # is retiring the per-query device argsort of the build side via cached
    # key-sorted partition runs.  Payloads are bounded so the int64 sum
    # stays exactly float64-representable — bit-for-bit means ==, not ≈.
    bk = (rng.permutation(n).astype(np.int64) * 1_000_003) + 17
    build = Relation({"k": bk,
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": bk[rng.integers(0, n, n)],
                      "w": rng.integers(0, 1000, n).astype(np.int64)})
    spec = FusedSpec(join_key="k", filter_fn=col("w") < 500, sort_keys=(),
                     agg=("b_v", "sum"))
    # independent host oracle: unique build keys, so the join is a lookup
    order = np.argsort(bk)
    pk, pw = np.asarray(probe["k"]), np.asarray(probe["w"])
    hit = order[np.searchsorted(bk[order], pk)]
    oracle = float(np.asarray(build["v"])[hit[pw < 500]].sum())

    out: Dict = {"n": n}
    lane_stats = None
    for shards in (1, 2, 4, 8):
        broker = ResourceBroker()
        req = None if shards == 1 else shards
        for _ in range(2):  # cold: compile + partition/device caches
            run_fused(spec, build, probe, broker=broker, shards=req)
        walls, scalars = [], set()
        for _ in range(reps):
            scalar, m = run_fused(spec, build, probe, broker=broker,
                                  shards=req)
            walls.append(m.wall_s)
            scalars.add(scalar)
            if m.devices != shards:
                raise RuntimeError(
                    f"requested {shards} shards, ran on {m.devices}")
            if m.host_syncs != 1:
                raise RuntimeError(
                    f"warm {shards}-shard query took {m.host_syncs} host "
                    f"syncs; the capacity hint is not holding")
            if shards > 1 and m.h2d_bytes:
                raise RuntimeError(
                    f"warm {shards}-shard query uploaded {m.h2d_bytes} "
                    f"bytes; the partition caches are not holding")
        if scalars != {oracle}:
            raise RuntimeError(
                f"{shards}-shard result diverged from the host oracle: "
                f"{sorted(scalars)} != {oracle}")
        s = latency_stats(walls)
        out[shards] = {"p50": s.p50, "p99": s.p99}
        if shards == 8:
            lane_stats = broker.stats().lanes
    for shards in (2, 4, 8):
        speedup = out[1]["p50"] / max(out[shards]["p50"], 1e-12)
        out[shards]["speedup"] = speedup
        emit(f"fig15/fused_shards{shards}", out[shards]["p50"] * 1e6,
             {"p99_s": round(out[shards]["p99"], 4),
              "speedup_vs_single": round(speedup, 2), "rows": n})
    emit("fig15/fused_single", out[1]["p50"] * 1e6,
         {"p99_s": round(out[1]["p99"], 4), "rows": n})
    if out[8]["speedup"] < 2.0:
        raise RuntimeError(
            f"sharded(8) speedup {out[8]['speedup']:.2f}x < 2.0x over "
            f"single-device at fixed {n} rows: the partition-parallel "
            f"path is not paying for itself")
    # every lane of the 8-gang must have dispatched and recorded its waits
    if lane_stats is None or len(lane_stats) < 8:
        raise RuntimeError(f"expected 8 broker lanes, saw "
                           f"{0 if lane_stats is None else len(lane_stats)}")
    for i, lane in enumerate(lane_stats):
        if lane["dispatches"] <= 0:
            raise RuntimeError(f"lane {i} never dispatched: {lane}")
        if "wait_s_total" not in lane or "ewma_wait_s" not in lane:
            raise RuntimeError(f"lane {i} is missing queue-wait stats")
        emit(f"fig15/lane{i}", lane["ewma_service_s"] * 1e6,
             {"dispatches": int(lane["dispatches"]),
              "peak_depth": int(lane["peak_depth"]),
              "coalesced": int(lane["coalesced"]),
              "wait_s_total": round(lane["wait_s_total"], 4)})
    out["lanes"] = [{k: lane[k] for k in ("dispatches", "peak_depth",
                                          "coalesced", "wait_s_total")}
                    for lane in lane_stats]

    # -- governed closed-loop serve: the sharded path under the single
    # global memory budget, concurrency 3, per-lane accounting in the report
    n_srv = 200_000 if fast else 400_000
    srng = np.random.default_rng(seed + 1)
    tables = {
        "orders": Relation({
            "uid": srng.integers(0, n_srv // 4, n_srv).astype(np.int64),
            "w": srng.integers(-100, 100, n_srv).astype(np.int64)}),
        "users": Relation({
            "uid": srng.integers(0, n_srv // 4, n_srv).astype(np.int64),
            "region": srng.integers(0, 10, n_srv).astype(np.int64)}),
    }
    ref_sess = Session(work_mem=32 * MB, policy="auto")
    ref_sess.register("orders", tables["orders"])
    ref_sess.register("users", tables["users"])
    ref_scalar = (ref_sess.table("orders").join("users", on="uid")
                  .filter(col("w") > 0).aggregate("w", "sum")).scalar()

    server = QueryServer(tables, total_mem=64 * MB, work_mem=16 * MB,
                         policy="auto", max_shards=8)
    if len(server.broker.lanes) != 8:
        raise RuntimeError("max_shards=8 server did not pre-create 8 lanes")
    q = (server.session.table("orders").join("users", on="uid")
         .filter(col("w") > 0).aggregate("w", "sum"))
    rep = server.serve([q], concurrency=3,
                       queries_per_worker=max(4, reps - 3), warmup=2,
                       keep_relations=False)
    gov, brk = rep.governor, rep.broker
    if gov.over_budget_events:
        raise RuntimeError(f"governed sharded serve over-granted: {gov}")
    if rep.failed:
        raise RuntimeError(f"governed sharded serve failed queries: "
                           f"{rep.failed}")
    bad = {r.scalar for r in rep.queries} - {ref_scalar}
    if bad:
        raise RuntimeError(f"served scalars diverged from the reference: "
                           f"{sorted(bad)} != {ref_scalar}")
    if len(brk.lanes) != 8 or any(l["dispatches"] <= 0 for l in brk.lanes):
        raise RuntimeError(f"serve report is missing per-lane dispatch "
                           f"accounting: {brk.lanes}")
    s = latency_stats([r.wall_s for r in rep.queries])
    emit("fig15/served_sharded_c3", s.p50 * 1e6,
         {"p99_s": round(s.p99, 4), "qps": round(rep.qps, 2),
          "over_budget": gov.over_budget_events,
          "lane_dispatches": "|".join(str(int(l["dispatches"]))
                                      for l in brk.lanes),
          "gang_wait_s_total": round(sum(l["wait_s_total"]
                                         for l in brk.lanes), 3)})
    out["serve"] = {"p50": s.p50, "p99": s.p99, "qps": rep.qps,
                    "over_budget": gov.over_budget_events,
                    "lanes": [int(l["dispatches"]) for l in brk.lanes]}
    return out


# -- Fig 16: tiered spill hierarchy under the constrained budget --------------

def fig16_tiered_spill(reps: int = 6) -> Dict:
    """Tiered spill (PR 8): compressed host-RAM pool + emulated remote tier
    between the operator and the disk ``SpillManager``, priced end to end.

    Three cells, three claims:

    * **Staircase** (concurrency 2): ONE large Grace join (N=1.2M) whose
      hash table exceeds the entire 24 MB budget, served back-to-back by
      two workers, disk-only vs tiered.  At low concurrency every spilled
      partition's fsync/journal cost sits on the critical path, so routing
      the spill traffic through the T0 pool (raw store at memcpy speed; the
      dict/pack codec runs only when it buys admission) takes the whole
      staircase step out: the gate is tiered P99 >= 1.5x better.  This is
      deliberately NOT measured at concurrency 8 — on a single-core host
      with a page-cached spill directory, ext4 journal batching amortizes
      the fsync cost across concurrent writers and the structural gap
      narrows to ~1.2-1.4x; the low-concurrency cell is where the tier's
      advantage is load-bearing, and pinning it keeps the gate honest.
    * **Serving** (the fig11 constrained cell: 24 MB budget, concurrency 8,
      1 MB admission floor, 3 small : 1 large mixed stream): tiered-linear
      must land strictly BETWEEN the disk-spill cliff and the tensor path
      on the large class (tensor < tiered < disk on large-class P50), the
      tensor and pressure-aware ``auto`` paths must stay stable
      (P99/P50 <= 1.5), and ``auto`` — which prices the tiered candidate
      with per-tier byte costs from the quote — must have <= 10% mean
      regret vs the best forced path.
    * **Prefetch overlap**: a tiered session with a pool that holds only
      ~half the spilled partitions (and no T1) must promote T2-resident
      build partitions back into the pool WHILE earlier partitions' probes
      are being consumed — the async T2->T0 stream — and still return the
      exact scalar.

    Every tiered cell closes its books: per-tier bytes_freed ==
    bytes_written, zero live bytes, zero leaked pool bytes at quiesce, and
    zero over-budget grants (the T0 pool is host RAM outside the governed
    budget; the governor's invariant must survive the tiers).
    """
    from repro.core import QueryServer, Session, TierConfig

    qpw = max(8, int(reps))
    out: Dict = {}

    def _steady(rep):
        return [r for r in rep.queries if r.seq > 0]

    def _balanced(rep, cell):
        t = rep.tiers
        if not t:
            raise RuntimeError(f"{cell}: tiered serve returned no tier books")
        for name in ("t0", "t1", "t2"):
            s = t[name]
            if s["bytes_freed"] != s["bytes_written"] or s["live_bytes"] != 0:
                raise RuntimeError(
                    f"{cell}: tier {name} books do not balance: "
                    f"written={s['bytes_written']} freed={s['bytes_freed']} "
                    f"live={s['live_bytes']}")
        if t["pool_leaked_bytes"] != 0:
            raise RuntimeError(f"{cell}: {t['pool_leaked_bytes']} T0 pool "
                               f"bytes leaked at quiesce")
        return t

    # -- cell 1: the spill staircase, disk vs tiered at concurrency 2 --------
    lb, lp = join_tables(1_200_000, seed=11)
    tier_cfg = TierConfig(t0_capacity=192 * MB, t1_capacity=256 * MB,
                          t1_latency_s=5e-5, t1_gbps=8.0)
    stair: Dict = {}
    stair_scalars = set()
    for variant, tiers in (("disk", None), ("tiered", tier_cfg)):
        server = QueryServer({"lb": lb, "lp": lp},
                             total_mem=24 * MB, work_mem=32 * MB,
                             policy="linear", min_grant=1 * MB,
                             queue_aware=False, device_max_batch=1,
                             tiers=tiers)
        q = (server.session.table("lp").join("lb", on="k")
             .aggregate("b_v", "sum"))
        rep = server.serve([q], concurrency=2, queries_per_worker=qpw,
                           warmup=2)
        stair_scalars.update(r.scalar for r in rep.queries)
        s = latency_stats([r.wall_s for r in _steady(rep)])
        gov = rep.governor
        if gov.over_budget_events:
            raise RuntimeError(f"staircase/{variant}: governor over-granted "
                               f"its budget: {gov}")
        row = {"p50": s.p50, "p99": s.p99,
               "spill_mb": rep.total_temp_mb,
               "over_budget": gov.over_budget_events}
        if tiers is not None:
            books = _balanced(rep, f"staircase/{variant}")
            if books["t0"]["bytes_written"] <= 0:
                raise RuntimeError("staircase/tiered: the T0 pool absorbed "
                                   "no spill traffic — the hierarchy is not "
                                   "in the write path")
            row["t0_written_mb"] = books["t0"]["bytes_written"] / 1e6
        emit(f"fig16/staircase_{variant}", s.p50 * 1e6,
             {"p99_s": round(s.p99, 4),
              "spill_mb": round(rep.total_temp_mb, 1),
              "over_budget": gov.over_budget_events,
              "qps": round(rep.qps, 2)})
        stair[variant] = row
    if len(stair_scalars) != 1:
        raise RuntimeError(f"staircase results diverged between disk and "
                           f"tiered spill: {stair_scalars}")
    stair["p99_speedup"] = stair["disk"]["p99"] / max(stair["tiered"]["p99"],
                                                      1e-9)
    emit("fig16/staircase_p99_speedup", stair["p99_speedup"],
         {"disk_p99_s": round(stair["disk"]["p99"], 4),
          "tiered_p99_s": round(stair["tiered"]["p99"], 4)})
    if stair["p99_speedup"] < 1.5:
        raise RuntimeError(
            f"tiered-linear P99 is only {stair['p99_speedup']:.2f}x better "
            f"than disk-only under the constrained budget (gate: >= 1.5x)")
    out["staircase"] = stair

    # -- cell 2: the fig11 serving cell with the tiered candidate priced -----
    sb, sp = join_tables(200_000, seed=7)
    lb2, lp2 = join_tables(600_000, seed=11)
    serve_cfg = TierConfig(t0_capacity=384 * MB, t1_capacity=256 * MB,
                           t1_latency_s=5e-5, t1_gbps=8.0)
    serving: Dict = {}
    means: Dict[str, float] = {}
    scalars: Dict[int, set] = {0: set(), 1: set()}
    for variant, policy, tiers in (("linear", "linear", None),
                                   ("linear_tiered", "linear", serve_cfg),
                                   ("tensor", "tensor", None),
                                   ("auto", "auto", serve_cfg)):
        server = QueryServer({"small_build": sb, "small_probe": sp,
                              "large_build": lb2, "large_probe": lp2},
                             total_mem=24 * MB, work_mem=32 * MB,
                             policy=policy, min_grant=1 * MB,
                             queue_aware=False, device_max_batch=1,
                             tiers=tiers)
        small = (server.session.table("small_probe")
                 .join("small_build", on="k")
                 .sort("k", "w").aggregate("b_v", "sum"))
        large = (server.session.table("large_probe")
                 .join("large_build", on="k")
                 .sort("k", "w").aggregate("b_v", "sum"))
        rep = server.serve([small, small, small, large],
                           concurrency=8, queries_per_worker=qpw, warmup=2)
        for r in rep.queries:
            scalars[1 if r.workload_idx == 3 else 0].add(r.scalar)
        steady = _steady(rep)
        s = latency_stats([r.wall_s for r in steady])
        lg = latency_stats([r.wall_s for r in steady if r.workload_idx == 3])
        gov = rep.governor
        if gov.over_budget_events:
            raise RuntimeError(f"serving/{variant}: governor over-granted "
                               f"its budget: {gov}")
        if tiers is not None:
            _balanced(rep, f"serving/{variant}")
        ratio = s.p99 / max(s.p50, 1e-9)
        means[variant] = sum(r.wall_s for r in steady) / len(steady)
        emit(f"fig16/serving_{variant}", s.p50 * 1e6,
             {"p99_s": round(s.p99, 4),
              "p99_over_p50": round(ratio, 2),
              "large_p50_s": round(lg.p50, 4),
              "spill_mb": round(rep.total_temp_mb, 1),
              "over_budget": gov.over_budget_events,
              "qps": round(rep.qps, 2)})
        serving[variant] = {"p50": s.p50, "p99": s.p99, "ratio": ratio,
                            "large_p50": lg.p50, "large_p99": lg.p99,
                            "mean": means[variant],
                            "spill_mb": rep.total_temp_mb}
    if any(len(v) != 1 for v in scalars.values()):
        raise RuntimeError(
            f"serving results diverged across spill variants: {scalars}")
    # between-ness on the class the tiers actually serve: the large query
    # spills by construction, and its P50 must order tensor < tiered < disk
    lg_t = serving["tensor"]["large_p50"]
    lg_tier = serving["linear_tiered"]["large_p50"]
    lg_d = serving["linear"]["large_p50"]
    if not (lg_t < lg_tier < lg_d):
        raise RuntimeError(
            f"tiered-linear did not land between the tensor path and the "
            f"disk-spill cliff on large-class p50: tensor={lg_t:.2f}s "
            f"tiered={lg_tier:.2f}s disk={lg_d:.2f}s")
    for variant in ("tensor", "auto"):
        if serving[variant]["ratio"] > 1.5:
            raise RuntimeError(
                f"{variant} p99/p50 {serving[variant]['ratio']:.2f} > 1.5x: "
                f"the stable path is not stable with tiers priced in")
    best_forced = min(means[v] for v in ("linear", "linear_tiered", "tensor"))
    regret = means["auto"] / best_forced - 1.0
    serving["auto_regret"] = regret
    emit("fig16/auto_regret", regret,
         {"auto_mean_s": round(means["auto"], 4),
          "best_forced_mean_s": round(best_forced, 4)})
    if regret > 0.10:
        raise RuntimeError(
            f"auto mean latency regret {regret:.1%} vs the best forced "
            f"path (gate: <= 10%) — tier-aware costing is mispricing")
    out["serving"] = serving

    # -- cell 3: async T2->T0 prefetch overlap -------------------------------
    pb, pp = join_tables(600_000, seed=3)
    ref = Session(work_mem=4 * MB, policy="linear")
    ref.register("pb", pb)
    ref.register("pp", pp)
    ref_scalar = (ref.table("pp").join("pb", on="k")
                  .aggregate("b_v", "sum")).scalar()
    pf_cfg = TierConfig(t0_capacity=8 * MB, t1_capacity=0,
                        t1_latency_s=5e-5, t1_gbps=8.0, prefetch=True)
    sess = Session(work_mem=4 * MB, policy="linear", tiers=pf_cfg)
    sess.register("pb", pb)
    sess.register("pp", pp)
    with Timer() as t:
        got = (sess.table("pp").join("pb", on="k")
               .aggregate("b_v", "sum")).scalar()
    if got != ref_scalar:
        raise RuntimeError(f"prefetching tiered join diverged from the disk "
                           f"reference: {got} != {ref_scalar}")
    snap = sess.tier_ledger.snapshot()
    sess.tier_ledger.verify_balanced()
    if snap["t2"]["bytes_written"] <= 0:
        raise RuntimeError("prefetch cell never demoted to T2 — the pool "
                           "was not undersized as intended")
    if snap["prefetches"] <= 0:
        raise RuntimeError("no T2->T0 promotions completed during probe "
                           "consumption — the async prefetcher is dead")
    emit("fig16/prefetch_overlap", t.elapsed * 1e6,
         {"prefetches": int(snap["prefetches"]),
          "promoted_mb": round(snap["t0"]["bytes_promoted"] / 1e6, 1),
          "t2_written_mb": round(snap["t2"]["bytes_written"] / 1e6, 1)})
    out["prefetch"] = {"prefetches": int(snap["prefetches"]),
                       "promoted_mb": snap["t0"]["bytes_promoted"] / 1e6,
                       "wall_s": t.elapsed}
    return out


# -- Fig 17: compressed device-resident column layouts -------------------------

def fig17_compressed_layouts(reps: int = 7) -> Dict:
    """Packed device layouts (PR 10): dictionary / frame-of-reference codes
    uploaded instead of logical 8-byte columns, joins and group-bys running
    in the code domain, decode deferred to the single result fetch.

    Three cells, each run twice — ``REPRO_DEVICE_COMPRESS=1`` (packed, the
    default) vs ``=0`` (raw) — over FRESH relation instances so every mode
    starts with a cold device cache:

      * **serving** — the fig9 shape (PK-FK join → sort → aggregate, cold
        first query then warm repeats) with compressible domains: dense key
        space and narrow payload ranges.  Gates: bit-for-bit equal scalars,
        warm H2D == 0 in BOTH modes (packed residency preserves the serving
        contract), cold H2D bytes shrink >= 2x, and warm HBM footprint
        (device-cache resident bytes) shrinks >= 2x;
      * **star** — the fig10 shape (3-table star join through the rewrite
        pipeline) so chained fused fragments + projection pruning compose
        with packed uploads; gated on scalar equality and H2D shrink >= 2x;
      * **governed** — the serving workload through a QueryServer under a
        constrained shared memory budget with compression on: packed
        uploads must not let any linear grant slip past the governor
        (``over_budget_events == 0``).

    The shrink ratios are returned as ``*_speedup`` leaves (higher is
    better) so the CI baseline comparison gates them like any other
    performance number."""
    import os

    from repro.core import QueryServer, Session, col
    from repro.core.table_cache import device_cache_resident_bytes

    n = 200_000

    def serving_tables(seed=0):
        rng = np.random.default_rng(seed)
        build = Relation({
            "k": rng.permutation(n).astype(np.int64),
            "v": rng.integers(0, 200, n).astype(np.int64),
        })
        probe = Relation({
            "k": rng.integers(0, n, n).astype(np.int64),
            "w": rng.integers(0, 1000, n).astype(np.int64),
        })
        return build, probe

    def star_tables(seed=0):
        n_orders, n_users, n_parts = 300_000, 10_000, 2_000
        rng = np.random.default_rng(seed)
        orders = Relation({
            "uid": rng.integers(0, n_users, n_orders).astype(np.int64),
            "pid": rng.integers(0, n_parts, n_orders).astype(np.int64),
            "w": rng.integers(-50, 50, n_orders).astype(np.int64),
        })
        users = Relation({
            "uid": np.arange(n_users, dtype=np.int64),
            "region": rng.integers(0, 4, n_users).astype(np.int64),
        })
        parts = Relation({
            "pid": np.arange(n_parts, dtype=np.int64),
            "price": rng.integers(1, 9, n_parts).astype(np.int64),
        })
        return orders, users, parts

    out: Dict = {}
    saved = os.environ.get("REPRO_DEVICE_COMPRESS")
    try:
        # -- serving cell (fig9 shape), packed vs raw ----------------------
        cell: Dict = {}
        for mode in ("packed", "raw"):
            os.environ["REPRO_DEVICE_COMPRESS"] = "1" if mode == "packed" else "0"
            build, probe = serving_tables()
            plan = lambda: Aggregate(Sort(Join(Scan(build), Scan(probe), "k"),
                                          ["k", "w"]), "b_v", "sum")
            sel = PathSelector(1 * MB, profile=RuntimeProfile())
            ex = Executor(work_mem=1 * MB, policy="auto", selector=sel)
            q = ex.execute(plan())
            cold_wall, cold_h2d = q.total_wall_s, q.total_h2d_bytes
            cold_h2d_logical = q.total_h2d_bytes_logical
            scalar = q.scalar
            walls, warm_h2d = [], 0
            for _ in range(reps):
                q = ex.execute(plan())
                walls.append(q.total_wall_s)
                warm_h2d = max(warm_h2d, q.total_h2d_bytes)
                if q.scalar != scalar:
                    raise RuntimeError(f"{mode} warm result diverged")
            s = latency_stats(walls)
            hbm = (device_cache_resident_bytes(build)
                   + device_cache_resident_bytes(probe))
            if warm_h2d != 0:
                raise RuntimeError(
                    f"{mode} warm queries transferred {warm_h2d} H2D bytes: "
                    f"device residency does not survive compression")
            emit(f"fig17/serving_{mode}", s.p50 * 1e6,
                 {"cold_h2d_mb": round(cold_h2d / 1e6, 2),
                  "cold_h2d_logical_mb": round(cold_h2d_logical / 1e6, 2),
                  "hbm_resident_mb": round(hbm / 1e6, 2),
                  "cold_wall_s": round(cold_wall, 4)})
            cell[mode] = {"scalar": scalar, "cold_h2d": cold_h2d,
                          "hbm": hbm, "p50": s.p50, "cold_wall": cold_wall}
        if cell["packed"]["scalar"] != cell["raw"]["scalar"]:
            raise RuntimeError(
                f"packed serving result diverged from raw: "
                f"{cell['packed']['scalar']} != {cell['raw']['scalar']}")
        h2d_shrink = cell["raw"]["cold_h2d"] / max(1, cell["packed"]["cold_h2d"])
        hbm_shrink = cell["raw"]["hbm"] / max(1, cell["packed"]["hbm"])
        if h2d_shrink < 2.0:
            raise RuntimeError(
                f"cold H2D shrink {h2d_shrink:.2f}x < 2x: packed uploads "
                f"are not materially smaller")
        if hbm_shrink < 2.0:
            raise RuntimeError(
                f"warm HBM shrink {hbm_shrink:.2f}x < 2x: packed residency "
                f"is not materially smaller")
        emit("fig17/serving_shrink", 0.0,
             {"h2d_shrink": round(h2d_shrink, 2),
              "hbm_shrink": round(hbm_shrink, 2)})
        out["serving"] = {
            "h2d_shrink_speedup": h2d_shrink,
            "hbm_shrink_speedup": hbm_shrink,
            "packed_cold_h2d_mb": cell["packed"]["cold_h2d"] / 1e6,
            "raw_cold_h2d_mb": cell["raw"]["cold_h2d"] / 1e6,
            "packed_hbm_mb": cell["packed"]["hbm"] / 1e6,
            "raw_hbm_mb": cell["raw"]["hbm"] / 1e6,
        }

        # -- star-join cell (fig10 shape through the rewrite pipeline) -----
        star: Dict = {}
        for mode in ("packed", "raw"):
            os.environ["REPRO_DEVICE_COMPRESS"] = "1" if mode == "packed" else "0"
            orders, users, parts = star_tables()
            sess = Session(work_mem=1 * MB, policy="tensor")
            for name, rel in (("orders", orders), ("users", users),
                              ("parts", parts)):
                sess.register(name, rel)
            run = lambda sess=sess: (
                sess.table("orders")
                .join(sess.table("users"), on="uid")
                .join(sess.table("parts"), on="pid")
                .filter((col("w") > 0) & (col("b_region") <= 2))
                .sort("uid").aggregate("w", "sum").collect())
            cold = run()
            q = cold
            for _ in range(max(2, reps // 2)):
                q = run()
                if q.scalar != cold.scalar:
                    raise RuntimeError(f"star {mode} diverged across repeats")
            star[mode] = {"scalar": cold.scalar,
                          "cold_h2d": cold.total_h2d_bytes,
                          "warm_h2d": q.total_h2d_bytes}
            emit(f"fig17/star_{mode}", 0.0,
                 {"cold_h2d_mb": round(cold.total_h2d_bytes / 1e6, 2),
                  "warm_h2d_mb": round(q.total_h2d_bytes / 1e6, 2)})
        if star["packed"]["scalar"] != star["raw"]["scalar"]:
            raise RuntimeError(
                f"packed star join diverged from raw: "
                f"{star['packed']['scalar']} != {star['raw']['scalar']}")
        star_shrink = (star["raw"]["cold_h2d"]
                       / max(1, star["packed"]["cold_h2d"]))
        if star_shrink < 2.0:
            raise RuntimeError(
                f"star-join cold H2D shrink {star_shrink:.2f}x < 2x")
        out["star"] = {"h2d_shrink_speedup": star_shrink,
                       "packed_cold_h2d_mb": star["packed"]["cold_h2d"] / 1e6,
                       "raw_cold_h2d_mb": star["raw"]["cold_h2d"] / 1e6}

        # -- governed cell: compression must not leak past the governor ----
        os.environ["REPRO_DEVICE_COMPRESS"] = "1"
        build, probe = serving_tables(seed=3)
        server = QueryServer(
            {"build": build, "probe": probe},
            total_mem=24 * MB, work_mem=32 * MB, policy="auto",
            min_grant=2 * MB)
        query = (server.session.table("probe").join("build", on="k")
                 .sort("k", "w").aggregate("b_v", "sum"))
        rep = server.serve([query], concurrency=4,
                           queries_per_worker=max(3, reps // 2), warmup=1)
        if len({r.scalar for r in rep.queries}) != 1:
            raise RuntimeError("governed packed serving diverged")
        if rep.governor.over_budget_events:
            raise RuntimeError(
                f"governor over-granted under packed layouts: {rep.governor}")
        emit("fig17/governed", rep.latency.p50 * 1e6,
             {"p99_s": round(rep.latency.p99, 4),
              "over_budget": rep.governor.over_budget_events,
              "h2d_mb": round(rep.total_h2d_bytes / 1e6, 2),
              "h2d_logical_mb": round(rep.total_h2d_bytes_logical / 1e6, 2)})
        out["governed"] = {"over_budget": rep.governor.over_budget_events,
                           "h2d_mb": rep.total_h2d_bytes / 1e6,
                           "h2d_logical_mb": rep.total_h2d_bytes_logical / 1e6}
    finally:
        if saved is None:
            os.environ.pop("REPRO_DEVICE_COMPRESS", None)
        else:
            os.environ["REPRO_DEVICE_COMPRESS"] = saved
    return out


ALL = {
    "fig1": fig1_scalability,
    "fig3": fig3_hashtable_growth,
    "fig4": fig4_tail_latency,
    "fig5": fig5_multikey_sort,
    "fig6": fig6_p99_workmem,
    "fig7": fig7_spill,
    "fig8": fig8_pipeline,
    "fig9": fig9_serving,
    "fig10": fig10_star_join,
    "fig11": fig11_concurrent_tail,
    "fig12": fig12_queue_aware,
    "fig13": fig13_slo_serving,
    "fig14": fig14_robustness_map,
    "fig15": fig15_sharded_scaling,
    "fig16": fig16_tiered_spill,
    "fig17": fig17_compressed_layouts,
    "headline": headline,
    "selector": selector_analysis,
    "regime": regime_model,
    "moe": moe_dispatch_paths,
}
