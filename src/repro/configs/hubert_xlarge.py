"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only audio transformer.
The conv feature frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, S, d_model]; the backbone predicts
cluster ids (vocab=504) per frame."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    vocab_size=504,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    mlp_type="gelu",
    is_encoder=True,
    causal=False,
    modality="audio_stub",
    source="arXiv:2106.07447 (w2v2-family encoder)",
)

SMOKE = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=2,
    d_model=64,
    vocab_size=56,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    mlp_type="gelu",
    is_encoder=True,
    causal=False,
    modality="audio_stub",
)

register(CONFIG, SMOKE)
