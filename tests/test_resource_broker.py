"""ResourceBroker: typed lease semantics, the exclusive-dispatch invariant,
micro-batch coalescing (bit-for-bit vs serial), and queue-aware pricing."""
import threading
import time

import numpy as np
import pytest

from repro.core import (DeviceQueue, Executor, FusedSpec, MemoryGovernor,
                        PathSelector, PressureQuote, Relation, ResourceBroker,
                        ResourceRequest, RuntimeProfile, run_fused)

MB = 1 << 20


# ---------------------------------------------------------------------------
# Lease semantics
# ---------------------------------------------------------------------------

def test_memory_lease_wraps_governor_grant():
    gov = MemoryGovernor(16 * MB, min_grant=1 * MB)
    broker = ResourceBroker(gov)
    with broker.memory_lease(4 * MB) as lease:
        assert lease.size == 4 * MB
        assert not lease.degraded
        assert gov.in_use == 4 * MB
    assert gov.in_use == 0
    # hold EWMA learned from the release — the signal that prices waits
    assert broker.stats().mem_ewma_hold_s > 0


def test_memory_lease_double_release_raises():
    broker = ResourceBroker(MemoryGovernor(8 * MB))
    lease = broker.memory_lease(2 * MB)
    lease.release()
    with pytest.raises(RuntimeError):
        lease.release()
    assert broker.governor.in_use == 0


def test_memory_lease_requires_governor():
    with pytest.raises(RuntimeError):
        ResourceBroker().memory_lease(1 * MB)


def test_device_lease_double_release_raises():
    broker = ResourceBroker()
    lease = broker.device_lease()
    lease.release()
    with pytest.raises(RuntimeError):
        lease.release()


def test_resource_request_validation():
    with pytest.raises(ValueError):
        ResourceRequest("gpu-ram")


# ---------------------------------------------------------------------------
# Device queue: exclusivity, coalescing, escape hatch
# ---------------------------------------------------------------------------

def test_same_batch_key_coalesces_distinct_keys_do_not():
    """Queued same-shape dispatches are admitted together as ONE group;
    a different shape queued between rounds stays exclusive."""
    queue = DeviceQueue()
    hold = queue.acquire(batch_key="head")
    active = []
    lock = threading.Lock()
    peak_batched = []
    done = threading.Event()

    def worker(key):
        with queue.acquire(batch_key=key) as lease:
            with lock:
                active.append(lease)
                if len(active) > 1:
                    peak_batched.append(all(l.batched for l in active))
            done.wait(2)  # keep group members overlapping
            with lock:
                active.remove(lease)

    threads = [threading.Thread(target=worker, args=("A",)) for _ in range(3)]
    threads.append(threading.Thread(target=worker, args=("B",)))
    for th in threads:
        th.start()
        time.sleep(0.02)  # arrival order: A, A, A, B
    hold.release()
    time.sleep(0.1)  # the A-group should now be admitted together
    with lock:
        n_active = len(active)
    done.set()
    for th in threads:
        th.join(timeout=10)
    assert n_active == 3           # the whole A group ran concurrently
    assert peak_batched and all(peak_batched)  # >1 active ⟹ all batched
    stats = queue.stats()
    assert stats["coalesced"] == 3  # the three A leases shared a group
    assert stats["groups"] == 3     # head, A-group, B


def test_serialize_escape_hatch_grants_without_queueing(monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_SERIALIZE", "0")
    queue = DeviceQueue()
    hold = queue.acquire(batch_key="x")
    t0 = time.perf_counter()
    other = queue.acquire(batch_key="y")  # must NOT block behind hold
    assert time.perf_counter() - t0 < 0.5
    assert other.wait_s == 0.0
    other.release(), hold.release()
    assert queue.stats()["bypassed"] == 2
    wait, depth = queue.expected_wait()
    assert wait == 0.0  # unserialized dispatch has no queue to price


def test_hammer_never_over_budget_and_exclusive_unless_batched():
    """The broker-level invariants under adversarial concurrency: the
    governor never over-grants, and the device never runs more than one
    dispatch at a time unless every concurrent lease belongs to one
    coalesced batch group."""
    budget = 16 * MB
    broker = ResourceBroker(MemoryGovernor(budget, min_grant=1 * MB))
    stop = time.perf_counter() + 1.0
    errors = []
    active = []
    lock = threading.Lock()
    sizes = [3 * MB, 7 * MB, 12 * MB, 5 * MB]
    keys = ["A", "B", None, "A", None, "B"]

    def worker(seed: int):
        i = seed
        try:
            while time.perf_counter() < stop:
                if i % 2:
                    with broker.memory_lease(sizes[i % len(sizes)]) as g:
                        assert 0 < g.size <= sizes[i % len(sizes)]
                        time.sleep(0.001)
                else:
                    with broker.device_lease(keys[i % len(keys)]) as lease:
                        with lock:
                            active.append(lease)
                            if len(active) > 1:
                                assert all(l.batched for l in active), \
                                    "concurrent exclusive dispatches"
                        time.sleep(0.001)
                        with lock:
                            active.remove(lease)
                i += 1
        except BaseException as e:  # pragma: no cover - diagnostic path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    assert not errors
    gov_stats = broker.governor.stats()
    assert gov_stats.over_budget_events == 0
    assert 0 < gov_stats.peak_in_use <= budget
    assert broker.governor.in_use == 0
    stats = broker.stats()
    assert stats.device_dispatches > 8
    assert stats.device_ewma_service_s > 0


# ---------------------------------------------------------------------------
# Micro-batched fused dispatch: bit-for-bit parity with serial
# ---------------------------------------------------------------------------

def _join_tables(n, seed=11):
    rng = np.random.default_rng(seed)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
    return build, probe


def test_batched_fused_dispatch_bit_for_bit_equal_to_serial():
    """Concurrent same-shape fused dispatches coalesce into micro-batched
    lease groups; every result must equal the serial run exactly (int64
    aggregates: bit-for-bit)."""
    broker = ResourceBroker(device_queue=DeviceQueue())
    n = 30_000
    spec = FusedSpec(join_key="k", filter_fn=None, sort_keys=("k",),
                     agg=("b_v", "sum"))
    tables = [_join_tables(n, seed=100 + i) for i in range(4)]
    serial = [run_fused(spec, b, p, broker=broker)[0] for b, p in tables]

    results = {}
    errors = []
    start = threading.Barrier(8)

    def worker(wid: int):
        try:
            start.wait(10)
            out = []
            for i, (b, p) in enumerate(tables):
                val, m = run_fused(spec, b, p, broker=broker)
                out.append(val)
            results[wid] = out
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120)
    assert not errors
    for wid, out in results.items():
        assert out == serial  # float equality of int64 sums: exact
    # with 8 workers racing 4 warm shapes, coalescing must have happened
    assert broker.stats().device_coalesced > 0


# ---------------------------------------------------------------------------
# Pricing
# ---------------------------------------------------------------------------

def test_memory_quote_prices_admission_wait_after_observations():
    gov = MemoryGovernor(8 * MB, min_grant=2 * MB)
    broker = ResourceBroker(gov)
    free = broker.price(ResourceRequest("memory", need_bytes=4 * MB))
    assert free.grant_bytes == 4 * MB
    assert free.expected_wait_s == 0.0 and not free.would_block
    hold = broker.memory_lease(8 * MB)
    blocked = broker.price(ResourceRequest("memory", need_bytes=4 * MB))
    assert blocked.would_block
    assert blocked.expected_wait_s == 0.0  # no wait/hold history yet
    time.sleep(0.05)
    hold.release()  # teaches the hold EWMA (~50ms)
    hold2 = broker.memory_lease(8 * MB)
    quote = broker.price(ResourceRequest("memory", need_bytes=4 * MB))
    assert quote.would_block
    assert quote.expected_wait_s > 0.01  # ≈ half the observed hold, at least
    hold2.release()


def test_queue_blind_broker_quotes_zero_wait_but_real_grants():
    """The fig12 ablation: queue_pricing=False keeps PR-4 semantics —
    pressure-aware grant sizing, no wait term."""
    gov = MemoryGovernor(8 * MB, min_grant=2 * MB)
    broker = ResourceBroker(gov, queue_pricing=False)
    with broker.memory_lease(8 * MB):
        time.sleep(0.02)
    hold = broker.memory_lease(8 * MB)
    quote = broker.price(ResourceRequest("memory", need_bytes=4 * MB))
    assert quote.grant_bytes == 2 * MB  # degraded sizing still reported
    assert quote.would_block            # blocking still visible
    assert quote.expected_wait_s == 0.0  # the wait term is what is ablated
    dev = broker.price(ResourceRequest("device"))
    assert dev.expected_wait_s == 0.0
    hold.release()


def test_device_quote_counts_serial_rounds_not_coalescible_work():
    queue = DeviceQueue()
    broker = ResourceBroker(device_queue=queue)
    # teach the service EWMA with one completed lease
    lease = broker.device_lease("warm")
    time.sleep(0.02)
    lease.release()
    service = queue.stats()["ewma_service_s"]
    assert service > 0
    hold = broker.device_lease("running")
    waiters = []
    for key in ("A", "A", "B"):
        th = threading.Thread(
            target=lambda k=key: broker.device_lease(k).release())
        th.start()
        waiters.append(th)
        time.sleep(0.02)
    # queued: A, A, B → rounds ahead for a NEW shape = running + A + B = 3
    wait_new, depth = queue.expected_wait("C")
    # for a shape that coalesces with the queued A round: running + B = 2
    wait_a, _ = queue.expected_wait("A")
    assert depth == 4
    assert wait_new == pytest.approx(3 * service, rel=0.5)
    assert wait_a < wait_new
    hold.release()
    for th in waiters:
        th.join(timeout=10)


def test_selector_folds_quote_waits_into_path_costs():
    """A linear-friendly fragment flips to tensor when the memory quote
    carries an admission wait, and back when the device queue is the
    expensive side — run-time queues, not estimates, break the tie."""
    rng = np.random.default_rng(3)
    n = 20_000
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
    spec = FusedSpec(join_key="k", filter_fn=None, sort_keys=("k",),
                     agg=("b_v", "sum"))
    sel = PathSelector(64 * MB, profile=RuntimeProfile())
    base = sel.choose_fragment(spec, build, probe)
    stall = max(1.0, 10 * (base.t_linear + base.t_tensor))
    parked = sel.choose_fragment(
        spec, build, probe,
        mem_quote=PressureQuote("memory", 64 * MB, stall, 1, True))
    assert parked.path == "tensor"
    assert parked.mem_wait_s == stall
    jammed = sel.choose_fragment(
        spec, build, probe,
        dev_quote=PressureQuote("device", 0, stall, 3, True))
    assert jammed.path == "linear"
    assert jammed.dev_wait_s == stall


# ---------------------------------------------------------------------------
# Per-operator tensor path: lease acquisition + profile hygiene
# ---------------------------------------------------------------------------

def test_per_op_tensor_path_lease_wait_excluded_from_profile():
    """The ROADMAP-noted profile pollution: per-operator tensor
    observations taken while the device lease was queued must not carry
    the contention noise — lease wait lands in OpMetrics.queue_wait_s and
    the profile records wall MINUS wait, exactly as fused queue_wait_s."""
    from repro.core import Join, Scan, Sort

    rng = np.random.default_rng(5)
    n = 4_000
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 100, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 100, n).astype(np.int64)})
    broker = ResourceBroker(device_queue=DeviceQueue())
    profile = RuntimeProfile()
    sel = PathSelector(1 * MB, force="tensor", profile=profile)
    ex = Executor(work_mem=1 * MB, policy="tensor", selector=sel,
                  fuse=False, broker=broker)
    plan = lambda: Sort(Join(Scan(build), Scan(probe), "k"), ["k"])
    ex.execute(plan())  # warm the jit caches (warmup discard consumes it)
    ex.execute(plan())  # converge profile cells with an uncontended run

    hold = broker.device_lease(batch_key="jam")  # jam the device queue
    stall = 0.25
    releaser = threading.Timer(stall, hold.release)
    releaser.start()
    res = ex.execute(plan())
    queued = [m for m in res.metrics if m.queue_wait_s > 0]
    assert queued, "per-operator tensor path never waited on its lease"
    total_wait = sum(m.queue_wait_s for m in res.metrics)
    assert total_wait >= 0.8 * stall  # the jam is visible end-to-end...
    for m in res.metrics:
        cell = profile.observed(m.op, "tensor", m.rows_in)
        if cell is None or cell.count == 0:
            continue
        # ...but no profile cell absorbed it: observations stay at the
        # uncontended execution cost, orders of magnitude below the stall
        assert cell.wall_s < 0.5 * stall
    releaser.join()


def test_executor_conflicting_governor_and_broker_rejected():
    gov_a = MemoryGovernor(8 * MB)
    broker_b = ResourceBroker(MemoryGovernor(8 * MB))
    with pytest.raises(ValueError):
        Executor(work_mem=4 * MB, governor=gov_a, broker=broker_b)
