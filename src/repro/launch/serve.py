"""Serving driver: continuous batching over the decode step.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.models import init_model
    from repro.serving.engine import BatchScheduler, Request, generate

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit("encoder-only architecture: no decode/serving path")
    params = init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    sched = BatchScheduler(args.batch_size)
    for i in range(args.requests):
        sched.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
            max_new_tokens=args.max_new,
            priority=int(rng.integers(0, 3))))

    t0 = time.time()
    served = 0
    while sched.queue:
        batch_reqs = sched.admit(args.batch_size)
        prompts = np.stack([r.prompt for r in batch_reqs])
        outs = generate(params, cfg, prompts, args.max_new)
        for r, o in zip(batch_reqs, outs):
            r.output = list(o)
            served += 1
        print(f"batch of {len(batch_reqs)} done "
              f"(priorities {[r.priority for r in batch_reqs]})")
    dt = time.time() - t0
    total_tokens = served * args.max_new
    print(f"served {served} requests / {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
