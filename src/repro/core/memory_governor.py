"""Global memory governor: one budget, many concurrent queries.

The paper's tail-latency claim is about memory *under contention*: a single
query with a private ``work_mem`` never reproduces the phase transition,
because nothing ever takes its memory away.  Real servers (PostgreSQL with
hundreds of backends, REMOP's memory-aware operator scheduling) hand every
concurrent operator a slice of one finite pool — and the slice an operator
actually receives, not the configured ``work_mem``, decides whether it stays
in the fast in-memory regime or collapses into the spill regime.

:class:`MemoryGovernor` owns that pool.  Linear-path operators acquire a
:class:`MemoryGrant` before building their linearized intermediate (hash
table / sort runs) and release it when the operator completes:

  * a request is served **in full** when the budget allows — the operator
    runs exactly as it would have with a private ``work_mem``;
  * under pressure the grant is **degraded** by the configured
    :class:`GrantPolicy` — down to ``min_grant`` under the default
    :class:`FloorGrantPolicy`, or to a demand-weighted share of the free
    pool under :class:`ProportionalShareGrantPolicy` — the operator still
    runs, but with less memory than it wanted, which is what pushes it over
    the spill boundary (the contention-induced tail fig11 measures);
  * when not even ``min_grant`` is available the request **blocks**
    (admission control) until a running query releases memory — queueing
    delay instead of an out-of-memory failure.

The governor's hard invariant — asserted continuously and exposed for tests
via :attr:`GovernorStats.over_budget_events` / :attr:`GovernorStats.
peak_in_use` — is that the sum of outstanding grants never exceeds the
budget, *whatever the policy returns* (policy output is clamped centrally).
Tensor-path operators never acquire grants: device-resident execution is
precisely the path that does not build a host linearized intermediate, which
is why it sidesteps the contention this module models.

:meth:`would_grant` is the grant-size half of the pressure signal; the
queue-aware half (expected admission *wait*) lives in
:meth:`~repro.core.resource_broker.ResourceBroker.price`, which reads
:meth:`admission_probe` — the peek that also reports whether acquisition
would block and how many waiters are already parked.

**Price-and-hold** closes the decide-then-act gap those peeks leave open: a
probe is non-binding, so ``auto`` could decide "linear fits in full" on a
quote and then *lose* the bytes to a concurrent grant before acquiring
(fig13's decide-then-lose incident).  :meth:`hold` places a short-TTL
:class:`MemoryHold` — the quoted bytes are *committed* (counted against the
budget alongside grants, so the invariant becomes ``in_use + held <=
total``) until the decision either converts the hold into a grant via
``acquire(..., hold=...)`` (no wait: the bytes are already committed),
cancels it (tensor path chosen), or the TTL reaps it (a decision that
crashed or stalled can never strand budget).  Expiry is lazy-but-prompt:
every lock acquisition reaps, and admission waits are bounded by the
nearest hold deadline so a waiter blocked only by an expiring hold wakes
when it lapses rather than sleeping forever.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Union

__all__ = ["MemoryGovernor", "MemoryGrant", "MemoryHold", "TieredGrant",
           "GovernorStats", "GrantPolicy", "FloorGrantPolicy",
           "ProportionalShareGrantPolicy", "BrokerInvariantViolation"]


class BrokerInvariantViolation(RuntimeError):
    """A resource-accounting invariant was broken (double release, negative
    budget, leaked hold conversion).  The one error class the serving layer
    treats as fatal: unlike a per-query failure, corrupted budget accounting
    poisons every subsequent admission decision, so the run must abort.
    Subclasses RuntimeError so existing double-release handling keeps
    working."""

MB = 1 << 20


# ---------------------------------------------------------------------------
# Grant degradation policies
# ---------------------------------------------------------------------------

class GrantPolicy:
    """Sizing for a request that cannot be served in full.

    ``degraded_size(requested, available, floor, demand_bytes)`` returns the
    bytes to grant; ``demand_bytes`` is the sum of *requested* bytes across
    outstanding grants and parked waiters (excluding this request) — the
    live demand picture a workload-aware policy weighs against.  The
    governor clamps the result into ``[floor, min(requested, available)]``
    regardless, so no policy can violate the budget invariant.
    """

    name = "base"

    def degraded_size(self, requested: int, available: int, floor: int,
                      demand_bytes: int) -> int:
        raise NotImplementedError

    def tier_quotas(self, granted: int, requested: int,
                    tiers) -> Dict[str, Optional[int]]:
        """Per-tier SPILL quotas accompanying a :class:`TieredGrant` when
        the governor has a spill-tier hierarchy attached (``tiers`` is a
        :class:`~repro.core.tier.TierConfig`-shaped object).

        Default sizing: the compressed T0 pool may hold up to
        ``max(2 × grant, half the pool)`` — 2× because dictionary encoding
        + bit packing roughly halves the footprint, and at least half the
        pool because the operator that NEEDS the staircase is precisely
        the floor-degraded one (a 1 MB floor grant would otherwise get a
        2 MB T0 quota and route its whole spill to the slow tiers).  The
        quota bounds ONE operator's claim; the pool's global capacity cap
        still holds, so concurrent quotas may oversubscribe it safely
        (first-come admission, exactly like an OS page cache).  T1 is
        bounded by its configured capacity; T2 (disk) is the unbounded
        backstop (``None``).  Policies may override to shape the staircase
        differently.
        """
        cap = int(tiers.t0_capacity)
        t0 = min(cap, max(2 * int(granted), cap // 2))
        t1 = tiers.t1_capacity
        return {"t0": t0, "t1": None if t1 is None else int(t1), "t2": None}


class FloorGrantPolicy(GrantPolicy):
    """Full grant if it fits, else the admission floor — NOT "whatever is
    left".  A partially-filled grant spills anyway (its deficit is what it
    is) while stranding the remaining pool, so the queries that COULD have
    fit (the fast tier) start degrading too and the whole distribution
    collapses.  Floor-degrading keeps the pool liquid: operators that fit
    stay fast, operators that don't pay their own spill and nobody else's.
    """

    name = "floor"

    def degraded_size(self, requested, available, floor, demand_bytes):
        return floor


class ProportionalShareGrantPolicy(GrantPolicy):
    """Demand-weighted proportional share — the PostgreSQL
    ``hash_mem_multiplier`` analogue.

    A squeezed request receives its share of the *free* pool weighted by its
    estimated linearized-intermediate footprint (callers request estimated
    hash-table / sort-run bytes, so the weight IS the hash-table size):

        share = available * (requested * m) / (demand + requested * m)

    with ``m = hash_mem_multiplier``.  Memory-hungry hash builds are favored
    by ``m`` exactly as PG lets hash tables exceed ``work_mem`` by that
    factor — their spill amplification is superlinear in the deficit, so a
    byte given to the biggest deficit saves the most temp I/O.  Unlike the
    floor policy this trades pool liquidity for deficit reduction; fig11's
    floor rationale still holds for bimodal workloads, which is why floor
    stays the default and this policy is opt-in
    (``MemoryGovernor(policy="proportional")``).
    """

    name = "proportional"

    def __init__(self, hash_mem_multiplier: float = 2.0):
        if hash_mem_multiplier <= 0:
            raise ValueError(
                f"hash_mem_multiplier must be positive, got "
                f"{hash_mem_multiplier}")
        self.hash_mem_multiplier = float(hash_mem_multiplier)

    def degraded_size(self, requested, available, floor, demand_bytes):
        weighted = requested * self.hash_mem_multiplier
        share = int(available * weighted / max(1.0, demand_bytes + weighted))
        return max(floor, share)


def _resolve_policy(policy: Union[str, GrantPolicy, None]) -> GrantPolicy:
    if policy is None or policy == "floor":
        return FloorGrantPolicy()
    if policy == "proportional":
        return ProportionalShareGrantPolicy()
    if isinstance(policy, GrantPolicy):
        return policy
    raise ValueError(f"unknown grant policy {policy!r}; expected 'floor', "
                     f"'proportional', or a GrantPolicy instance")


@dataclasses.dataclass
class GovernorStats:
    """Cumulative counters; snapshot via :meth:`MemoryGovernor.stats`."""

    grants: int = 0            # grants issued
    degraded: int = 0          # grants smaller than their request
    waits: int = 0             # requests that blocked in admission control
    wait_s_total: float = 0.0  # total seconds spent blocked
    peak_in_use: int = 0       # high-water mark of committed bytes (granted + held)
    over_budget_events: int = 0  # invariant violations (must stay 0)
    holds: int = 0             # price-and-hold reservations placed
    holds_converted: int = 0   # holds that became grants
    holds_expired: int = 0     # holds reaped at TTL expiry
    holds_cancelled: int = 0   # holds explicitly released unconverted


@dataclasses.dataclass
class MemoryGrant:
    """An outstanding slice of the governor's budget.

    ``size`` is the work_mem the holding operator must live within; ``size <
    requested`` marks a degraded grant.  Use as a context manager (exit
    releases if still held) or call :meth:`release` exactly once — a second
    explicit release raises instead of silently corrupting the budget
    accounting (a double ``_release`` would inflate the available pool and
    let the governor over-grant its budget).
    """

    governor: "MemoryGovernor"
    size: int
    requested: int
    wait_s: float = 0.0
    _released: bool = False

    @property
    def degraded(self) -> bool:
        return self.size < self.requested

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        if self._released:
            raise BrokerInvariantViolation(
                f"memory grant of {self.size} B released twice; a silent "
                f"double release would inflate the available budget")
        self._released = True
        self.governor._release(self.size, self.requested)

    def __enter__(self) -> "MemoryGrant":
        return self

    def __exit__(self, *exc) -> None:
        if not self._released:
            self.release()


@dataclasses.dataclass
class TieredGrant(MemoryGrant):
    """A :class:`MemoryGrant` extended with per-tier spill quotas.

    ``quotas`` maps tier name (``"t0"``/``"t1"``/``"t2"``) to the byte
    quota this operator may place there (``None`` = only the tier's own
    capacity caps it).  Issued instead of a plain grant whenever the
    governor has a spill-tier hierarchy attached
    (``MemoryGovernor(tiers=...)``); sizing comes from
    :meth:`GrantPolicy.tier_quotas`.  Release semantics are unchanged —
    quotas are advisory caps the :class:`~repro.core.tier.TierManager`
    enforces, not budget the governor tracks (the T0 pool's bytes are
    bounded BY the quota, which is itself derived from the granted size).
    """

    quotas: Dict[str, Optional[int]] = dataclasses.field(default_factory=dict)


class MemoryHold:
    """A short-TTL commitment of budget bytes placed at decision time.

    The price-and-hold half of a reservation: ``size`` bytes are counted
    against the budget (``in_use + held <= total``) from placement until the
    hold **converts** into a grant (``MemoryGovernor.acquire(...,
    hold=...)``), is **cancelled** (the decision chose the tensor path), or
    **expires** at ``deadline`` (the TTL backstop: a crashed or stalled
    decision can never strand budget).  Exactly one of those three outcomes
    occurs — the leak test asserts ``holds == converted + expired +
    cancelled`` and ``held_bytes == 0`` at quiesce.
    """

    __slots__ = ("governor", "size", "requested", "deadline", "state")

    def __init__(self, governor: "MemoryGovernor", size: int, requested: int,
                 deadline: float):
        self.governor = governor
        self.size = size
        self.requested = requested
        self.deadline = deadline
        self.state = "held"  # held | converted | expired | cancelled

    @property
    def active(self) -> bool:
        """True while the hold still pins budget (reaps expiry first)."""
        self.governor._reap_holds()
        return self.state == "held"

    def cancel(self) -> None:
        """Release the hold unconverted.  Idempotent; a no-op once the hold
        has converted or expired."""
        self.governor._cancel_hold(self)

    def __enter__(self) -> "MemoryHold":
        return self

    def __exit__(self, *exc) -> None:
        self.cancel()


class MemoryGovernor:
    """Thread-safe admission controller over one total memory budget."""

    def __init__(self, total_bytes: int, min_grant: int = 1 * MB,
                 full_grant_wait_s: float = 0.0,
                 policy: Union[str, GrantPolicy, None] = None,
                 tiers=None):
        if total_bytes <= 0:
            raise ValueError(f"total_bytes must be positive, got {total_bytes}")
        min_grant = max(1, int(min_grant))
        if min_grant > total_bytes:
            raise ValueError(
                f"min_grant ({min_grant} B) exceeds the total budget "
                f"({total_bytes} B); no request could ever be admitted")
        self.total_bytes = int(total_bytes)
        self.min_grant = min_grant
        # how long a request is willing to wait for its FULL size before
        # accepting a degraded grant (0 = degrade immediately; degrading
        # early trades per-query latency for throughput, like PG choosing a
        # smaller hash table over queueing the whole backend)
        self.full_grant_wait_s = float(full_grant_wait_s)
        self.policy = _resolve_policy(policy)
        # optional spill-tier hierarchy (a TierConfig-shaped object): when
        # set, every grant is a TieredGrant carrying per-tier spill quotas
        self.tiers = tiers
        self._in_use = 0
        self._held = 0            # bytes committed to unexpired holds
        self._holds: list = []    # active MemoryHold objects
        self._demand = 0          # sum of REQUESTED bytes, outstanding grants
        self._waiters = 0         # requests parked in admission control
        self._waiting_demand = 0  # sum of their requested bytes
        self._cond = threading.Condition()
        self._stats = GovernorStats()

    # -- observability -------------------------------------------------------
    @property
    def in_use(self) -> int:
        return self._in_use

    @property
    def held_bytes(self) -> int:
        """Bytes committed to active (unexpired) holds."""
        self._reap_holds()
        with self._cond:
            return self._held

    @property
    def available(self) -> int:
        return self.total_bytes - self._in_use - self._held

    @property
    def waiters(self) -> int:
        """Requests currently parked in admission control."""
        return self._waiters

    @property
    def pressure(self) -> float:
        """Fraction of the budget currently granted (0.0 = idle, 1.0 = full)."""
        return self._in_use / self.total_bytes

    def stats(self) -> GovernorStats:
        self._reap_holds()
        with self._cond:
            return dataclasses.replace(self._stats)

    # -- hold bookkeeping (price-and-hold reservations) ----------------------
    def _reap_locked(self, now: float) -> None:
        """Expire past-deadline holds (lock held).  Lazy: runs on every lock
        acquisition; admission waits are additionally bounded by the nearest
        hold deadline so expiry also wakes parked waiters promptly."""
        if not self._holds:
            return
        freed = 0
        live = []
        for h in self._holds:
            if h.state == "held" and now >= h.deadline:
                h.state = "expired"
                freed += h.size
                self._stats.holds_expired += 1
            elif h.state == "held":
                live.append(h)
        if freed:
            self._holds[:] = live
            self._held -= freed
            self._cond.notify_all()

    def _reap_holds(self) -> None:
        with self._cond:
            self._reap_locked(time.perf_counter())

    def _next_hold_deadline_locked(self):
        return min((h.deadline for h in self._holds if h.state == "held"),
                   default=None)

    def hold(self, requested: int, ttl_s: float = 0.25
             ) -> Optional["MemoryHold"]:
        """Commit the bytes :meth:`acquire` would grant right now, for at
        most ``ttl_s`` seconds.  Returns ``None`` when acquisition would
        *block* (not even the floor is free): there is nothing truthful to
        hold, and the quote already says "you will wait".  Never blocks."""
        requested = max(1, int(requested))
        floor = min(requested, self.min_grant)
        now = time.perf_counter()
        with self._cond:
            self._reap_locked(now)
            avail = self.total_bytes - self._in_use - self._held
            if avail < floor or self._waiters > 0:
                # parked waiters have admission priority over new decisions:
                # holding bytes past them would starve admission control
                return None
            size = self._size_for(requested, avail, floor)
            h = MemoryHold(self, size, requested, now + float(ttl_s))
            self._holds.append(h)
            self._held += size
            self._stats.holds += 1
            self._stats.peak_in_use = max(self._stats.peak_in_use,
                                          self._in_use + self._held)
            if self._in_use + self._held > self.total_bytes:  # pragma: no cover
                self._stats.over_budget_events += 1
            return h

    def _cancel_hold(self, h: "MemoryHold") -> None:
        with self._cond:
            self._reap_locked(time.perf_counter())
            if h.state != "held":
                return  # converted/expired/already cancelled: idempotent
            h.state = "cancelled"
            self._holds.remove(h)
            self._held -= h.size
            self._stats.holds_cancelled += 1
            self._cond.notify_all()

    def _size_for(self, requested: int, avail: int, floor: int) -> int:
        """Grant sizing (lock held): full if it fits, else the policy's
        degraded size clamped into [floor, min(requested, avail)] — the
        clamp, not the policy, owns the never-over-budget invariant.
        Callers are never in the waiting set at sizing time (acquire runs
        ``end_wait`` first), so the demand picture excludes this request
        by construction."""
        if avail >= requested:
            return requested
        demand = self._demand + self._waiting_demand
        size = int(self.policy.degraded_size(requested, avail, floor,
                                             max(0, demand)))
        return max(floor, min(size, requested, max(floor, avail)))

    def would_grant(self, requested: int) -> int:
        """Non-binding peek: the grant size a request of ``requested`` bytes
        would receive right now.  Mirrors :meth:`acquire`'s sizing exactly
        (a signal reporting a size the grant will never contain would price
        the linear path against phantom memory); it does NOT model admission
        blocking — :meth:`admission_probe` adds the would-block/waiters
        picture and :meth:`~repro.core.resource_broker.ResourceBroker.price`
        turns that into an expected wait."""
        return self.admission_probe(requested)[0]

    def admission_probe(self, requested: int):
        """``(size, would_block, waiters)`` — the wait-aware pressure peek.

        ``size`` is :meth:`would_grant`'s answer; ``would_block`` reports
        whether :meth:`acquire` would park in admission control right now
        (not even the floor is free); ``waiters`` how many requests are
        already parked ahead.  Lock-held reads only; never blocks, never
        reserves."""
        requested = max(1, int(requested))
        floor = min(requested, self.min_grant)
        with self._cond:
            self._reap_locked(time.perf_counter())
            avail = self.total_bytes - self._in_use - self._held
            size = self._size_for(requested, avail, floor)
            return size, avail < floor, self._waiters

    # -- grant lifecycle -----------------------------------------------------
    def _make_grant(self, size: int, requested: int,
                    wait_s: float) -> MemoryGrant:
        if self.tiers is None:
            return MemoryGrant(self, size, requested, wait_s)
        quotas = self.policy.tier_quotas(size, requested, self.tiers)
        return TieredGrant(self, size, requested, wait_s, quotas=quotas)

    def acquire(self, requested: int, timeout: Optional[float] = None,
                hold: Optional["MemoryHold"] = None) -> MemoryGrant:
        """Block until at least ``min(requested, min_grant)`` bytes are free,
        then grant the policy's sizing (full when it fits).

        With ``full_grant_wait_s > 0`` the request first waits up to that
        long for its *full* size before settling for a degraded grant.
        ``timeout`` bounds the total admission wait; expiry raises
        :class:`TimeoutError` (the caller's query fails rather than wedging
        a worker forever — surfaced, never silent).

        ``hold`` converts a still-active :class:`MemoryHold` placed by
        :meth:`hold` into the grant *without waiting*: the bytes were
        committed at decision time, which is exactly the decide-then-lose
        guarantee.  An expired or cancelled hold falls through to the normal
        admission path (the quote's promise lapsed; the request competes
        like everyone else).
        """
        requested = max(1, int(requested))
        floor = min(requested, self.min_grant)
        t0 = time.perf_counter()
        deadline = None if timeout is None else t0 + timeout
        with self._cond:
            self._reap_locked(t0)
            if hold is not None and hold.state == "held":
                # conversion: committed bytes move from held to granted —
                # no admission wait, no sizing (priced at hold time)
                hold.state = "converted"
                self._holds.remove(hold)
                self._held -= hold.size
                self._in_use += hold.size
                self._demand += hold.requested
                self._stats.holds_converted += 1
                self._stats.grants += 1
                if hold.size < hold.requested:
                    self._stats.degraded += 1
                self._stats.peak_in_use = max(self._stats.peak_in_use,
                                              self._in_use + self._held)
                if self._in_use + self._held > self.total_bytes:  # pragma: no cover
                    self._stats.over_budget_events += 1
                return self._make_grant(hold.size, hold.requested, 0.0)
            waited = False

            def begin_wait():
                nonlocal waited
                if not waited:
                    waited = True
                    self._waiters += 1
                    self._waiting_demand += requested

            def end_wait():
                if waited:
                    self._waiters -= 1
                    self._waiting_demand -= requested

            def avail():
                return self.total_bytes - self._in_use - self._held

            def wait_bounded(remaining):
                # bound every park by the nearest hold deadline: a waiter
                # blocked only by an expiring hold must wake when it lapses
                nd = self._next_hold_deadline_locked()
                if nd is not None:
                    until_expiry = max(1e-3, nd - time.perf_counter())
                    remaining = (until_expiry if remaining is None
                                 else min(remaining, until_expiry))
                self._cond.wait(remaining)
                self._reap_locked(time.perf_counter())

            try:
                # phase 1: opportunistic wait for the full request
                if self.full_grant_wait_s > 0:
                    full_deadline = t0 + self.full_grant_wait_s
                    if deadline is not None:
                        full_deadline = min(full_deadline, deadline)
                    while (avail() < requested
                           and time.perf_counter() < full_deadline):
                        begin_wait()
                        wait_bounded(full_deadline - time.perf_counter())
                # phase 2: admission control — never grant below the floor
                while avail() < floor:
                    begin_wait()
                    remaining = (None if deadline is None
                                 else deadline - time.perf_counter())
                    if remaining is not None and remaining <= 0:
                        self._stats.waits += 1
                        self._stats.wait_s_total += time.perf_counter() - t0
                        raise TimeoutError(
                            f"admission control: {requested} B requested, "
                            f"{avail()} B available after {timeout:.3f}s")
                    wait_bounded(remaining)
            finally:
                end_wait()
            size = self._size_for(requested, avail(), floor)
            self._in_use += size
            self._demand += requested
            if self._in_use + self._held > self.total_bytes:  # pragma: no cover
                self._stats.over_budget_events += 1
            self._stats.grants += 1
            if size < requested:
                self._stats.degraded += 1
            if waited:
                self._stats.waits += 1
                self._stats.wait_s_total += time.perf_counter() - t0
            self._stats.peak_in_use = max(self._stats.peak_in_use,
                                          self._in_use + self._held)
            wait_s = time.perf_counter() - t0 if waited else 0.0
        return self._make_grant(size, requested, wait_s)

    def _release(self, size: int, requested: int) -> None:
        with self._cond:
            self._in_use -= size
            self._demand -= requested
            if self._in_use < 0:  # pragma: no cover - accounting corruption
                self._stats.over_budget_events += 1
                self._in_use = 0
            if self._demand < 0:  # pragma: no cover
                self._demand = 0
            self._cond.notify_all()
