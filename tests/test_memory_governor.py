"""MemoryGovernor: grant semantics, admission control, and the budget
invariant (the fig11 acceptance criterion's "zero over-budget grants")."""
import threading
import time

import pytest

from repro.core import MemoryGovernor

MB = 1 << 20


def test_full_grant_when_budget_free():
    gov = MemoryGovernor(64 * MB, min_grant=1 * MB)
    with gov.acquire(16 * MB) as g:
        assert g.size == 16 * MB
        assert not g.degraded
        assert gov.in_use == 16 * MB
    assert gov.in_use == 0
    assert gov.stats().grants == 1
    assert gov.stats().degraded == 0


def test_degrades_to_floor_not_to_leftover():
    gov = MemoryGovernor(24 * MB, min_grant=2 * MB)
    hold = gov.acquire(16 * MB)
    # 8 MB is free, but a 16 MB request that can't be met in full gets the
    # FLOOR (it will spill regardless; the leftover stays liquid for
    # requests that can actually fit)
    g = gov.acquire(16 * MB)
    assert g.size == 2 * MB
    assert g.degraded
    assert gov.stats().degraded == 1
    # a request the leftover CAN serve in full still gets everything it asked
    g2 = gov.acquire(5 * MB)
    assert g2.size == 5 * MB and not g2.degraded
    for grant in (g2, g, hold):
        grant.release()
    assert gov.in_use == 0


def test_small_request_below_floor_granted_exactly():
    gov = MemoryGovernor(8 * MB, min_grant=2 * MB)
    with gov.acquire(512 * 1024) as g:
        assert g.size == 512 * 1024


def test_admission_blocks_until_release():
    gov = MemoryGovernor(4 * MB, min_grant=1 * MB)
    first = gov.acquire(4 * MB)  # pool exhausted: not even the floor is free
    acquired = []

    def blocked():
        with gov.acquire(1 * MB) as g:
            acquired.append(g.size)

    th = threading.Thread(target=blocked)
    th.start()
    time.sleep(0.05)
    assert acquired == []          # still parked in admission control
    first.release()
    th.join(timeout=5)
    assert acquired == [1 * MB]
    stats = gov.stats()
    assert stats.waits >= 1
    assert stats.wait_s_total > 0


def test_admission_timeout_raises():
    gov = MemoryGovernor(4 * MB, min_grant=1 * MB)
    hold = gov.acquire(4 * MB)
    with pytest.raises(TimeoutError):
        gov.acquire(1 * MB, timeout=0.05)
    hold.release()


def test_would_grant_is_nonbinding_peek():
    gov = MemoryGovernor(24 * MB, min_grant=2 * MB)
    assert gov.would_grant(16 * MB) == 16 * MB
    hold = gov.acquire(16 * MB)
    # full-or-floor, exactly mirroring acquire(): 8 MB is free but a 16 MB
    # request would be degraded to the floor, and the pressure signal must
    # price the linear path against the grant it would actually get
    assert gov.would_grant(16 * MB) == 2 * MB
    assert gov.would_grant(8 * MB) == 8 * MB   # fits: served in full
    hold2 = gov.acquire(8 * MB)
    assert gov.would_grant(16 * MB) == 2 * MB  # exhausted: the floor
    assert gov.in_use == 24 * MB               # peeks granted nothing
    hold.release(), hold2.release()


def test_double_release_raises_and_does_not_inflate_budget():
    """Regression: releasing the same grant twice must raise — a silent
    second release would credit the pool twice and let the governor
    over-grant its budget."""
    gov = MemoryGovernor(8 * MB)
    g = gov.acquire(4 * MB)
    g.release()
    with pytest.raises(RuntimeError):
        g.release()
    assert gov.in_use == 0  # the failed release changed nothing
    assert gov.stats().over_budget_events == 0
    # the pool was credited exactly once: a full-budget request still fits
    with gov.acquire(8 * MB) as g2:
        assert g2.size == 8 * MB


def test_context_manager_exit_after_manual_release_is_safe():
    gov = MemoryGovernor(8 * MB)
    with gov.acquire(4 * MB) as g:
        g.release()  # explicit early release inside the with-block
    assert gov.in_use == 0
    assert gov.stats().over_budget_events == 0


def test_admission_probe_reports_blocking_and_waiters():
    gov = MemoryGovernor(4 * MB, min_grant=1 * MB)
    size, would_block, waiters = gov.admission_probe(2 * MB)
    assert (size, would_block, waiters) == (2 * MB, False, 0)
    hold = gov.acquire(4 * MB)  # pool exhausted
    size, would_block, waiters = gov.admission_probe(2 * MB)
    assert size == 1 * MB and would_block and waiters == 0
    started = threading.Event()

    def blocked():
        started.set()
        with gov.acquire(2 * MB):
            pass

    th = threading.Thread(target=blocked)
    th.start()
    started.wait(5)
    time.sleep(0.05)  # let the thread park in admission control
    assert gov.admission_probe(2 * MB)[2] == 1  # one waiter visible
    hold.release()
    th.join(timeout=5)
    assert gov.admission_probe(2 * MB) == (2 * MB, False, 0)


def test_proportional_share_policy_weights_by_demand():
    """PG hash_mem_multiplier analogue: a squeezed request receives a
    demand-weighted share of the FREE pool (never below the floor, never
    the over-budget), instead of collapsing straight to the floor."""
    gov = MemoryGovernor(24 * MB, min_grant=2 * MB, policy="proportional")
    hold = gov.acquire(16 * MB)
    assert hold.size == 16 * MB  # fits: policy only shapes degraded grants
    # avail=8MB, demand=16MB, request 16MB with multiplier 2:
    #   share = 8 * 32 / (16 + 32) = 5.33 MB — between floor and leftover
    g = gov.acquire(16 * MB)
    assert 2 * MB < g.size < 8 * MB
    assert g.degraded
    assert gov.in_use <= 24 * MB
    # would_grant mirrors acquire's policy sizing (one grant outstanding
    # per probe, so the numbers match the just-issued grant's environment)
    g.release()
    assert gov.would_grant(16 * MB) == g.size
    # the weight IS the estimated hash-table size: of two requests that
    # both exceed the free pool, the hungrier one gets the bigger share
    assert 2 * MB <= gov.would_grant(10 * MB) < gov.would_grant(20 * MB)
    hold.release()


def test_proportional_share_never_exceeds_available():
    from repro.core import ProportionalShareGrantPolicy

    gov = MemoryGovernor(
        16 * MB, min_grant=1 * MB,
        policy=ProportionalShareGrantPolicy(hash_mem_multiplier=100.0))
    hold = gov.acquire(10 * MB)
    # an absurd multiplier wants everything; the central clamp caps the
    # grant at the free pool (the invariant lives in the governor, not
    # the policy)
    g = gov.acquire(16 * MB)
    assert g.size <= 6 * MB
    assert gov.in_use <= 16 * MB
    g.release(), hold.release()


def test_grant_policy_rejects_unknown_names():
    with pytest.raises(ValueError):
        MemoryGovernor(8 * MB, policy="fair-ish")


def test_constructor_validation():
    with pytest.raises(ValueError):
        MemoryGovernor(0)
    with pytest.raises(ValueError):
        MemoryGovernor(1 * MB, min_grant=2 * MB)


def test_concurrent_hammer_never_over_grants():
    """The hard invariant: under adversarial concurrency the sum of
    outstanding grants never exceeds the budget (peak high-water mark is
    tracked under the same lock that grants, so it cannot miss a spike)."""
    budget = 16 * MB
    gov = MemoryGovernor(budget, min_grant=1 * MB)
    stop = time.perf_counter() + 1.0
    errors = []

    def worker(seed: int):
        sizes = [3 * MB, 7 * MB, 1 * MB, 12 * MB, 5 * MB]
        i = seed
        try:
            while time.perf_counter() < stop:
                with gov.acquire(sizes[i % len(sizes)]) as g:
                    assert 0 < g.size <= sizes[i % len(sizes)]
                    time.sleep(0.001)
                i += 1
        except BaseException as e:  # pragma: no cover - diagnostic path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    assert not errors
    stats = gov.stats()
    assert stats.over_budget_events == 0
    assert 0 < stats.peak_in_use <= budget
    assert gov.in_use == 0
    assert stats.grants > 8  # the loop actually cycled
