"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; ``dryrun.py`` sets XLA_FLAGS *before* any jax
import to materialize 512 host placeholder devices.
"""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"importing jax (launch/dryrun.py does this)")
    return jax.sharding.Mesh(
        __import__("numpy").asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = data * model
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.sharding.Mesh(
        __import__("numpy").asarray(devices).reshape(data, model),
        ("data", "model"))
