"""SLO primitives for open-loop serving: tenant classes and arrival processes.

The closed-loop driver (``QueryServer.serve``) holds offered concurrency
constant — each worker submits its next query only when the previous one
completes, so the system can never be offered more load than it is
finishing.  Production traffic does not cooperate like that: clients arrive
on their own clock (open loop), load comes in bursts, and a backlog *grows*
when service slows instead of throttling itself.  The difference is the
classic coordinated-omission trap: a closed loop under-reports exactly the
overload tails an open loop exposes.

:class:`ArrivalProcess` generates that traffic: a homogeneous Poisson
stream at ``rate_qps`` by default, or a piecewise-constant-rate process
(``phases``) for bursty storms — each arrival is an independent logical
client, so a storm of thousands of arrivals models thousands of clients
without thousands of threads.  Seeded and fully reproducible: the same seed
replays the same arrival schedule (the seed-discipline satellite fig13
records in its summary).

:class:`TenantClass` is the admission-control contract a stream of arrivals
runs under: a **deadline** (the SLO budget a query is worth serving
within), a **priority** (higher drains first from the ready queue, and may
preempt floor-degraded linear operators mid-spill), and **sheddability**
(whether admission may reject the query outright when its quoted wait
already exceeds the deadline — serving it would burn capacity on a result
nobody can use, the classic load-shedding argument).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["TenantClass", "ArrivalProcess"]


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """One tenant's SLO contract.

    ``deadline_s`` — the end-to-end (arrival → completion) budget; admission
    sheds a sheddable query whose quoted wait already exceeds it, and a
    served query is SLO-violating when its sojourn runs past it.
    ``priority`` — higher drains first from the ready queue; a positive
    priority additionally triggers preemption of floor-degraded linear
    operators when this tenant's admission would otherwise block.
    ``sheddable`` — False marks traffic that must always run (the premium
    contract): admission never rejects it and a missed deadline is recorded
    on the served sample (``slo_ok=False``), never converted into a
    rejection.
    """

    name: str
    deadline_s: float
    priority: int = 0
    sheddable: bool = True

    def __post_init__(self):
        if self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be positive, got {self.deadline_s}")


class ArrivalProcess:
    """Seeded open-loop arrival-time generator.

    With only ``rate_qps``: a homogeneous Poisson process (exponential
    inter-arrivals).  With ``phases`` — a sequence of ``(duration_s,
    rate_qps)`` segments, cycled for as long as arrivals are drawn — a
    piecewise-constant-rate process: the canonical bursty-traffic model
    (e.g. ``[(4, 2), (3, 60), (5, 2)]`` = calm, storm, cool-down).  A
    segment rate of 0 is a silent gap.

    :meth:`times` draws the arrival offsets over ``[0, duration_s)`` —
    every draw with the same seed yields the same schedule.
    """

    def __init__(self, rate_qps: float = 1.0,
                 phases: Optional[Sequence[Tuple[float, float]]] = None,
                 seed: int = 0):
        if phases is not None:
            phases = [(float(d), float(r)) for d, r in phases]
            if not phases:
                raise ValueError("phases must be non-empty when given")
            for d, r in phases:
                if d <= 0:
                    raise ValueError(f"phase duration must be positive, got {d}")
                if r < 0:
                    raise ValueError(f"phase rate must be >= 0, got {r}")
        elif rate_qps < 0:
            raise ValueError(f"rate_qps must be >= 0, got {rate_qps}")
        self.rate_qps = float(rate_qps)
        self.phases = phases
        self.seed = int(seed)

    def times(self, duration_s: float, max_n: int = 1_000_000) -> np.ndarray:
        """Sorted arrival offsets in ``[0, duration_s)``; deterministic for
        a given seed.  ``max_n`` is a runaway guard (a mis-set rate cannot
        OOM the harness), raising rather than silently truncating."""
        rng = np.random.default_rng(self.seed)
        phases = (list(self.phases) if self.phases is not None
                  else [(float(duration_s) or 1.0, self.rate_qps)])
        out = []
        seg_start = 0.0
        i = 0
        while seg_start < duration_s:
            dur, rate = phases[i % len(phases)]
            i += 1
            seg_end = min(float(duration_s), seg_start + dur)
            if rate > 0:
                t = seg_start
                while True:
                    t += rng.exponential(1.0 / rate)
                    if t >= seg_end:
                        break
                    out.append(t)
                    if len(out) > max_n:
                        raise ValueError(
                            f"arrival process exceeded max_n={max_n} "
                            f"arrivals before t={t:.1f}s; check the rate")
            seg_start = seg_end
        return np.asarray(out, dtype=np.float64)
