"""Packed device column layouts: codec round-trips, layout choice, byte
accounting, and the shared-cache invalidation contract (PR 10)."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codec_device import (DICT_MAX_CARD, choose_layout,
                                     decode_device, decode_host, dict_bucket,
                                     encode_host, pad_dictionary)
from repro.core.relation import Relation
from repro.core.table_cache import (column_layout, device_cache_resident_bytes,
                                    get_device_layouts, pending_upload_bytes)


def _roundtrip(col):
    layout, aux = choose_layout(col)
    codes = encode_host(col, layout, aux)
    back = decode_host(codes, layout, aux)
    np.testing.assert_array_equal(back, col)
    assert back.dtype == col.dtype
    return layout, codes


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------

def test_for_roundtrip_dense_domain():
    col = np.arange(1000, 2000, dtype=np.int64)
    layout, codes = _roundtrip(col)
    assert layout.encoding == "for"
    assert layout.ref == 1000
    assert codes.dtype.itemsize < 8


def test_for_roundtrip_negative_values():
    rng = np.random.default_rng(0)
    col = rng.integers(-500, -100, 4096).astype(np.int64)
    layout, codes = _roundtrip(col)
    assert layout.encoding == "for"
    assert layout.ref == int(col.min())


def test_dict_roundtrip_low_cardinality():
    rng = np.random.default_rng(1)
    # wide sparse domain: FOR cannot narrow it, the dictionary can
    vals = rng.integers(0, 1 << 60, 100).astype(np.int64)
    col = rng.choice(vals, 50_000)
    layout, codes = _roundtrip(col)
    assert layout.encoding == "dict"
    assert layout.card == len(np.unique(col))
    assert codes.dtype.itemsize == 1  # <= 255 distinct values


def test_raw_when_incompressible():
    rng = np.random.default_rng(2)
    col = rng.integers(0, 1 << 40, 10_000).astype(np.int64)
    layout, aux = choose_layout(col)
    assert layout.encoding == "raw" and aux is None


def test_empty_column_stays_raw():
    col = np.zeros((0,), np.int64)
    layout, aux = choose_layout(col)
    assert layout.encoding == "raw"
    np.testing.assert_array_equal(encode_host(col, layout, aux), col)


def test_float_and_narrow_columns_stay_raw():
    assert choose_layout(np.ones(100, np.float64))[0].encoding == "raw"
    assert choose_layout(np.ones(100, np.int8))[0].encoding == "raw"


def test_max_width_span_keeps_raw():
    # span touches the int64 range AND cardinality is high: neither FOR
    # (no narrower dtype holds the span) nor dict (too many uniques) wins
    rng = np.random.default_rng(9)
    col = rng.integers(np.iinfo(np.int64).min + 1, np.iinfo(np.int64).max - 1,
                       100_000).astype(np.int64)
    assert choose_layout(col)[0].encoding == "raw"


def test_max_width_two_point_domain_dictionary_encodes():
    # the int64 extremes with only two distinct values: FOR is impossible
    # but a 2-entry dictionary still packs 8-byte values to 1-byte codes
    col = np.array([np.iinfo(np.int64).min + 1, np.iinfo(np.int64).max - 1]
                   * 50, dtype=np.int64)
    layout, codes = _roundtrip(col)
    assert layout.encoding == "dict" and layout.card == 2
    assert codes.dtype.itemsize == 1


def test_uint64_roundtrip():
    col = (np.arange(5000, dtype=np.uint64) + np.uint64(1 << 63))
    layout, codes = _roundtrip(col)
    assert layout.encoding == "for"
    assert layout.logical_dtype == "uint64"


def test_code_dtype_reserves_sentinel_slot():
    # span of exactly 255 must NOT choose uint8: the dtype max is reserved
    # for the join cores' dead/padding sentinel
    col = (np.arange(256, dtype=np.int64) % 256 + 10_000).repeat(4)
    layout, _ = _roundtrip(col)
    assert layout.encoding == "for"
    assert np.dtype(layout.code_dtype).itemsize > 1


def test_compress_toggle_disables_codecs():
    col = np.arange(1000, dtype=np.int64)
    os.environ["REPRO_DEVICE_COMPRESS"] = "0"
    try:
        assert choose_layout(col)[0].encoding == "raw"
    finally:
        os.environ.pop("REPRO_DEVICE_COMPRESS", None)
    assert choose_layout(col)[0].encoding == "for"


# ---------------------------------------------------------------------------
# dictionary padding + device decode
# ---------------------------------------------------------------------------

def test_pad_dictionary_preserves_searchsorted():
    d = np.array([3, 7, 11, 42], np.int64)
    padded = pad_dictionary(d, dict_bucket(len(d)))
    assert len(padded) == 16
    probes = np.array([3, 7, 11, 42, 5, 43, 100], np.int64)
    # first-occurrence rule survives the repeat-last padding
    np.testing.assert_array_equal(
        np.searchsorted(padded, probes[:4], side="left"),
        np.searchsorted(d, probes[:4], side="left"))
    # probes beyond every entry still land past the real codes
    assert np.searchsorted(padded, 43, side="left") >= len(d)


@pytest.mark.parametrize("seed", [0, 1])
def test_decode_device_matches_decode_host(seed):
    rng = np.random.default_rng(seed)
    for col in (rng.integers(-100, 100, 2048).astype(np.int64),
                rng.choice(rng.integers(0, 1 << 50, 30), 2048)):
        layout, aux = choose_layout(col)
        codes = encode_host(col, layout, aux)
        dev = decode_device(jnp.asarray(codes), layout.encoding,
                            layout.logical_dtype, ref=layout.ref,
                            dict_values=None if aux is None
                            else jnp.asarray(aux))
        np.testing.assert_array_equal(np.asarray(dev),
                                      decode_host(codes, layout, aux))


def test_upload_bytes_prices_padded_dictionary():
    rng = np.random.default_rng(3)
    col = rng.choice(rng.integers(0, 1 << 50, 100), 10_000)
    layout, _ = choose_layout(col)
    assert layout.encoding == "dict"
    expect = 10_000 * layout.code_itemsize + dict_bucket(layout.card) * 8
    assert layout.upload_bytes() == expect


def test_dict_max_cardinality_bound():
    assert DICT_MAX_CARD == 1 << 16
    assert dict_bucket(1) == 16
    assert dict_bucket(17) == 32


# ---------------------------------------------------------------------------
# table-cache integration: residency, pending bytes, invalidation
# ---------------------------------------------------------------------------

def _packed_rel(n=4096, seed=0):
    rng = np.random.default_rng(seed)
    return Relation({
        "k": np.arange(n, dtype=np.int64),
        "v": rng.integers(0, 100, n).astype(np.int64),
    })


def test_get_device_layouts_warm_is_free():
    rel = _packed_rel()
    cols, phys, logical = get_device_layouts(rel)
    assert phys > 0 and logical > phys  # packed < logical width
    for name in ("k", "v"):
        lay = cols[name]
        np.testing.assert_array_equal(np.asarray(lay.decode()), rel[name])
    _, phys2, log2 = get_device_layouts(rel)
    assert phys2 == 0 and log2 == 0
    assert pending_upload_bytes(rel) == 0


def test_pending_upload_bytes_prices_packed():
    rel = _packed_rel(seed=1)
    pend = pending_upload_bytes(rel)
    assert 0 < pend < rel.nbytes()  # packed: strictly below logical width
    _, phys, _ = get_device_layouts(rel)
    assert phys == pend  # the quote equals what the upload then moves


def test_invalidate_drops_layouts_with_device_columns():
    rel = _packed_rel(seed=2)
    lay0, _ = column_layout(rel, "v")
    get_device_layouts(rel)
    assert device_cache_resident_bytes(rel) > 0
    # mutate in place, then invalidate: EVERY cached device artifact —
    # raw columns, packed codes, dictionaries, layout descriptors — must go
    rel.columns["v"] = rel["v"] + 1000
    rel.invalidate_device_cache()
    assert device_cache_resident_bytes(rel) == 0
    lay1, _ = column_layout(rel, "v")
    assert lay1.ref == lay0.ref + 1000  # re-analyzed, not served stale
    cols, phys, _ = get_device_layouts(rel)
    assert phys > 0
    np.testing.assert_array_equal(np.asarray(cols["v"].decode()), rel["v"])


def test_select_shares_and_invalidation_covers_subrelation():
    rel = _packed_rel(seed=3)
    get_device_layouts(rel)
    sub = rel.select(["v"])
    # the select view shares the parent's caches: no second upload
    _, phys_sub, _ = get_device_layouts(sub)
    assert phys_sub == 0
    rel.columns["v"] *= 2  # in place: sub holds the SAME numpy object
    rel.invalidate_device_cache()
    # the shared cache was dropped for BOTH views; stale packed codes or
    # layout descriptors must not survive through the sub-relation
    cols, phys, _ = get_device_layouts(sub)
    assert phys > 0
    np.testing.assert_array_equal(np.asarray(cols["v"].decode()), rel["v"])
