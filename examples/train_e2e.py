"""End-to-end training driver.

Full pipeline: synthetic corpus → relational preprocessing (dedup + multi-key
packing order through the dual-path engine) → train steps with checkpointing
and resume.

Default is a CPU-sized run that finishes in ~2 minutes.  ``--hundred-m``
switches to a ~100M-parameter llama-family config for a few hundred steps —
the deliverable-scale driver (hours on CPU; sized for a single accelerator).

    PYTHONPATH=src python examples/train_e2e.py
    PYTHONPATH=src python examples/train_e2e.py --hundred-m --steps 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.models import init_model
from repro.train.checkpoint import Checkpointer, latest_step, restore_checkpoint
from repro.train.optimizer import make_optimizer
from repro.train.trainer import TrainPolicy, make_train_step

LM_100M = ArchConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=768,
    vocab_size=32_000, num_heads=12, num_kv_heads=4, head_dim=64,
    d_ff=2048, rope_theta=10_000.0,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = LM_100M if args.hundred_m else get_smoke_config("yi-9b")
    if args.hundred_m:
        args.seq_len = max(args.seq_len, 512)
    print(f"config={cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    opt = make_optimizer("adamw", lr=3e-4)
    step_fn = jax.jit(make_train_step(cfg, opt, TrainPolicy(remat=False)))
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)

    ckpt = Checkpointer(args.ckpt_dir, interval=20)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from checkpoint at step {start}")

    pipe = DataPipeline(PipelineConfig(
        num_docs=8000, vocab=cfg.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch, policy="auto"))
    pipe.restore({"consumed": start, "seed": 0})
    it = iter(pipe)

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = next(it)
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            tok_s = (step - start + 1) * args.batch * args.seq_len / (time.time() - t0)
            print(f"step {step:4d} loss {losses[-1]:.4f} ({tok_s:.0f} tok/s)")
        ckpt.maybe_save(step + 1, (params, opt_state))
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}) — checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
