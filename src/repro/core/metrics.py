"""Execution metrics: latency percentiles, spill accounting, working-set peaks.

The paper evaluates three families of metrics together (abstract, §V):
  * latency distribution — P50 *and* P99 (+max), because the phenomenon under
    study is predictability loss, not mean slowdown;
  * physical I/O — Temp_MB and 8 KB block counts (PostgreSQL-style);
  * peak working set of the linearized intermediate (hash table / sort runs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

BLOCK_BYTES = 8192  # PostgreSQL temp-file block size; paper reports 25,662 blocks ≈ 200 MB

__all__ = ["BLOCK_BYTES", "SpillAccount", "OpMetrics", "LatencyStats", "latency_stats", "Timer"]


@dataclasses.dataclass
class SpillAccount:
    """Temp-file I/O accounting for one operator execution."""

    bytes_written: int = 0
    bytes_read: int = 0
    # Bytes of temp space released back (partition/run deletion).  Live
    # occupancy — what tier capacity enforcement actually cares about — is
    # ``live_bytes = written - freed``; ``bytes_written`` alone only ever
    # grows and overstates footprint by the whole recursion history.
    bytes_freed: int = 0
    files_created: int = 0
    partition_passes: int = 0  # recursive partitioning / merge passes
    # High-water mark of live temp occupancy, maintained by write()/free().
    peak_live_bytes: int = 0

    def write(self, nbytes: int) -> None:
        self.bytes_written += int(nbytes)
        if self.live_bytes > self.peak_live_bytes:
            self.peak_live_bytes = self.live_bytes

    def read(self, nbytes: int) -> None:
        self.bytes_read += int(nbytes)

    def free(self, nbytes: int) -> None:
        self.bytes_freed += int(nbytes)

    @property
    def live_bytes(self) -> int:
        """Temp bytes written and not yet deleted (true current occupancy)."""
        return max(0, self.bytes_written - self.bytes_freed)

    @property
    def temp_bytes(self) -> int:
        return self.bytes_written

    @property
    def temp_mb(self) -> float:
        return self.bytes_written / 1e6

    @property
    def blocks(self) -> int:
        return -(-self.bytes_written // BLOCK_BYTES)

    def merge(self, other: "SpillAccount") -> None:
        self.bytes_written += other.bytes_written
        self.bytes_read += other.bytes_read
        self.bytes_freed += other.bytes_freed
        self.files_created += other.files_created
        self.partition_passes = max(self.partition_passes, other.partition_passes)
        # conservative: peaks of sequential operators never overlapped, so
        # the merged peak is the max, not the sum
        self.peak_live_bytes = max(self.peak_live_bytes, other.peak_live_bytes)


@dataclasses.dataclass
class OpMetrics:
    """Metrics for a single operator execution."""

    op: str
    path: str  # "linear" | "tensor"
    rows_in: int
    rows_out: int
    wall_s: float
    spill: SpillAccount
    peak_working_set_bytes: int = 0
    decision_reason: str = ""
    # Device→host synchronization events for this operator (a transfer of
    # results or a blocking scalar read such as a match count).  The linear
    # path is host-native and reports 0; the per-operator tensor path pays
    # 1-2 per operator; the fused device-resident path pays 1 per *query*.
    host_syncs: int = 0
    # Host→device bytes actually transferred for this operator's inputs —
    # PHYSICAL bytes: with packed device layouts (core/codec_device) this is
    # the codes + dictionaries that really crossed the bus, not the logical
    # column width.  Warm queries over device-cached base tables report 0 —
    # the serving-path contract the fig9 benchmark measures (and packed
    # residency keeps satisfying: a resident column in either form is warm).
    h2d_bytes: int = 0
    # The same transfers priced at LOGICAL column width — what the upload
    # would have cost without packed layouts.  physical/logical is the
    # per-operator compression ratio fig17 reports; 0 when nothing moved.
    h2d_bytes_logical: int = 0
    # Memory grant this linear operator ran under (0 when ungoverned or on
    # the tensor path).  Under a shared MemoryGovernor this is the budget
    # slice actually received — smaller than the configured work_mem when
    # concurrent queries contend, which is what pushes the operator into
    # the spill regime fig11 measures.  ``grant_degraded`` marks a grant
    # smaller than its request: the operator's wall then reflects
    # contention-induced spilling, not the operator's full-memory cost,
    # and is excluded from runtime-profile feedback (load is admission's
    # problem; the profile models cost).
    grant_bytes: int = 0
    grant_degraded: bool = False
    # Seconds this operator spent queued for its device lease (concurrent
    # serving: device dispatch is admitted through the broker's DeviceQueue;
    # the fused pipeline AND the per-operator tensor path both hold a lease).
    # Included in wall_s — it IS end-to-end latency — but excluded from the
    # runtime-profile feedback, which models execution cost, not load.
    queue_wait_s: float = 0.0
    # Seconds this linear operator spent blocked in memory admission control
    # before its grant was issued (0 when ungoverned or on the tensor path).
    # NOT part of wall_s: the operator's timer starts after admission, so
    # admission wait never pollutes runtime-profile feedback; end-to-end
    # latency including it is the serving layer's per-query timer.
    mem_wait_s: float = 0.0
    # True when this operator's device dispatch was admitted as part of a
    # coalesced (micro-batched) lease group — several queued dispatches of
    # the same compiled shape ran as one admission round instead of
    # serially.  Scheduling only; results are bit-for-bit identical.
    batched: bool = False
    # True when this operator's run may have paid jit compilation (a fused
    # program cache miss, including a hit on a not-yet-ready entry).  The
    # executor's warm-feedback gate keys off THIS, not a global counter
    # delta — another thread's concurrent compile must not make a warm run
    # look cold.
    compiled: bool = False
    # True when this operator started on a floor-degraded LINEAR grant, was
    # preempted mid-spill by the broker, and re-ran (successfully) on the
    # tensor path — the metrics describe the tensor run that produced the
    # result; this flag records that a preemption paid for it.
    preempted: bool = False
    # Mesh devices this operator's dispatch spanned: 1 for the linear path
    # and the single-device tensor path, N for a partition-parallel fused
    # fragment (one broker lane per device; queue_wait_s then accumulates
    # the gang acquisition's blocked time across lanes).
    devices: int = 1
    # True when an ExecutionGuard abandoned this operator's first path
    # mid-query and the tensor path finished it (a SwitchPoint, distinct
    # from broker preemption: the operator itself decided its decision was
    # mispriced).  ``path`` then names the path that produced the result;
    # the abandoned attempt is described by the pre_switch_* fields.
    switched: bool = False
    # Wall seconds the abandoned pre-switch (or pre-preemption) attempt
    # burned before the switch point.  Included in wall_s so end-to-end
    # query accounting stays honest, but attributed to pre_switch_path —
    # never to the final path's runtime-profile cell.
    pre_switch_wall_s: float = 0.0
    pre_switch_path: str = ""
    # Logical bytes of already-spilled partitions the switch completion
    # read back through the spill/tier manager instead of rebuilding from
    # the base relations (the loss-free reuse the guard contract promises;
    # also counted in spill.bytes_read, so books stay balanced).
    reused_spill_bytes: int = 0

    @property
    def h2d_bytes_physical(self) -> int:
        """Alias for :attr:`h2d_bytes` — the bytes that really moved."""
        return self.h2d_bytes

    def as_row(self) -> Dict[str, object]:
        return {
            "op": self.op,
            "path": self.path,
            "rows_in": self.rows_in,
            "rows_out": self.rows_out,
            "wall_s": round(self.wall_s, 6),
            "temp_mb": round(self.spill.temp_mb, 3),
            "temp_blocks": self.spill.blocks,
            # leftover live temp space after the operator finished — nonzero
            # means a partition/run file leaked past its pass
            "temp_live_mb": round(self.spill.live_bytes / 1e6, 3),
            "temp_peak_live_mb": round(self.spill.peak_live_bytes / 1e6, 3),
            "passes": self.spill.partition_passes,
            "peak_ws_mb": round(self.peak_working_set_bytes / 1e6, 3),
            "host_syncs": self.host_syncs,
            "h2d_mb": round(self.h2d_bytes / 1e6, 3),
            "h2d_logical_mb": round(self.h2d_bytes_logical / 1e6, 3),
            "grant_mb": round(self.grant_bytes / 1e6, 3),
            "devices": self.devices,
            "switched": self.switched,
            "reason": self.decision_reason,
        }


@dataclasses.dataclass
class LatencyStats:
    p50: float
    p95: float
    p99: float
    max: float
    mean: float
    n: int

    def as_row(self) -> Dict[str, float]:
        return {
            "p50_s": round(self.p50, 6),
            "p95_s": round(self.p95, 6),
            "p99_s": round(self.p99, 6),
            "max_s": round(self.max, 6),
            "mean_s": round(self.mean, 6),
            "n": self.n,
        }


def latency_stats(samples_s: List[float]) -> LatencyStats:
    a = np.asarray(samples_s, dtype=np.float64)
    return LatencyStats(
        p50=float(np.percentile(a, 50)),
        p95=float(np.percentile(a, 95)),
        p99=float(np.percentile(a, 99)),
        max=float(a.max()),
        mean=float(a.mean()),
        n=len(a),
    )


class Timer:
    """Wall-clock context manager."""

    def __enter__(self) -> "Timer":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self.t0
