"""Shared test environment: a deterministic 8-device CPU mesh.

The sharded fused path (``run_fused(shards=N)``), the distributed e2e
test, and the broker's per-lane accounting all need more than one XLA
device.  On CPU that is spelled ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` — and it only works when set **before jax's first
import**, which is why it lives here (pytest imports ``conftest.py``
before any test module) instead of ad hoc inside individual tests.

CI sets the same flag as a job-level env var (see
``.github/workflows/ci.yml``); this module is the belt to that suspender
for local runs.  An explicit user-provided device-count flag is always
respected, and if jax was somehow imported first (e.g. by a pytest
plugin) the flag is left untouched — tests that need the mesh then skip
via the :func:`eight_device_mesh` fixture instead of silently running
against a stale device topology.
"""
import os
import sys

_FORCE_FLAG = "--xla_force_host_platform_device_count=8"

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = f"{_flags} {_FORCE_FLAG}".strip()

import pytest


@pytest.fixture
def eight_device_mesh():
    """The 8 forced host devices, or skip when the topology is unavailable
    (jax imported before the flag could be set)."""
    import jax

    if jax.device_count() < 8:
        pytest.skip("needs the forced 8-device CPU mesh "
                    "(jax was imported before XLA_FLAGS took effect)")
    return jax.devices()[:8]
