"""Runtime feedback profile (PR 2): observed wall times blended into the
selector's predictions so the crossover point self-corrects on any host."""
import numpy as np

from repro.core import (
    Aggregate,
    Executor,
    Join,
    PathSelector,
    Relation,
    RuntimeProfile,
    Scan,
    Sort,
    match_fragment,
    size_bucket,
)


def _tables(n, seed=0):
    rng = np.random.default_rng(seed)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 99, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 99, n).astype(np.int64)})
    return build, probe


def test_blend_cold_returns_prediction_exactly():
    prof = RuntimeProfile()
    assert prof.blend(0.25, "hash_join", "linear", 1000) == 0.25


def test_blend_converges_to_observation():
    prof = RuntimeProfile(confidence=2)
    for _ in range(20):
        prof.record("hash_join", "linear", 1000, 2.0)
    blended = prof.blend(0.01, "hash_join", "linear", 1000)
    assert abs(blended - 2.0) < 0.2  # w = 20/22 pulls ~91% of the way
    one = RuntimeProfile(confidence=2)
    one.record("hash_join", "linear", 1000, 2.0)
    partial = one.blend(0.01, "hash_join", "linear", 1000)
    assert 0.01 < partial < blended  # confidence weighting is gradual


def test_ewma_recovers_from_outlier():
    prof = RuntimeProfile(alpha=0.35)
    prof.record("sort", "tensor", 5000, 10.0)  # a one-off stall
    for _ in range(12):
        prof.record("sort", "tensor", 5000, 0.1)
    cell = prof.observed("sort", "tensor", 5000)
    assert cell.wall_s < 0.2  # the stall washed out


def test_size_buckets_isolate_scales():
    prof = RuntimeProfile()
    prof.record("hash_join", "linear", 1000, 1.0)
    assert prof.observed("hash_join", "linear", 1_000_000) is None
    assert size_bucket(1000) != size_bucket(1_000_000)
    # rows inside one octave share a cell
    assert size_bucket(1025) == size_bucket(2047)


def test_feedback_flips_fragment_decision():
    """The regret-correction mechanism: a path observed to be much slower
    than predicted loses the blended comparison, without recalibration.
    Constants are pinned so the cold prediction unambiguously favors linear
    — the flip must come from the observations alone."""
    from repro.core import CostConstants, CostModel

    build, probe = _tables(20_000)
    plan = Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"])
    spec, b, p = match_fragment(plan)
    prof = RuntimeProfile()
    model = CostModel(CostConstants(linear_row_cost=1e-9))  # "linear is free"
    sel = PathSelector(work_mem=1 << 30, cost_model=model, profile=prof)
    assert sel.choose_fragment(spec, b, p).path == "linear"
    for _ in range(6):  # observe the linear fragment stalling badly
        prof.record("fragment", "linear", len(b) + len(p), 30.0)
    assert sel.choose_fragment(spec, b, p).path == "tensor"


def test_warmup_discard_drops_only_first_sample():
    """Per-op tensor path: the first sample may hide a jit compile the
    caller cannot detect; it must not enter the blend."""
    prof = RuntimeProfile()
    prof.record("hash_join", "tensor", 1000, 5.0, warmup_discard=True)
    cell = prof.observed("hash_join", "tensor", 1000)
    assert cell is not None and cell.count == 0 and cell.warmups_seen == 1
    assert prof.blend(0.01, "hash_join", "tensor", 1000) == 0.01
    prof.record("hash_join", "tensor", 1000, 0.2, warmup_discard=True)
    cell = prof.observed("hash_join", "tensor", 1000)
    assert cell.count == 1 and cell.wall_s == 0.2  # second sample sticks


def test_executor_records_observations():
    build, probe = _tables(3000, seed=3)
    prof = RuntimeProfile()
    sel = PathSelector(work_mem=1 << 30, force="linear", profile=prof)
    ex = Executor(work_mem=1 << 30, policy="linear", selector=sel)
    ex.execute(Aggregate(Sort(Join(Scan(build), Scan(probe), "k"), ["k"]),
                         "b_v", "sum"))
    n = len(build) + len(probe)
    assert prof.observed("hash_join", "linear", n) is not None
    assert prof.observed("fragment", "linear", n) is not None


def test_fused_compile_run_not_recorded_as_steady_state():
    """The first fused execution compiles; its wall must NOT enter the
    profile (it would flip the very next decision back to linear)."""
    from repro.core import pipeline_cache_clear

    pipeline_cache_clear()
    build, probe = _tables(4096, seed=5)
    prof = RuntimeProfile()
    sel = PathSelector(work_mem=1 << 10, profile=prof)  # tiny mem → tensor
    ex = Executor(work_mem=1 << 10, policy="auto", selector=sel)
    plan = lambda: Aggregate(Sort(Join(Scan(build), Scan(probe), "k"), ["k"]),
                             "b_v", "sum")
    q1 = ex.execute(plan())
    assert q1.metrics[0].op == "fused_pipeline"
    assert prof.observed("fragment", "tensor", len(build) + len(probe)) is None
    q2 = ex.execute(plan())  # warm: this one is a real observation
    assert q2.metrics[0].op == "fused_pipeline"
    cell = prof.observed("fragment", "tensor", len(build) + len(probe))
    assert cell is not None and cell.count == 1
