"""SSD chunked scan vs. the sequential-recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_ref, ssd_scan, ssd_step


def _inputs(key, b, s, h, p, g, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
@pytest.mark.parametrize("b,s,h,p,g,n", [
    (2, 32, 4, 8, 1, 16),
    (1, 32, 4, 8, 2, 8),   # grouped B/C
])
def test_ssd_scan_matches_sequential(chunk, b, s, h, p, g, n):
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(0), b, s, h, p, g, n)
    y_ref, st_ref = ssd_ref(x, dt, A, B, C)
    y, st = ssd_scan(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_carries():
    b, s, h, p, g, n = 1, 16, 2, 4, 1, 8
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(1), b, s, h, p, g, n)
    # split the sequence: scan(first half) state feeds second half
    y_full, st_full = ssd_scan(x, dt, A, B, C, chunk=8)
    y1, st1 = ssd_scan(x[:, :8], dt[:, :8], A, B[:, :8], C[:, :8], chunk=8)
    y2, st2 = ssd_scan(x[:, 8:], dt[:, 8:], A, B[:, 8:], C[:, 8:], chunk=8,
                       init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=2e-4, atol=2e-4)


def test_ssd_step_matches_scan_tail():
    b, s, h, p, g, n = 2, 9, 2, 4, 1, 8
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(2), b, s, h, p, g, n)
    _, st_prev = ssd_scan(x[:, :8], dt[:, :8], A, B[:, :8], C[:, :8], chunk=8)
    y_step, st_step = ssd_step(x[:, 8], dt[:, 8], A, B[:, 8], C[:, 8], st_prev)
    y_ref, st_ref = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_ref[:, 8].reshape(b, h, p)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_step), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)
