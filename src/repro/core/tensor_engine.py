"""The TENSOR execution path (the paper's contribution, §III–IV), in JAX.

Dimension preservation on TPU-class hardware means *static-shape, axis-
explicit* programs instead of pointer-chasing linearized intermediates:

  * ``tensor_join`` — equi-join as **sorted coordinate alignment**: the join
    key stays an explicit coordinate axis; build rows are ordered along it
    (``argsort``), probe coordinates are aligned with ``searchsorted`` and
    match ranges expanded by segment arithmetic into a *statically sized*
    index space (capacity + validity mask).  No hash table is materialized;
    memory traffic is deterministic O(N log N) — this is what keeps the path
    out of the spill-amplification regime (§VI: T_tensor(N) ≈ O(N)).

  * ``tensor_join_aggregate`` — the strongest form of delayed materialization:
    for join-then-aggregate queries the join output is **never produced**;
    both relations are segment-reduced along the shared key axis and the
    aggregate is a contraction (einsum) over that axis.

  * ``tensor_sort`` — multi-key sort performed *step-wise along key axes*
    (stable LSD passes), exactly §IV.B: the key combination is "not
    immediately reduced to linear comparison operations but sorted
    step-by-step within the multidimensional structure".

Device residency (this layer's contract): join capacity is computed *on
device* by the same sort+searchsorted the join itself uses — there is no
separate host planning sort — and the only device→host traffic a per-operator
call pays is one scalar match count plus one batched result fetch.  The
``*_device`` variants take and return :class:`DeviceRelation` and pay *zero*
syncs (or one scalar when a join must discover its capacity), deferring all
materialization to the query root.  Capacities are padded to powers of two so
repeated queries hit the jit compile cache instead of recompiling.

All entry points are jit-compiled with static capacities, so the compiled
program's working set is known at compile time — the tensor path cannot
"discover" at runtime that it must spill.
"""
from __future__ import annotations

import math
import os
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Relational payloads are 64-bit (SQL bigint); the tensor path must preserve
# them exactly.  Model code elsewhere in the framework always passes explicit
# dtypes, so enabling x64 here is safe for the LM substrate.
jax.config.update("jax_enable_x64", True)

from .device_relation import DeviceColumn, DeviceRelation
from .metrics import OpMetrics, SpillAccount, Timer
from .relation import Relation

__all__ = [
    "tensor_join",
    "tensor_join_aggregate",
    "tensor_sort",
    "tensor_join_device",
    "tensor_sort_device",
    "join_capacity",
    "aligned_join_indices",
    "capacity_bucket",
    "sort_perm_device",
    "use_pallas",
    "segment_sum_dispatch",
    "radix_hash_probe_dispatch",
]

# Distinct sentinels so masked-out build rows can never meet masked-out probe
# rows at the same key value.  Relations whose key domain includes these two
# extreme int64 values are not supported by the masked device join (documented
# contract; SQL bigint workloads never reach them).
_BUILD_DEAD_KEY = -(2**62) - 11
_PROBE_DEAD_KEY = -(2**62) - 22


def _next_pow2(n: int) -> int:
    return 1 << max(4, int(math.ceil(math.log2(max(1, n)))))


def capacity_bucket(n: int) -> int:
    """Power-of-two shape bucket: the static capacity handed to jit.

    Bucketing means nearby match counts land on the same compiled program —
    the compile cache is keyed on (capacity, dtypes, num_keys), not on the
    exact data-dependent count.
    """
    return _next_pow2(max(1, n))


# ---------------------------------------------------------------------------
# Pallas kernel dispatch (interpret-mode fallback on CPU)
# ---------------------------------------------------------------------------

def use_pallas(num_segments: Optional[int] = None) -> bool:
    """Should the engine route segment/sort inner loops to Pallas kernels?

    ``REPRO_PALLAS=1`` forces the kernels on (interpret mode off-TPU),
    ``REPRO_PALLAS=0`` forces pure jnp, and the default ``auto`` uses the
    kernels on TPU backends only — interpret mode is a correctness fallback,
    not a fast path.  The one-hot segment-sum kernel is additionally gated to
    modest segment counts (its accumulator tile is [tblk, num_segments]).
    """
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env == "0":
        return False
    if num_segments is not None and num_segments > 4096:
        return False
    if env == "1":
        return True
    return jax.default_backend() == "tpu"


def segment_sum_dispatch(values: jnp.ndarray, seg_ids: jnp.ndarray,
                         num_segments: int, use_kernel: bool) -> jnp.ndarray:
    """Segment sum via the Pallas kernel when requested, else pure jnp.

    ``use_kernel`` is resolved by the caller *outside* any jit trace (via
    :func:`use_pallas`) so the env-var toggle is honored per call, not frozen
    into a compiled program.
    """
    if use_kernel:
        from ..kernels.segment_join.ops import segment_sum as _pallas_segsum
        return _pallas_segsum(seg_ids, values, num_segments).astype(values.dtype)
    return jax.ops.segment_sum(values, seg_ids, num_segments=num_segments)


def radix_hash_probe_dispatch(bk_codes, pk_codes, domain: int,
                              use_kernel: bool):
    """Dense-domain hash-probe core: Pallas radix join or pure-jnp scatter.

    Both paths share one contract (and are parity-tested bit-for-bit):
    codes lie in ``[0, domain]`` with slot ``domain`` as the dead/padding
    slot; the result is ``(cnt_p, build_row, has_dup)`` — per probe row
    the number of matching build rows and the largest matching build-row
    id (−1 on miss), plus whether any live slot collides (the caller's
    retry-to-sorted-core signal).  ``use_kernel`` is resolved outside jit
    traces via :func:`use_pallas`, exactly like the segment-sum dispatch.
    """
    if use_kernel:
        from ..kernels.segment_join.ops import radix_hash_probe
        return radix_hash_probe(bk_codes.astype(jnp.int32),
                                pk_codes.astype(jnp.int32), domain)
    nb = bk_codes.shape[0]
    cnt = jnp.zeros((domain + 1,), jnp.int32).at[bk_codes].add(1)
    inv = jnp.zeros((domain + 1,), jnp.int32).at[bk_codes].max(
        jnp.arange(1, nb + 1, dtype=jnp.int32))
    cnt_p = jnp.take(cnt, pk_codes)
    build_row = jnp.take(inv, pk_codes) - 1
    has_dup = jnp.max(cnt[:domain]) > 1
    return cnt_p, build_row, has_dup


# ---------------------------------------------------------------------------
# Join: sorted coordinate alignment
# ---------------------------------------------------------------------------

def _join_plan_impl(build_keys, probe_keys):
    """Shared device planning stage: ONE sort + searchsorted produces both the
    exact match count (the capacity signal) and the alignment arrays the join
    expansion reuses — the seed's duplicate host-side planning sort is gone."""
    order = jnp.argsort(build_keys, stable=True)
    sorted_keys = jnp.take(build_keys, order)
    left = jnp.searchsorted(sorted_keys, probe_keys, side="left")
    right = jnp.searchsorted(sorted_keys, probe_keys, side="right")
    counts = right - left
    ends = jnp.cumsum(counts)
    starts = ends - counts
    if counts.shape[0]:
        total = ends[-1]
    else:
        total = jnp.asarray(0, ends.dtype)
    return order, left, starts, ends, total


_join_plan = jax.jit(_join_plan_impl)


def _expand_join_impl(order, left, starts, ends, capacity: int):
    n_build = order.shape[0]
    n_probe = ends.shape[0]
    slot = jnp.arange(capacity, dtype=ends.dtype)
    # which probe row does output slot s belong to?
    probe_idx = jnp.searchsorted(ends, slot, side="right")
    probe_idx_c = jnp.minimum(probe_idx, max(n_probe - 1, 0))
    offset = slot - starts[probe_idx_c]
    build_pos = left[probe_idx_c] + offset
    build_idx = jnp.take(order, jnp.clip(build_pos, 0, max(n_build - 1, 0)))
    total = ends[-1] if n_probe else jnp.asarray(0, ends.dtype)
    valid = slot < total
    return build_idx, probe_idx_c, valid


_expand_join = jax.jit(_expand_join_impl, static_argnames=("capacity",))


@partial(jax.jit, static_argnames=("capacity",))
def aligned_join_indices(
    build_keys: jnp.ndarray, probe_keys: jnp.ndarray, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Core dimension-preserving equi-join.

    Returns ``(build_idx, probe_idx, valid, total)`` where the first two are
    ``capacity``-sized gather indices into the original relations, ``valid``
    masks real matches, and ``total`` is the exact match count (callers can
    detect capacity overflow as ``total > capacity``).
    """
    order, left, starts, ends, total = _join_plan_impl(build_keys, probe_keys)
    build_idx, probe_idx, valid = _expand_join_impl(order, left, starts, ends,
                                                    capacity)
    return build_idx, probe_idx, valid, total


def join_capacity(build_keys, probe_keys) -> int:
    """Exact match count, computed ON DEVICE by the join's own planning stage.

    This models the "expected intermediate result size" signal the paper's
    execution-time selector observes (§III.C).  The seed ran a duplicate
    host-side O(N log N) sort here; now the one device sort is shared with
    the join itself and only the scalar count crosses to the host.
    """
    bk = jnp.asarray(build_keys)
    pk = jnp.asarray(probe_keys)
    if bk.shape[0] == 0 or pk.shape[0] == 0:
        return 0
    *_, total = _join_plan(bk, pk)
    return int(total)


def tensor_join(
    build: Relation,
    probe: Relation,
    key: str,
    capacity: Optional[int] = None,
) -> Tuple[Relation, OpMetrics]:
    """Tensor-path equi-join producing the same schema as the linear path.

    Host-Relation convenience API: internally runs the device-resident join
    and pays exactly two host syncs — the scalar match count (capacity
    discovery + overflow check) and one batched result fetch.  The seed paid
    a full host planning sort plus one transfer per payload column.
    """
    bk = np.asarray(build[key], dtype=np.int64)
    pk = np.asarray(probe[key], dtype=np.int64)
    if len(bk) == 0 or len(pk) == 0:
        out = {name: col[:0] for name, col in probe.columns.items()}
        out.update({f"b_{n}": c[:0] for n, c in build.columns.items() if n != key})
        return Relation(out), OpMetrics(
            op="hash_join", path="tensor", rows_in=len(build) + len(probe),
            rows_out=0, wall_s=0.0, spill=SpillAccount())
    with Timer() as t:
        order, left, starts, ends, total = _join_plan(jnp.asarray(bk),
                                                      jnp.asarray(pk))
        n = int(total)  # host sync #1: one scalar, no data
        if capacity is None:
            capacity = capacity_bucket(n)
        elif n > capacity:
            raise ValueError(f"capacity {capacity} < exact match count {n}")
        build_idx, probe_idx, _valid = _expand_join(order, left, starts, ends,
                                                    capacity)
        b_idx = build_idx[:n]
        p_idx = probe_idx[:n]
        # Late materialization: gather payload columns ON DEVICE, only valid
        # rows, then fetch everything in one batched transfer.
        out_dev: Dict[str, jnp.ndarray] = {}
        for name, col in probe.columns.items():
            out_dev[name] = jnp.take(jnp.asarray(col), p_idx)
        for name, col in build.columns.items():
            if name == key:
                continue
            out_dev[f"b_{name}"] = jnp.take(jnp.asarray(col), b_idx)
        if not out_dev:
            out_dev[key] = jnp.take(jnp.asarray(probe[key]), p_idx)
        fetched = jax.device_get(out_dev)  # host sync #2: the result
        result = Relation({k: np.asarray(v) for k, v in fetched.items()})
    peak = (
        bk.nbytes * 3  # keys + order + sorted copy
        + pk.nbytes * 3  # searchsorted operands
        + capacity * 8 * 3  # index space
    )
    metrics = OpMetrics(
        op="hash_join",
        path="tensor",
        rows_in=len(build) + len(probe),
        rows_out=len(result),
        wall_s=t.elapsed,
        spill=SpillAccount(),  # structurally zero: no spill regime exists
        peak_working_set_bytes=peak,
        host_syncs=2,
        # materializing host API: every input column crosses to the device
        # per call (the cached executor paths report 0 when warm)
        h2d_bytes=build.nbytes() + probe.nbytes(),
    )
    return result, metrics


def tensor_join_device(
    build: DeviceRelation,
    probe: DeviceRelation,
    key: str,
    capacity: Optional[int] = None,
) -> Tuple[DeviceRelation, OpMetrics]:
    """Device-resident equi-join: payload columns never move.

    The output :class:`DeviceRelation` carries *gather indices* into the
    input relations' base columns (late materialization) plus a validity
    mask over the capacity-padded index space.  Host traffic: one scalar
    match count when ``capacity`` must be discovered, otherwise zero.
    """
    if build.num_physical_rows == 0 or probe.num_physical_rows == 0:
        cols = {name: c.take_lazy(jnp.zeros((0,), jnp.int64))
                for name, c in probe.columns.items()}
        cols.update({f"b_{name}": c.take_lazy(jnp.zeros((0,), jnp.int64))
                     for name, c in build.columns.items() if name != key})
        if not cols:
            cols[key] = probe.columns[key].take_lazy(jnp.zeros((0,), jnp.int64))
        return DeviceRelation(cols), OpMetrics(
            op="hash_join", path="tensor",
            rows_in=len(build) + len(probe), rows_out=0, wall_s=0.0,
            spill=SpillAccount())
    bk = build.col(key).astype(jnp.int64)
    pk = probe.col(key).astype(jnp.int64)
    # masked-out input rows must never match: move them to dead key values
    if build.valid is not None:
        bk = jnp.where(build.valid, bk, _BUILD_DEAD_KEY)
    if probe.valid is not None:
        pk = jnp.where(probe.valid, pk, _PROBE_DEAD_KEY)
    with Timer() as t:
        order, left, starts, ends, total = _join_plan(bk, pk)
        # scalar sync: the capacity / overflow signal.  Even with an explicit
        # capacity the count must be verified — silently truncating the join
        # would corrupt results (the fused pipeline instead piggybacks this
        # check on its single result fetch).
        n = int(total)
        syncs = 1
        if capacity is None:
            capacity = capacity_bucket(n)
        elif n > capacity:
            raise ValueError(f"capacity {capacity} < exact match count {n}")
        build_idx, probe_idx, valid = _expand_join(order, left, starts, ends,
                                                   capacity)
        cols: Dict[str, DeviceColumn] = {}
        for name, c in probe.columns.items():
            cols[name] = c.take_lazy(probe_idx)
        for name, c in build.columns.items():
            if name == key:
                continue
            cols[f"b_{name}"] = c.take_lazy(build_idx)
        if not cols:
            cols[key] = probe.columns[key].take_lazy(probe_idx)
        out = DeviceRelation(cols, valid=valid)
    metrics = OpMetrics(
        op="hash_join",
        path="tensor",
        rows_in=len(build) + len(probe),
        rows_out=capacity,  # physical (padded) rows; logical count is masked
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=bk.nbytes * 3 + pk.nbytes * 3 + capacity * 8 * 3,
        host_syncs=syncs,
    )
    return out, metrics


# ---------------------------------------------------------------------------
# Fused join + aggregate (join output never materialized)
# ---------------------------------------------------------------------------

# Both relations' values are contracted at ONE explicit dtype.  With x64
# enabled (module policy above) that is float64; the seed promoted build
# values to f64 while always truncating probe values to f32, which made
# Σ(b·p) silently lose probe precision.
_AGG_DTYPE = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


@partial(jax.jit, static_argnames=("num_segments", "use_kernel"))
def _join_aggregate(
    build_keys, build_vals, probe_keys, probe_vals, num_segments: int,
    use_kernel: bool = False
):
    seg_b = segment_sum_dispatch(build_vals, build_keys, num_segments, use_kernel)
    cnt_b = segment_sum_dispatch(
        jnp.ones_like(build_vals), build_keys, num_segments, use_kernel)
    seg_p = segment_sum_dispatch(probe_vals, probe_keys, num_segments, use_kernel)
    cnt_p = segment_sum_dispatch(
        jnp.ones_like(probe_vals), probe_keys, num_segments, use_kernel)
    # SUM over join pairs of (b_val + p_val) decomposes along the key axis:
    #   sum_k [ cnt_p[k]*seg_b[k] + cnt_b[k]*seg_p[k] ]
    # and SUM of products contracts directly:  sum_k seg_b[k]*seg_p[k].
    sum_pairs = jnp.dot(cnt_b, cnt_p)
    sum_add = jnp.dot(seg_b, cnt_p) + jnp.dot(cnt_b, seg_p)
    sum_prod = jnp.dot(seg_b, seg_p)
    return sum_pairs, sum_add, sum_prod


def tensor_join_aggregate(
    build: Relation,
    probe: Relation,
    key: str,
    build_val: str,
    probe_val: str,
    key_domain: int,
) -> Tuple[dict, OpMetrics]:
    """SUM-style aggregates over the join result WITHOUT materializing it.

    Returns {count, sum_add, sum_prod} == aggregates over the (virtual) join
    of ``build ⋈ probe``: pair count, Σ(b+p), Σ(b·p).  Both value columns are
    contracted at one explicit dtype (:data:`_AGG_DTYPE`).
    """
    with Timer() as t:
        pairs, s_add, s_prod = _join_aggregate(
            jnp.asarray(build[key], jnp.int32),
            jnp.asarray(build[build_val], _AGG_DTYPE),
            jnp.asarray(probe[key], jnp.int32),
            jnp.asarray(probe[probe_val], _AGG_DTYPE),
            key_domain,
            use_kernel=use_pallas(key_domain),
        )
        pairs, s_add, s_prod = jax.device_get((pairs, s_add, s_prod))
        out = {
            "count": float(pairs),
            "sum_add": float(s_add),
            "sum_prod": float(s_prod),
        }
    metrics = OpMetrics(
        op="join_aggregate",
        path="tensor",
        rows_in=len(build) + len(probe),
        rows_out=1,
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=key_domain * 4 * 4 + build.nbytes() + probe.nbytes(),
        host_syncs=1,
        h2d_bytes=(build[key].nbytes + build[build_val].nbytes
                   + probe[key].nbytes + probe[probe_val].nbytes),
    )
    return out, metrics


# ---------------------------------------------------------------------------
# Sort: step-wise multi-key (stable LSD passes over key axes)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_keys", "has_valid"))
def _multikey_perm(key_cols: Tuple[jnp.ndarray, ...], valid, num_keys: int,
                   has_valid: bool = False) -> jnp.ndarray:
    n = key_cols[0].shape[0]
    perm = jnp.arange(n)
    # least-significant key first; stability makes the composition lexicographic
    for i in range(num_keys - 1, -1, -1):
        idx = jnp.argsort(key_cols[i][perm], stable=True)
        perm = perm[idx]
    if has_valid:
        # one extra stable LSD pass on validity: masked rows sink to the tail
        # without disturbing key order among live rows
        idx = jnp.argsort(jnp.logical_not(valid)[perm], stable=True)
        perm = perm[idx]
    return perm


def _keys_fit_int32(key_cols) -> bool:
    """Key columns the Pallas tile sorter can take without value loss: the
    kernel casts to int32, so unsigned 32-bit (which would wrap negative)
    needs headroom — only dtypes whose full range embeds in int32 qualify."""
    def ok(dt):
        if not jnp.issubdtype(dt, jnp.integer):
            return False
        info = jnp.iinfo(dt)
        return info.min >= -(2**31) and info.max < 2**31
    return all(ok(c.dtype) for c in key_cols)


def sort_perm_device(key_cols: Tuple[jnp.ndarray, ...],
                     valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Sort permutation over key axes, Pallas-tiled when keys fit int32.

    The Pallas path (bitonic VMEM tile runs + XLA merge) engages under
    :func:`use_pallas` for int32-representable keys; otherwise the pure-jnp
    stable LSD passes run.  Masked rows always sink to the tail.
    """
    if valid is None and use_pallas() and _keys_fit_int32(key_cols):
        from ..kernels.multikey_sort.ops import multikey_sort_lsd_padded
        return multikey_sort_lsd_padded(tuple(key_cols))
    return _multikey_perm(tuple(key_cols), valid, len(key_cols),
                          has_valid=valid is not None)


def tensor_sort(
    rel: Relation, keys: Sequence[str]
) -> Tuple[Relation, OpMetrics]:
    """Tensor-path multi-key sort: per-axis stable passes, no key packing.

    Host-Relation API: permutation *and* payload gathers run on device; one
    batched fetch brings the result back (the seed fetched the permutation
    and re-gathered every column on the host)."""
    key_cols = tuple(jnp.asarray(rel[k]) for k in keys)
    with Timer() as t:
        perm = sort_perm_device(key_cols)
        out_dev = {k: jnp.take(jnp.asarray(v), perm)
                   for k, v in rel.columns.items()}
        fetched = jax.device_get(out_dev)
        out = Relation({k: np.asarray(v) for k, v in fetched.items()})
    peak = rel.nbytes() + len(rel) * 8 * 2
    metrics = OpMetrics(
        op="sort",
        path="tensor",
        rows_in=len(rel),
        rows_out=len(out),
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=peak,
        host_syncs=1,
        h2d_bytes=rel.nbytes(),
    )
    return out, metrics


def tensor_sort_device(
    rel: DeviceRelation, keys: Sequence[str]
) -> Tuple[DeviceRelation, OpMetrics]:
    """Device-resident multi-key sort: zero host syncs.

    Computes the permutation on device and composes it into the relation's
    pending gather indices — payload columns are not touched."""
    key_cols = tuple(rel.col(k) for k in keys)
    with Timer() as t:
        perm = sort_perm_device(key_cols, valid=rel.valid)
        out = rel.take_lazy(perm)
    peak = sum(c.dtype.itemsize for c in key_cols) * len(rel) + len(rel) * 8 * 2
    metrics = OpMetrics(
        op="sort",
        path="tensor",
        rows_in=len(rel),
        rows_out=len(rel),
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=peak,
        host_syncs=0,
    )
    return out, metrics
