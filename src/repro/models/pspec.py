"""Sharding-constraint helper usable from pure model code.

``constrain(x, "dp", None, "model")`` applies a with_sharding_constraint built
against the *ambient* mesh (the ``with mesh:`` scope the launcher lowers
under).  Outside any mesh (unit tests, CPU examples) it is a no-op, so model
code stays mesh-agnostic.  Logical names:

  "dp"    → the data-parallel axes present in the mesh (("pod","data") or
            ("data",)),
  "model" → the tensor-parallel axis,
  None    → replicated.

A constraint is skipped when the dimension does not divide the axis size —
GSPMD would reject it as an annotation; dropping it just returns inference to
the solver for that tensor.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

__all__ = ["constrain", "ambient_mesh"]


def ambient_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _resolve(name, mesh):
    if name is None:
        return None
    if name == "dp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if axes else None
    return name if name in mesh.axis_names else None


def _axis_size(entry, mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return int(mesh.shape[entry])


def constrain(x, *names):
    mesh = ambient_mesh()
    if mesh is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    entries = []
    for dim, name in zip(x.shape, names):
        e = _resolve(name, mesh)
        if e is not None and dim % _axis_size(e, mesh) != 0:
            e = None  # not annotatable; leave to the solver
        entries.append(e)
    return jax.lax.with_sharding_constraint(x, P(*entries))


def constrain_kv_cache(x):
    """[B, S, KH, D] cache: context-parallel — SEQUENCE sharded over "model"
    (mirrors distributed.sharding.cache_specs so the in-place decode update
    never re-layouts the cache).  Attention over the sharded S axis costs one
    all-reduce of softmax stats + the (B,H,D) output — independent of S."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    model = mesh.shape["model"] if "model" in mesh.axis_names else 1
    _, S = x.shape[0], x.shape[1]
    if S % model == 0 and S >= model:
        return constrain(x, "dp", "model", *([None] * (x.ndim - 2)))
    return constrain(x, "dp", *([None] * (x.ndim - 1)))
