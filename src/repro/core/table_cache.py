"""Device-resident base-table cache + key-cardinality sketches (serving path).

Repeated queries over the same base tables are the serving-path common case:
a per-query host→device upload (and pow2 re-padding) of columns that have not
changed since the last query is pure amortizable overhead, exactly like the
per-query planning `np.unique` sample the selector used to pay.  This module
makes both **resident across queries**:

  * :func:`get_device_columns` — bucket-padded (or exact-shape) device uploads
    of a relation's columns, cached *on the relation instance* and keyed by a
    sampled content token (:func:`repro.core.relation.column_token`).  A warm
    query transfers **zero** H2D bytes.  Rebinding/resizing/re-dtyping a
    column always changes the token; in-place element writes are caught with
    sampled confidence only — mutating callers must use
    :meth:`Relation.invalidate_device_cache` for a guaranteed refresh
    (Relations are immutable by convention).
  * :func:`key_stats` — a cached key-cardinality sketch (sample cardinality,
    duplication factor, min/max) shared by `PathSelector.choose_join` and the
    fused pipeline's host planner, so neither pays a 64k-row `np.unique` per
    query.

Storing the cache on the `Relation` instance ties entry lifetime to the table
itself (dropped with the relation, no global growth) and sidesteps `id()`
reuse.  Sub-relations made with :meth:`Relation.select` share the parent's
cache dicts *by reference*: the planner's projection-pruned scans (fresh
instances every query) re-use — and warm — the base table's uploads and
sketches, entries stay token-checked per column, and
:meth:`Relation.invalidate_device_cache` on the parent reaches every
selection.  `REPRO_TABLE_CACHE=0` disables caching (every query re-uploads
and re-samples); global hit/miss/H2D counters are exposed via
:func:`table_cache_info` for tests and benchmarks.

Cache/sketch bookkeeping is serialized by one module lock (the transfers
and scans themselves run outside it), so concurrent serving sessions can
share base tables without a cold upload stalling warm lookups.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from .codec_device import (DeviceCodes, DeviceColumnLayout, choose_layout,
                           compress_enabled, dict_bucket, encode_host,
                           pad_dictionary)
from .relation import Relation, column_token

__all__ = [
    "KeyStats",
    "cache_enabled",
    "column_layout",
    "device_cache_resident_bytes",
    "get_device_columns",
    "get_device_layouts",
    "pending_upload_bytes",
    "key_stats",
    "table_cache_info",
    "table_cache_clear",
]

_CACHE_ATTR = "_device_cache"
_STATS_ATTR = "_key_stats"
_LAYOUT_ATTR = "_layout_cache"
SAMPLE_ROWS = 65536  # key-cardinality sample size (matches the seed selector)


@dataclasses.dataclass
class _Counters:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    h2d_bytes: int = 0
    h2d_bytes_logical: int = 0
    sketch_hits: int = 0
    sketch_misses: int = 0
    layout_hits: int = 0
    layout_misses: int = 0


_COUNTERS = _Counters()

# One lock for every per-relation cache dict and the global counters:
# concurrent serving sessions share base tables, and cache bookkeeping must
# not mutate a dict mid-probe.  The lock guards the DICTS, not the compute:
# uploads and sketch scans run outside it (double-checked insert), so a
# cold multi-MB transfer never parks other sessions' warm lookups.  A rare
# racing pair both upload the same column — real transferred bytes, still
# reported — and every later query is warm.
_LOCK = threading.RLock()


def cache_enabled() -> bool:
    """Base-table cache toggle: ``REPRO_TABLE_CACHE=0`` disables residency."""
    return os.environ.get("REPRO_TABLE_CACHE", "1") != "0"


def table_cache_info() -> Dict[str, int]:
    with _LOCK:
        return dataclasses.asdict(_COUNTERS)


def table_cache_clear() -> None:
    """Reset the global counters.  Per-relation storage lives on the Relation
    instances themselves — drop it with ``rel.invalidate_device_cache()``."""
    global _COUNTERS
    with _LOCK:
        _COUNTERS = _Counters()


def _upload(col: np.ndarray, bucket: Optional[int]):
    """Host→device transfer of one column, optionally zero-padded to a
    power-of-two bucket (original dtype preserved)."""
    import jax.numpy as jnp

    if bucket is not None:
        pad = bucket - len(col)
        if pad:
            col = np.concatenate([col, np.zeros(pad, col.dtype)])
    return jnp.asarray(col)


def _padded_nbytes(col: np.ndarray, bucket: Optional[int]) -> int:
    n = len(col) if bucket is None else bucket
    return int(n * col.dtype.itemsize)


def get_device_columns(rel: Relation, bucket: Optional[int] = None
                       ) -> Tuple[Dict[str, object], int]:
    """Device arrays for all columns of ``rel`` plus the H2D bytes this call
    actually transferred (0 on a fully warm cache).

    ``bucket`` pads every column to that power-of-two length (the fused
    pipeline's shape-bucketed contract); ``None`` keeps exact shapes (the
    per-operator device path).  Entries are keyed ``(name, bucket, token)``
    so the two shapes coexist and a stale token is replaced in place.
    """
    uploaded = 0
    out: Dict[str, object] = {}
    if not cache_enabled():
        for name, col in rel.columns.items():
            out[name] = _upload(col, bucket)
            uploaded += _padded_nbytes(col, bucket)
        with _LOCK:
            _COUNTERS.misses += len(rel.columns)
            _COUNTERS.h2d_bytes += uploaded
        return out, uploaded
    tokens = {name: column_token(col) for name, col in rel.columns.items()}
    missing = []
    with _LOCK:
        cache = rel.__dict__.setdefault(_CACHE_ATTR, {})
        for name in rel.columns:
            entry = cache.get((name, bucket))
            if entry is not None and entry[0] == tokens[name]:
                _COUNTERS.hits += 1
                out[name] = entry[1]
                continue
            if entry is not None:
                _COUNTERS.invalidations += 1  # mutated column → fresh transfer
            _COUNTERS.misses += 1
            missing.append(name)
    # transfers run OUTSIDE the lock (cf. key_stats): a cold multi-MB
    # upload must not park every other session's warm dict probes behind
    # it.  Two queries racing on the same cold column both transfer (the
    # bytes they report were really moved); the last insert wins and all
    # later queries are warm.
    for name in missing:
        col = rel.columns[name]
        out[name] = _upload(col, bucket)
        uploaded += _padded_nbytes(col, bucket)
    if missing:
        with _LOCK:
            for name in missing:
                cache[(name, bucket)] = (tokens[name], out[name])
            _COUNTERS.h2d_bytes += uploaded
    return out, uploaded


def column_layout(rel: Relation, name: str
                  ) -> Tuple[DeviceColumnLayout, Optional[np.ndarray]]:
    """Packed-layout descriptor for one column, cached per (relation,
    column, content token) next to the key sketch.

    The descriptor (and, for dictionary layouts, the sorted host-side
    dictionary) is the fingerprint-keyed analysis the upload and costing
    paths share — neither re-scans the column once a fresh entry exists.
    With ``REPRO_DEVICE_COMPRESS=0`` the cache is bypassed entirely and
    every column reports a ``raw`` layout.
    """
    col = rel.columns[name]
    if not compress_enabled():
        return choose_layout(col)  # degrades to raw, nothing worth caching
    token = column_token(col)
    with _LOCK:
        cache = (rel.__dict__.setdefault(_LAYOUT_ATTR, {})
                 if cache_enabled() else None)
        if cache is not None:
            entry = cache.get(name)
            if entry is not None and entry[0] == token:
                _COUNTERS.layout_hits += 1
                return entry[1], entry[2]
        _COUNTERS.layout_misses += 1
    # the O(N) min/max/unique scans run OUTSIDE the lock (cf. key_stats)
    layout, aux = choose_layout(col)
    if cache is not None:
        with _LOCK:
            cache[name] = (token, layout, aux)
    return layout, aux


def get_device_layouts(rel: Relation, bucket: Optional[int] = None
                       ) -> Tuple[Dict[str, DeviceCodes], int, int]:
    """Packed device columns for ``rel``: ``(cols, physical, logical)``.

    ``cols`` maps column name → :class:`DeviceCodes` (device codes +
    layout + device dictionary); ``physical`` is the H2D bytes this call
    actually moved (packed codes + dictionaries), ``logical`` the bytes
    the same call would have moved at logical width — the pair the
    executor reports as ``h2d_bytes`` vs ``h2d_bytes_logical``.

    Storage discipline: ``raw``-layout columns share the plain
    ``get_device_columns`` entries (key ``(name, bucket)``); packed
    columns live under ``(name, bucket, "c")`` in the *same* per-relation
    cache dict, so :meth:`Relation.invalidate_device_cache` drops codes,
    dictionaries and raw uploads together.  A column whose logical-width
    copy is already resident is served from it rather than re-uploaded
    packed — zero transfer always beats a smaller transfer.
    """
    layouts = {name: column_layout(rel, name) for name in rel.columns}
    packed = [n for n, (lay, _) in layouts.items() if lay.encoding != "raw"]
    raw = [n for n in rel.columns if n not in packed]
    out: Dict[str, DeviceCodes] = {}
    up_phys = up_log = 0
    if raw:
        dev_raw, up_raw = get_device_columns(rel.select(raw), bucket)
        for name in raw:
            out[name] = DeviceCodes(dev_raw[name], layouts[name][0])
        up_phys += up_raw
        up_log += up_raw
    if not packed:
        return out, up_phys, up_log
    tokens = {name: column_token(rel.columns[name]) for name in packed}
    if not cache_enabled():
        for name in packed:
            dc, phys = _upload_packed(rel.columns[name], *layouts[name],
                                      bucket)
            out[name] = dc
            up_phys += phys
            up_log += _padded_nbytes(rel.columns[name], bucket)
        with _LOCK:
            _COUNTERS.misses += len(packed)
            _COUNTERS.h2d_bytes += up_phys
            _COUNTERS.h2d_bytes_logical += up_log
        return out, up_phys, up_log
    missing = []
    with _LOCK:
        cache = rel.__dict__.setdefault(_CACHE_ATTR, {})
        for name in packed:
            entry = cache.get((name, bucket, "c"))
            if entry is not None and entry[0] == tokens[name]:
                _COUNTERS.hits += 1
                out[name] = entry[1]
                continue
            raw_entry = cache.get((name, bucket))
            if raw_entry is not None and raw_entry[0] == tokens[name]:
                # logical-width copy already resident: reuse it — zero
                # transfer beats uploading packed codes next to it
                _COUNTERS.hits += 1
                col = rel.columns[name]
                out[name] = DeviceCodes(
                    raw_entry[1],
                    DeviceColumnLayout("raw", col.dtype.name, col.dtype.name,
                                       len(col)))
                continue
            if entry is not None:
                _COUNTERS.invalidations += 1  # mutated column → re-encode
            _COUNTERS.misses += 1
            missing.append(name)
    # encodes + transfers outside the lock (same double-checked-insert
    # discipline as get_device_columns)
    fresh_phys = fresh_log = 0
    for name in missing:
        dc, phys = _upload_packed(rel.columns[name], *layouts[name], bucket)
        out[name] = dc
        fresh_phys += phys
        fresh_log += _padded_nbytes(rel.columns[name], bucket)
    if missing:
        with _LOCK:
            for name in missing:
                cache[(name, bucket, "c")] = (tokens[name], out[name])
            _COUNTERS.h2d_bytes += fresh_phys
            _COUNTERS.h2d_bytes_logical += fresh_log
    return out, up_phys + fresh_phys, up_log + fresh_log


def _upload_packed(col: np.ndarray, layout: DeviceColumnLayout,
                   dictionary: Optional[np.ndarray],
                   bucket: Optional[int]) -> Tuple[DeviceCodes, int]:
    """Encode + transfer one packed column; returns the DeviceCodes and
    the physical bytes moved (codes + padded dictionary)."""
    import jax.numpy as jnp

    codes = encode_host(col, layout, dictionary)
    dev_codes = _upload(codes, bucket)
    phys = _padded_nbytes(codes, bucket)
    dict_dev = None
    if layout.encoding == "dict":
        padded = pad_dictionary(dictionary, dict_bucket(layout.card))
        dict_dev = jnp.asarray(padded)
        phys += int(padded.nbytes)
    return DeviceCodes(dev_codes, layout, dict_dev), phys


def pending_upload_bytes(rel, bucket: Optional[int] = None) -> int:
    """H2D bytes a query over ``rel`` would pay *right now* — the explicit
    transfer term the plan-level cost model charges the tensor path.  Zero
    when every column is already device-resident at this bucket.

    With compression on this prices what :func:`get_device_layouts` would
    actually move — *packed* bytes (plus dictionaries) — and a column
    resident in either physical form (packed codes or a logical-width
    upload) is free, matching the reuse rule above."""
    if not isinstance(rel, Relation):
        return 0  # already device-resident
    comp = compress_enabled()
    # token hashing and layout analysis outside the lock (the discipline
    # everywhere in this module): this probe runs on every fragment
    # decision of every session — layouts are fingerprint-cached
    tokens = {name: column_token(col) for name, col in rel.columns.items()}
    layouts = ({name: column_layout(rel, name)[0] for name in rel.columns}
               if comp else None)
    total = 0
    with _LOCK:
        cache = rel.__dict__.get(_CACHE_ATTR) if cache_enabled() else None
        for name, col in rel.columns.items():
            if cache is not None:
                entry = cache.get((name, bucket))
                if entry is not None and entry[0] == tokens[name]:
                    continue
                if comp:
                    entry = cache.get((name, bucket, "c"))
                    if entry is not None and entry[0] == tokens[name]:
                        continue
            if comp:
                rows = len(col) if bucket is None else bucket
                total += layouts[name].upload_bytes(rows)
            else:
                total += _padded_nbytes(col, bucket)
    return total


def device_cache_resident_bytes(rel) -> int:
    """HBM bytes currently held by this relation's cached device state —
    raw uploads, packed codes, dictionaries, and partitioned shard
    layouts.  This is the warm-cache footprint fig17 gates on."""
    if not isinstance(rel, Relation):
        return 0
    total = 0
    with _LOCK:
        for entry in (rel.__dict__.get(_CACHE_ATTR) or {}).values():
            obj = entry[1]
            if isinstance(obj, DeviceCodes):
                total += int(obj.codes.nbytes)
                if obj.dict_values is not None:
                    total += int(obj.dict_values.nbytes)
            else:
                total += int(obj.nbytes)
        for entry in (rel.__dict__.get("_partition_cache") or {}).values():
            for obj in entry.get("cols", {}).values():
                if isinstance(obj, DeviceCodes):
                    total += int(obj.codes.nbytes)
                    if obj.dict_values is not None:
                        total += int(obj.dict_values.nbytes)
                else:
                    total += int(obj.nbytes)
    return total


@dataclasses.dataclass(frozen=True)
class KeyStats:
    """Cached execution-time observables of one key column (§III.C)."""

    n: int            # column length
    sample_n: int     # rows sampled for cardinality
    card: int         # distinct keys in the sample
    dup: float        # average duplication factor (sample)
    kmin: object      # column minimum (exact Python scalar)
    kmax: object      # column maximum


def key_stats(rel: Relation, key: str) -> KeyStats:
    """Key-cardinality sketch, cached per (relation, key, content token).

    The seed selector re-ran ``np.unique`` over a 65536-row sample on every
    ``choose_join`` call — per-query planning overhead this cache amortizes
    away for repeated queries over the same base tables.
    """
    col = np.asarray(rel[key])
    token = column_token(col)
    with _LOCK:
        cache = (rel.__dict__.setdefault(_STATS_ATTR, {})
                 if cache_enabled() else None)
        if cache is not None:
            entry = cache.get(key)
            if entry is not None and entry[0] == token:
                _COUNTERS.sketch_hits += 1
                return entry[1]
        _COUNTERS.sketch_misses += 1
    # the O(N) scans run OUTSIDE the lock (cf. planner._packed_column):
    # holding it would park every session's warm lookups — on unrelated
    # tables — behind one cold sketch; a rare racing double-sketch of the
    # same column computes identical stats and is cheaper
    n = len(col)
    if n == 0:
        stats = KeyStats(0, 0, 0, 1.0, 0, 0)
    else:
        sample = col[: min(n, SAMPLE_ROWS)]
        card = max(1, len(np.unique(sample)))
        dup = max(1.0, len(sample) / card)
        # min/max over the full column: one O(N) scan each, amortized by
        # the cache (the fused planner needs the exact key range, not a
        # sample's)
        stats = KeyStats(n, len(sample), card, dup,
                         col.min().item(), col.max().item())
    if cache is not None:
        with _LOCK:
            cache[key] = (token, stats)
    return stats
