"""Int8 KV-cache quantization (2× decode cache capacity / context length).

Per-(position, head) symmetric scales keep the quantization error local: a
token with outlier keys cannot degrade other positions.  At 32k context the
bf16 KV cache is the dominant decode working set (yi-34b decode_32k:
~1 TB global); int8 halves it — or equivalently doubles servable batch or
context at the same HBM.

Decode integration: quantize entries as they are appended; dequantize the
whole (sharded) cache at attention time — on TPU this is a VPU-cheap cast
fused into the QK^T producer.  Accuracy is validated against bf16 attention
in tests (cosine > 0.999 at 4k context).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["QuantizedKV", "quantize_kv", "dequantize_kv", "append_quantized",
           "decode_attention_quantized"]


class QuantizedKV(NamedTuple):
    q: jnp.ndarray       # int8 [B, S, KH, D]
    scale: jnp.ndarray   # f32  [B, S, KH] per-(position, head)


def quantize_kv(x: jnp.ndarray) -> QuantizedKV:
    """x [B, S, KH, D] → int8 + per-(pos, head) scale."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return QuantizedKV(q, scale)


def dequantize_kv(qkv: QuantizedKV, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (qkv.q.astype(jnp.float32) * qkv.scale[..., None]).astype(dtype)


def append_quantized(cache: QuantizedKV, new: jnp.ndarray,
                     pos: jnp.ndarray) -> QuantizedKV:
    """Write one new [B, 1, KH, D] entry at position pos (in-place DUS)."""
    entry = quantize_kv(new)
    zero = jnp.zeros((), jnp.int32)
    p = jnp.asarray(pos, jnp.int32)
    q = jax.lax.dynamic_update_slice(cache.q, entry.q, (zero, p, zero, zero))
    s = jax.lax.dynamic_update_slice(cache.scale, entry.scale, (zero, p, zero))
    return QuantizedKV(q, s)


def decode_attention_quantized(q, k_cache: QuantizedKV, v_cache: QuantizedKV,
                               cur_pos, **kw):
    """decode_attention against int8 caches (dequantize at use)."""
    from ..models.attention import decode_attention
    return decode_attention(q, dequantize_kv(k_cache, q.dtype),
                            dequantize_kv(v_cache, q.dtype), cur_pos, **kw)
