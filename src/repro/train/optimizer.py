"""Optimizers: AdamW and Adafactor, built for sharded execution.

State layout mirrors the parameter pytree, so parameter PartitionSpecs apply
verbatim (ZeRO-style: since every large parameter is already 2-D sharded over
("data","model"), the optimizer state inherits the same full sharding — the
v5e HBM budget math in DESIGN.md §6 depends on this).  Adafactor keeps
factored second moments (row/col vectors, replicated — they are tiny) which
is what makes the 398B Jamba config fit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "make_optimizer", "global_norm"]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (new_params, new_state, metrics)
    name: str


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        grads, gnorm = _clip_by_global_norm(grads, clip_norm) if clip_norm else (
            jax.tree.map(lambda g: g.astype(jnp.float32), grads), global_norm(grads))
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        is_tup = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=is_tup)
        new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=is_tup)
        new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=is_tup)
        return new_params, {"step": step, "m": new_m, "v": new_v}, {"grad_norm": gnorm}

    return Optimizer(init, update, "adamw")


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: Optional[float] = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments for >=2-D leaves; no first moment."""

    def init(params):
        def state_for(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),   # reduce cols
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(state_for, params)}

    def update(grads, state, params):
        grads, gnorm = _clip_by_global_norm(grads, clip_norm) if clip_norm else (
            jax.tree.map(lambda g: g.astype(jnp.float32), grads), global_norm(grads))
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(p, g, s):
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                new_s = {"vr": vr, "vc": vc}
            else:
                vhat = beta * s["v"] + (1 - beta) * g2
                new_s = {"v": vhat}
            u = g / jnp.sqrt(vhat + eps)
            # Adafactor update clipping (RMS of update <= 1)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms)
            newp = p.astype(jnp.float32) - lr * u
            if weight_decay:
                newp -= lr * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), new_s

        out = jax.tree.map(upd, params, grads, state["v"])
        is_tup = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda t2: t2[0], out, is_leaf=is_tup)
        new_v = jax.tree.map(lambda t2: t2[1], out, is_leaf=is_tup)
        return new_params, {"step": step, "v": new_v}, {"grad_norm": gnorm}

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
