"""chunked_attention / decode_attention vs. a naive dense-softmax oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention, decode_attention


def naive_attention(q, k, v, *, causal=True, q_offset=0, window=None,
                    cap=None, scale=None):
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    pos_q = q_offset + jnp.arange(Sq)
    pos_k = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        mask &= (pos_q[:, None] - pos_k[None, :]) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)


@pytest.mark.parametrize("B,S,H,KH,D", [
    (2, 64, 4, 4, 16),    # MHA
    (1, 128, 8, 2, 32),   # GQA
    (2, 64, 4, 1, 16),    # MQA
])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None),
    (True, 16, None),       # sliding window
    (True, None, 50.0),     # softcap
    (False, None, None),    # encoder
])
def test_chunked_matches_naive(B, S, H, KH, D, causal, window, cap):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    ref = naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    for q_chunk, kv_chunk in [(16, 32), (64, 16), (S, S)]:
        out = chunked_attention(q, k, v, causal=causal, window=window, cap=cap,
                                q_chunk=q_chunk, kv_chunk=kv_chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_decode_matches_naive_last_row():
    B, S, H, KH, D = 2, 48, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q_all = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    ref = naive_attention(q_all, k, v, causal=True)
    cur = S - 1
    out = decode_attention(q_all[:, cur:cur + 1], k, v, jnp.asarray(cur))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref[:, cur]),
                               rtol=2e-5, atol=2e-5)


def test_decode_masks_future_cache():
    """Garbage beyond cur_pos in the cache must not leak into the output."""
    B, S, H, KH, D = 1, 32, 4, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    cur = 10
    out1 = decode_attention(q, k, v, jnp.asarray(cur))
    k2 = k.at[:, cur + 1:].set(1e6)
    v2 = v.at[:, cur + 1:].set(-1e6)
    out2 = decode_attention(q, k2, v2, jnp.asarray(cur))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
