"""GROUP BY dual paths: linear (spilling hash aggregate) vs tensor (segment
reductions) — identical results under any work_mem."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis; pip install -r requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.core import Relation
from repro.core.aggregate import group_aggregate_linear, group_aggregate_tensor

AGGS = {"v": "sum", "w": "min", "u": "max", "c": "count"}


def _mk(rng, n, domain):
    return Relation({
        "k": rng.integers(0, domain, n).astype(np.int64),
        "v": rng.integers(-100, 100, n).astype(np.int64),
        "w": rng.integers(-1000, 1000, n).astype(np.int64),
        "u": rng.integers(-1000, 1000, n).astype(np.int64),
        "c": np.ones(n, np.int64),
    })


@pytest.mark.parametrize("work_mem", [1 << 30, 8 * 1024])
@pytest.mark.parametrize("n,domain", [(20_000, 64), (20_000, 15_000), (1, 1)])
def test_aggregate_paths_agree(work_mem, n, domain):
    rng = np.random.default_rng(0)
    rel = _mk(rng, n, domain)
    lin, m_lin = group_aggregate_linear(rel, "k", AGGS, work_mem)
    ten, m_ten = group_aggregate_tensor(rel, "k", AGGS)
    assert m_ten.spill.temp_bytes == 0
    assert set(lin.names) == set(ten.names)
    order_l = np.argsort(lin["k"])
    order_t = np.argsort(ten["k"])
    for name in lin.names:
        np.testing.assert_allclose(lin[name][order_l], ten[name][order_t],
                                   rtol=1e-9, atol=1e-9, err_msg=name)


def test_linear_spills_under_pressure():
    rng = np.random.default_rng(1)
    rel = _mk(rng, 100_000, 90_000)  # many groups → table >> 8 KB
    _, m = group_aggregate_linear(rel, "k", {"v": "sum"}, 8 * 1024)
    assert m.spill.temp_bytes > 0 and m.spill.partition_passes >= 1
    _, m2 = group_aggregate_linear(rel, "k", {"v": "sum"}, 1 << 30)
    assert m2.spill.temp_bytes == 0


def test_oracle_against_numpy():
    rng = np.random.default_rng(2)
    rel = _mk(rng, 5000, 37)
    out, _ = group_aggregate_tensor(rel, "k", {"v": "sum"})
    for kk, ss in zip(out["k"], out["sum_v"]):
        np.testing.assert_allclose(ss, rel["v"][rel["k"] == kk].sum())


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300), domain=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1),
       work_mem=st.sampled_from([4 * 1024, 1 << 30]))
def test_property_aggregate_paths_agree(n, domain, seed, work_mem):
    rng = np.random.default_rng(seed)
    rel = _mk(rng, n, domain)
    lin, _ = group_aggregate_linear(rel, "k", {"v": "sum", "c": "count"}, work_mem)
    ten, _ = group_aggregate_tensor(rel, "k", {"v": "sum", "c": "count"})
    ol, ot = np.argsort(lin["k"]), np.argsort(ten["k"])
    for name in lin.names:
        np.testing.assert_allclose(lin[name][ol], ten[name][ot], rtol=1e-9)
