"""Core of the reproduction: tensor-based execution paths for high-dimensional
relational operations, with execution-time path selection (the paper's
contribution), plus the faithful linear (spilling) baseline it is measured
against."""
from .cost_model import CostConstants, CostModel
from .aggregate import group_aggregate_linear, group_aggregate_tensor
from .executor import Aggregate, Executor, Filter, GroupBy, Join, QueryResult, Scan, Sort
from .linear_engine import HashTable, hash_join_linear, sort_linear, table_bytes_estimate
from .metrics import BLOCK_BYTES, LatencyStats, OpMetrics, SpillAccount, latency_stats
from .path_selector import Decision, PathSelector
from .relation import Relation
from .spill import SpillManager
from .tensor_engine import (
    aligned_join_indices,
    join_capacity,
    tensor_join,
    tensor_join_aggregate,
    tensor_sort,
)

__all__ = [
    "Aggregate", "BLOCK_BYTES", "CostConstants", "CostModel", "Decision",
    "Executor", "Filter", "GroupBy", "HashTable", "Join", "LatencyStats", "OpMetrics",
    "PathSelector", "QueryResult", "Relation", "Scan", "Sort", "SpillAccount",
    "SpillManager", "aligned_join_indices", "hash_join_linear", "join_capacity",
    "group_aggregate_linear", "group_aggregate_tensor",
    "latency_stats", "sort_linear", "table_bytes_estimate", "tensor_join",
    "tensor_join_aggregate", "tensor_sort",
]
