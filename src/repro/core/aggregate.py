"""GROUP BY (hash aggregate) with dual execution paths.

The third classic linearizing operator after join and sort: the linear path
builds a hash table of groups (spilling to grouped partitions under
work_mem), the tensor path segment-reduces along the key axis (the same
dimension-preserving structure as the fused join-aggregate).  Semantics are
identical; the executor treats it as another deferred decision point.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .linear_engine import _next_pow2, _splitmix64, table_bytes_estimate
from .metrics import OpMetrics, SpillAccount, Timer
from .relation import Relation
from .spill import SpillManager

__all__ = ["group_aggregate_linear", "group_aggregate_tensor",
           "group_aggregate_device"]

_AGGS = ("sum", "count", "min", "max")


def _agg_inmem(rel: Relation, key: str, values: Dict[str, str]) -> Relation:
    keys = rel[key]
    uniq, inv = np.unique(keys, return_inverse=True)
    out: Dict[str, np.ndarray] = {key: uniq}
    for col, fn in values.items():
        v = rel[col]
        if fn == "sum":
            out[f"{fn}_{col}"] = np.bincount(inv, weights=v.astype(np.float64),
                                             minlength=len(uniq))
        elif fn == "count":
            out[f"{fn}_{col}"] = np.bincount(inv, minlength=len(uniq)).astype(np.float64)
        elif fn in ("min", "max"):
            fill = np.inf if fn == "min" else -np.inf
            acc = np.full(len(uniq), fill)
            ufunc = np.minimum if fn == "min" else np.maximum
            ufunc.at(acc, inv, v.astype(np.float64))
            out[f"{fn}_{col}"] = acc
        else:
            raise ValueError(fn)
    return Relation(out)


def _merge_groups(parts: List[Relation], key: str, values: Dict[str, str]) -> Relation:
    merged = parts[0]
    for p in parts[1:]:
        merged = merged.concat(p)
    keys = merged[key]
    uniq, inv = np.unique(keys, return_inverse=True)
    out = {key: uniq}
    for col, fn in values.items():
        name = f"{fn}_{col}"
        v = merged[name]
        if fn in ("sum", "count"):
            out[name] = np.bincount(inv, weights=v, minlength=len(uniq))
        else:
            fill = np.inf if fn == "min" else -np.inf
            acc = np.full(len(uniq), fill)
            (np.minimum if fn == "min" else np.maximum).at(acc, inv, v)
            out[name] = acc
    return Relation(out)


def group_aggregate_linear(rel: Relation, key: str, values: Dict[str, str],
                           work_mem: int, mgr: SpillManager = None
                           ) -> Tuple[Relation, OpMetrics]:
    """Hash aggregate with work_mem discipline: when the group table would
    not fit, inputs hash-partition to disk and each partition aggregates
    independently (PostgreSQL's spill-to-disk hash aggregation)."""
    own = mgr is None
    mgr = mgr or SpillManager()
    spill = SpillAccount()
    peak = 0
    try:
        with Timer() as t:
            keys = rel[key].astype(np.int64)
            n_groups_est = min(len(rel), max(1, len(np.unique(
                keys[: min(len(keys), 65536)])) * max(1, len(keys) // 65536)))
            est = table_bytes_estimate(n_groups_est)
            if est <= work_mem or len(rel) <= 64:
                out = _agg_inmem(rel, key, values)
                peak = est
            else:
                fanout = min(64, max(2, _next_pow2(int(np.ceil(est / work_mem)))))
                spill.partition_passes += 1
                h = (_splitmix64(keys, salt=7) % np.uint64(fanout)).astype(np.int64)
                parts = []
                for f in range(fanout):
                    part = rel.take(np.nonzero(h == f)[0])
                    if len(part) == 0:
                        continue
                    path = mgr.write_relation(part, f"agg{f}", spill)
                    parts.append(path)
                peak = table_bytes_estimate(n_groups_est // fanout)
                results = []
                for path in parts:
                    part = mgr.read_relation(path, spill)
                    mgr.delete(path)
                    results.append(_agg_inmem(part, key, values))
                out = _merge_groups(results, key, values)
    finally:
        if own:
            mgr.cleanup()
    return out, OpMetrics(op="group_aggregate", path="linear",
                          rows_in=len(rel), rows_out=len(out),
                          wall_s=t.elapsed, spill=spill,
                          peak_working_set_bytes=peak)


def _group_reduce_impl(keys, valid, cols, fns, num_segments, use_kernel):
    """Device group-by core: factorize the key axis ON DEVICE (sort + run
    boundaries), then segment-reduce every aggregate column.

    ``valid`` masks physical rows that are not logical rows (the device-
    resident pipeline's capacity padding / filtered rows); masked rows carry
    zero weight and sink to the tail of the sorted key axis.  Output arrays
    are ``num_segments``-padded; the returned prefix mask selects the real
    groups.  No host transfer happens anywhere in here.
    """
    import jax
    import jax.numpy as jnp

    from .tensor_engine import segment_sum_dispatch

    n = keys.shape[0]
    order = jnp.argsort(keys, stable=True)
    if valid is None:
        vmask = jnp.ones((n,), bool)
    else:
        # second stable pass on invalidity: masked rows sink to the tail
        # WITHOUT remapping their keys (a sentinel remap would collide with
        # real rows at the dtype extreme and merge segments)
        order = jnp.take(order, jnp.argsort(
            jnp.logical_not(jnp.take(valid, order)), stable=True))
        vmask = jnp.take(valid, order)
    sk = jnp.take(keys, order)
    boundary = jnp.concatenate(
        [jnp.ones((1,), bool), sk[1:] != sk[:-1]]) if n > 1 else jnp.ones((1,), bool)
    # valid rows form a prefix, so within it `boundary` is exact
    newseg = boundary & vmask
    seg = jnp.cumsum(newseg.astype(jnp.int32)) - 1  # masked rows inherit ids; weight 0
    nseg = newseg.sum()
    uniq = jax.ops.segment_max(
        jnp.where(vmask, sk, jnp.iinfo(keys.dtype).min), seg,
        num_segments=num_segments)
    results = []
    for col, fn in zip(cols, fns):
        v = jnp.take(col.astype(jnp.float64), order)
        if fn == "sum":
            r = segment_sum_dispatch(jnp.where(vmask, v, 0.0), seg,
                                     num_segments, use_kernel)
        elif fn == "count":
            r = segment_sum_dispatch(vmask.astype(jnp.float64), seg,
                                     num_segments, use_kernel)
        elif fn == "min":
            r = jax.ops.segment_min(jnp.where(vmask, v, jnp.inf), seg,
                                    num_segments=num_segments)
        elif fn == "max":
            r = jax.ops.segment_max(jnp.where(vmask, v, -jnp.inf), seg,
                                    num_segments=num_segments)
        else:
            raise ValueError(fn)
        results.append(r)
    valid_out = jnp.arange(num_segments) < nseg
    return uniq, tuple(results), valid_out


def group_aggregate_device(rel, key: str, values: Dict[str, str],
                           use_kernel: bool = None):
    """Device-resident GROUP BY: DeviceRelation → DeviceRelation, zero syncs.

    The seed's tensor group-by factorized keys on the host (np.unique) —
    a full device→host→device round trip per operator.  Here factorization
    is a device sort; the output stays device-resident with its real group
    count carried as a prefix validity mask.
    """
    import jax.numpy as jnp

    from .device_relation import DeviceRelation
    from .tensor_engine import use_pallas

    cols_in = tuple(rel.col(c) for c in values)
    fns = tuple(values.values())
    key_col = rel.columns[key]
    key_decode = None
    if key_col.decode is not None:
        # packed key column: factorize in the CODE domain.  Both codecs are
        # order-preserving (FOR is value−min, dict codes are sorted-unique
        # ranks), so sorting codes sorts values and segment boundaries are
        # identical — only the per-group representative needs decoding, one
        # O(groups) device op after the reduce instead of an O(rows) decode
        # before it.
        keys_dev = key_col.force_codes()
        key_decode = key_col.decode
    else:
        keys_dev = rel.col(key)
        if not jnp.issubdtype(keys_dev.dtype, jnp.integer):
            # seed-compatible coercion: non-integer group keys truncate to
            # int64 (the segment machinery needs an integer coordinate axis)
            keys_dev = keys_dev.astype(jnp.int64)
    n = rel.num_physical_rows
    if n == 0:
        out_cols = {key: rel.col(key)}
        for col, agg in values.items():
            out_cols[f"{agg}_{col}"] = jnp.zeros((0,), jnp.float64)
        return (DeviceRelation.from_arrays(out_cols),
                OpMetrics(op="group_aggregate", path="tensor", rows_in=0,
                          rows_out=0, wall_s=0.0, spill=SpillAccount()))
    if use_kernel is None:
        use_kernel = use_pallas(n)
    with Timer() as t:
        fn = _group_reduce_jit()
        uniq, results, valid_out = fn(keys_dev, rel.valid, cols_in, fns, n,
                                      use_kernel)
        if key_decode is not None:
            # decode-at-fetch for the group axis: garbage codes in invalid
            # segments decode to arbitrary (clipped) values, masked by the
            # valid_out prefix exactly like every other padded output
            uniq = key_decode(uniq)
        out_cols = {key: uniq}
        for (col, agg), r in zip(values.items(), results):
            out_cols[f"{agg}_{col}"] = r
        out = DeviceRelation.from_arrays(out_cols, valid=valid_out)
    peak = n * 8 * (2 + len(values))
    return out, OpMetrics(op="group_aggregate", path="tensor",
                          rows_in=n, rows_out=n,
                          wall_s=t.elapsed, spill=SpillAccount(),
                          peak_working_set_bytes=peak, host_syncs=0)


_GROUP_REDUCE_JIT = None


def _group_reduce_jit():
    """Lazy jit of the group reduce (fns/num_segments/use_kernel static)."""
    import jax

    global _GROUP_REDUCE_JIT
    if _GROUP_REDUCE_JIT is None:
        _GROUP_REDUCE_JIT = jax.jit(
            _group_reduce_impl,
            static_argnames=("fns", "num_segments", "use_kernel"))
    return _GROUP_REDUCE_JIT


def group_aggregate_tensor(rel: Relation, key: str, values: Dict[str, str],
                           key_domain: int = None) -> Tuple[Relation, OpMetrics]:
    """Dimension-preserving aggregate: segment reductions along the key axis
    (jit, static segment count) — no group hash table ever exists.

    Host-Relation API over :func:`group_aggregate_device`: lift, reduce on
    device, one batched fetch."""
    from .device_relation import DeviceRelation

    dev = DeviceRelation.from_host(rel)
    with Timer() as t:
        out_dev, m = group_aggregate_device(dev, key, values)
        syncs = 1
        if out_dev.valid is not None:
            # group outputs are padded to the physical row count; fetch the
            # group count (scalar sync) and device-slice so the batched
            # result fetch is O(groups), not O(rows)
            nseg = int(out_dev.valid.sum())
            syncs = 2
            out_dev = DeviceRelation.from_arrays(
                {k: out_dev.col(k)[:nseg] for k in out_dev.names})
        out = out_dev.to_host()
    peak = rel.nbytes() + len(out) * 8 * (1 + len(values))
    return out, OpMetrics(op="group_aggregate", path="tensor",
                          rows_in=len(rel), rows_out=len(out),
                          wall_s=t.elapsed, spill=SpillAccount(),
                          peak_working_set_bytes=peak, host_syncs=syncs)
