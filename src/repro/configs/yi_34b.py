"""Yi-34B [arXiv:2403.04652; hf]: llama-arch dense GQA."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    vocab_size=64_000,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    rope_theta=10_000.0,
    source="arXiv:2403.04652; hf 01-ai/Yi-34B",
)

SMOKE = ArchConfig(
    name="yi-34b",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    num_heads=8,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
)

register(CONFIG, SMOKE)
