"""Flag p50 regressions in a fresh benchmark run vs the committed baseline.

    PYTHONPATH=src python -m benchmarks.run --fast --save results/bench_fresh.json
    PYTHONPATH=src python -m benchmarks.compare results/bench_fresh.json

Walks both summaries for numeric leaves whose key mentions ``p50`` (seconds),
prints a ratio table, and exits non-zero when any shared p50 exceeds the
baseline by more than ``--threshold``x.  Entries present in only one file are
reported but never fail the run (new benchmarks land; subsets run with
``--only``), so the gate stays usable on partial sweeps.  CI runs this with
``continue-on-error`` — shared-runner timing noise should flag, not block.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, Tuple


def _p50_leaves(obj, prefix: Tuple[str, ...] = ()) -> Dict[Tuple[str, ...], float]:
    out: Dict[Tuple[str, ...], float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_p50_leaves(v, prefix + (str(k),)))
    elif isinstance(obj, (int, float)) and prefix and "p50" in prefix[-1]:
        out[prefix] = float(obj)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="bench_summary.json from the run under test")
    ap.add_argument("--baseline", default="results/bench_summary.json",
                    help="committed reference summary")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="flag fresh/baseline p50 ratios above this")
    args = ap.parse_args()

    base = _p50_leaves(json.loads(pathlib.Path(args.baseline).read_text()))
    fresh = _p50_leaves(json.loads(pathlib.Path(args.fresh).read_text()))

    regressions = []
    for key in sorted(base):
        name = "/".join(key)
        if key not in fresh:
            print(f"SKIPPED     {name} (not in fresh run)")
            continue
        bv, fv = base[key], fresh[key]
        ratio = fv / bv if bv > 0 else float("inf")
        flag = ratio > args.threshold
        status = "REGRESSION" if flag else "ok"
        print(f"{status:11s} {name}: {bv:.4g}s -> {fv:.4g}s ({ratio:.2f}x)")
        if flag:
            regressions.append(name)
    for key in sorted(set(fresh) - set(base)):
        print(f"NEW         {'/'.join(key)}: {fresh[key]:.4g}s (no baseline)")

    if regressions:
        print(f"\n{len(regressions)} p50 regression(s) above "
              f"{args.threshold:.2f}x: {', '.join(regressions)}")
        return 1
    print(f"\nno p50 regressions above {args.threshold:.2f}x "
          f"({len(base)} baseline entries checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
