"""Radix partitioning for sharded fused fragments (host-side, cached).

The sharded tensor path splits a fused Join→[Filter]→[Agg] fragment into
``num_parts`` co-partitions by a multiplicative hash of the join key and
runs one partition per mesh device (:mod:`repro.distributed.sharding`).
This module owns the host side of that contract:

  * **Partitioning contract** — row ``i`` lands in partition
    ``hash64(key[i]) >> (64 - log2 P)`` (Fibonacci multiplicative hash,
    robust to skewed/clustered key domains).  Both join sides use the same
    function, so matching keys always meet in the same partition and a
    per-partition join is exact.
  * **Sorted runs** — the *build* side of a partition is stored sorted by
    the join key.  That turns each per-device join into a searchsorted
    probe over an L2-resident run with **no per-query device sort at
    all** — the single-device fused path re-argsorts the build side inside
    every query, and that sort is ~half its wall time at 1M rows.  The
    one-time partition+sort pass is amortized across queries exactly like
    the device-resident base-table cache (:mod:`repro.core.table_cache`),
    whose caching discipline this module mirrors: entries live **on the
    Relation instance** (dropped with the table, shared with
    ``select()`` sub-relations), are keyed by sampled content tokens, and
    bookkeeping is serialized by one module lock while partitioning and
    transfers run outside it.
  * **Skew-aware sizing** — per-partition buckets are quarter-power-of-two
    (bounded shape count for the compile cache, ≤25% padding waste even
    under skew, vs. up-to-2x for plain pow2 when partition counts land
    just past a power of two), and :func:`partition_skew` reports
    ``max/mean`` partition fill so the cost model can price the critical
    partition of a skewed key distribution.

Padding: the key column pads with the int64 sentinel (``_I64_MAX`` — the
documented key-domain exclusion the fused path already relies on), which
also sorts past every real key so sorted runs stay sorted through their
padding; payload columns pad with zeros and are never read (validity is
masked by the per-partition row counts).

Packed payloads: the join KEY column always stays logical int64 — the
sentinel padding and cross-relation co-partitioning contracts live in the
value domain — but payload columns store *packed codes* per their cached
:func:`~repro.core.table_cache.column_layout` (dictionary / frame-of-
reference; :mod:`repro.core.codec_device`), so warm sharded queries keep
packed bytes resident and cold ones upload packed bytes.  Dictionaries
ride next to the partitioned columns (replicated, not sharded) and the
shard program decodes at gather, same as the single-device fused path.
"""
from __future__ import annotations

import threading
from typing import Dict, Tuple

import numpy as np

from .codec_device import (DeviceColumnLayout, compress_enabled, dict_bucket,
                           encode_host, pad_dictionary)
from .relation import Relation, column_token

__all__ = [
    "PART_MIN_BUCKET",
    "partition_bucket",
    "partition_of",
    "partition_counts",
    "partition_skew",
    "get_partitioned_columns",
    "pending_partition_bytes",
    "partition_cache_info",
    "partition_cache_clear",
]

_I64_MAX = np.iinfo(np.int64).max
_FIB = np.uint64(0x9E3779B97F4A7C15)  # 2^64 / golden ratio

_CACHE_ATTR = "_partition_cache"
PART_MIN_BUCKET = 4096


class _Counters:
    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.h2d_bytes = 0


_COUNTERS = _Counters()
# Same discipline as table_cache: the lock guards the per-relation cache
# dicts and the counters; partitioning passes and device transfers run
# outside it (double-checked insert — a racing pair both partition, both
# results are identical, every later query is warm).
_LOCK = threading.RLock()


def partition_cache_info() -> Dict[str, int]:
    with _LOCK:
        return {"hits": _COUNTERS.hits, "misses": _COUNTERS.misses,
                "h2d_bytes": _COUNTERS.h2d_bytes}


def partition_cache_clear() -> None:
    with _LOCK:
        _COUNTERS.hits = 0
        _COUNTERS.misses = 0
        _COUNTERS.h2d_bytes = 0


def partition_bucket(n: int) -> int:
    """Quarter-power-of-two shape bucket for per-partition arrays.

    Plain pow2 buckets double a partition's padding the moment skew pushes
    its fill just past a power of two — with P partitions that waste is
    paid P times.  Quarter-pow2 steps (4 buckets per octave) bound padding
    at 25% while keeping the compiled-shape universe small."""
    n = max(PART_MIN_BUCKET, int(n))
    p = 1 << (int(n - 1).bit_length())  # next pow2 >= n
    for num in (5, 6, 7):  # p/2 * 1.25 / 1.5 / 1.75
        q = (p >> 3) * num
        if q >= n:
            return q
    return p


def partition_of(keys: np.ndarray, num_parts: int) -> np.ndarray:
    """Partition id per row: top bits of the Fibonacci hash of the int64
    key, folded to ``num_parts``.  Identical on both join sides."""
    h = keys.astype(np.int64, copy=False).view(np.uint64) * _FIB
    # top 32 hash bits scaled to [0, num_parts): unbiased enough for
    # partitioning and free of the modulo's weakness on even key strides
    return ((h >> np.uint64(32)) * np.uint64(num_parts)
            >> np.uint64(32)).astype(np.int64)


def partition_counts(rel: Relation, key: str, num_parts: int) -> np.ndarray:
    """Exact per-partition row counts for ``rel`` under the partitioning
    contract — one O(n) hash pass, memoized on the relation instance by
    content token (the selector prices skew per decision; warm serving
    queries must not pay a per-query hash pass, the same discipline as
    ``key_stats``)."""
    num_parts = int(num_parts)
    token = column_token(rel[key])
    memo_key = ("counts", key, num_parts)
    with _LOCK:
        cache = rel.__dict__.setdefault(_CACHE_ATTR, {})
        hit = cache.get(memo_key)
        if hit is not None and hit[0] == token:
            _COUNTERS.hits += 1
            return hit[1]
        _COUNTERS.misses += 1
    counts = np.bincount(partition_of(rel[key], num_parts),
                         minlength=num_parts).astype(np.int64)
    with _LOCK:
        cache = rel.__dict__.setdefault(_CACHE_ATTR, {})
        cache[memo_key] = (token, counts)
    return counts


def partition_skew(counts: np.ndarray) -> float:
    """``max/mean`` partition fill — 1.0 is perfectly balanced; the cost
    model charges the sharded path's critical partition with this factor."""
    counts = np.asarray(counts, dtype=np.int64)
    mean = float(counts.mean()) if len(counts) else 0.0
    if mean <= 0:
        return 1.0
    return float(counts.max()) / mean


def _build_partitions(rel: Relation, key: str, num_parts: int,
                      sort_within: bool):
    """One partitioning pass over the host columns.

    Returns ``(host_cols, counts, bucket, layouts, dicts_host)`` where each
    host column is a ``(num_parts, bucket)`` array with partition ``p``'s
    rows in its first ``counts[p]`` slots.  ``sort_within`` additionally
    orders each partition's rows by the join key (the build-side sorted-run
    layout).  Payload columns are stored as packed codes per ``layouts``;
    ``dicts_host`` holds the bucket-padded dictionaries of ``dict``-encoded
    payloads (the key column is always logical int64 — sentinel contract)."""
    from .table_cache import column_layout

    keys = np.asarray(rel[key])
    part = partition_of(keys, num_parts)
    if sort_within:
        order = np.lexsort((keys, part))  # partition-major, key-minor
    else:
        order = np.argsort(part, kind="stable")
    counts = np.bincount(part, minlength=num_parts).astype(np.int64)
    bucket = partition_bucket(int(counts.max()) if len(counts) else 0)
    offsets = np.zeros(num_parts + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    host_cols = {}
    layouts: Dict[str, DeviceColumnLayout] = {}
    dicts_host = {}
    for name in rel.names:
        col = np.asarray(rel[name])[order]
        if name == key and not np.issubdtype(col.dtype, np.integer):
            raise TypeError(f"join key {name!r} must be integer-typed")
        if name == key:
            buf = np.full((num_parts, bucket), _I64_MAX, dtype=np.int64)
            col = col.astype(np.int64, copy=False)
            layouts[name] = DeviceColumnLayout("raw", "int64", "int64",
                                               len(col))
        else:
            lay, aux = column_layout(rel, name)
            layouts[name] = lay
            if lay.encoding != "raw":
                col = encode_host(col, lay, aux)  # zero pad = a dead code,
                # never read (masked by counts)
            if lay.encoding == "dict":
                dicts_host[name] = pad_dictionary(aux, dict_bucket(lay.card))
            buf = np.zeros((num_parts, bucket), dtype=col.dtype)
        for p in range(num_parts):
            buf[p, :counts[p]] = col[offsets[p]:offsets[p + 1]]
        host_cols[name] = buf
    return host_cols, counts, bucket, layouts, dicts_host


def _upload(host_cols, counts, num_parts: int, dicts_host):
    """Host→device placement of a partitioned layout: each ``(P, bucket)``
    column is sharded one partition-row per mesh device, so the compiled
    ``shard_map`` program consumes it with zero per-call resharding.
    Dictionaries are small and REPLICATED (every shard decodes against the
    full dictionary)."""
    import jax
    import jax.numpy as jnp

    from ..distributed.sharding import partition_sharding

    sharding = partition_sharding(num_parts)
    cols = {name: jax.device_put(jnp.asarray(buf), sharding)
            for name, buf in host_cols.items()}
    counts_dev = jax.device_put(jnp.asarray(counts), sharding)
    dicts_dev = {name: jnp.asarray(d) for name, d in dicts_host.items()}
    return cols, counts_dev, dicts_dev


def get_partitioned_columns(rel: Relation, key: str, num_parts: int,
                            sort_within: bool):
    """Partitioned device columns for ``rel``, cached on the instance.

    Returns ``(cols, counts_dev, counts, bucket, uploaded_bytes,
    logical_bytes, layouts, dicts)``: ``cols`` maps column name →
    ``(num_parts, bucket)`` device array (packed codes for compressed
    payloads) sharded over the partition mesh, ``counts_dev`` the
    per-partition row counts as a sharded ``(num_parts,)`` device array,
    ``counts`` the same on host, ``uploaded_bytes`` the physical H2D
    traffic this call actually paid (0 on a warm hit — the serving-path
    contract) and ``logical_bytes`` the same transfer priced at logical
    column width.  ``layouts`` maps name → :class:`~repro.core.
    codec_device.DeviceColumnLayout`; ``dicts`` maps ``dict``-encoded
    payload names to their replicated device dictionaries."""
    num_parts = int(num_parts)
    if num_parts < 1:
        raise ValueError(f"num_parts must be >= 1, got {num_parts}")
    tokens = tuple((name, column_token(rel[name])) for name in rel.names)
    cache_key = (key, num_parts, bool(sort_within))
    with _LOCK:
        cache = rel.__dict__.setdefault(_CACHE_ATTR, {})
        entry = cache.get(cache_key)
        if entry is not None and entry["tokens"] == tokens:
            _COUNTERS.hits += 1
            return (entry["cols"], entry["counts_dev"], entry["counts"],
                    entry["bucket"], 0, 0, entry["layouts"], entry["dicts"])
        _COUNTERS.misses += 1
    host_cols, counts, bucket, layouts, dicts_host = _build_partitions(
        rel, key, num_parts, sort_within)
    cols, counts_dev, dicts_dev = _upload(host_cols, counts, num_parts,
                                          dicts_host)
    uploaded = sum(int(b.nbytes) for b in host_cols.values()) + counts.nbytes
    uploaded += sum(int(d.nbytes) for d in dicts_host.values())
    logical = int(num_parts * bucket
                  * sum((8 if name == key else rel[name].dtype.itemsize)
                        for name in rel.names)) + int(counts.nbytes)
    with _LOCK:
        cache = rel.__dict__.setdefault(_CACHE_ATTR, {})
        current = cache.get(cache_key)
        if current is not None and current["tokens"] == tokens:
            # racing pair: keep the first insert, both transfers were real
            _COUNTERS.h2d_bytes += uploaded
            return (current["cols"], current["counts_dev"],
                    current["counts"], current["bucket"], uploaded, logical,
                    current["layouts"], current["dicts"])
        cache[cache_key] = {"tokens": tokens, "cols": cols,
                            "counts_dev": counts_dev, "counts": counts,
                            "bucket": bucket, "layouts": layouts,
                            "dicts": dicts_dev}
        _COUNTERS.h2d_bytes += uploaded
    return (cols, counts_dev, counts, bucket, uploaded, logical, layouts,
            dicts_dev)


def pending_partition_bytes(rel: Relation, key: str, num_parts: int,
                            sort_within: bool) -> int:
    """H2D bytes :func:`get_partitioned_columns` would transfer right now —
    0 when the partitioned layout is already resident (the selector's
    cache-aware cost term, mirroring ``pending_upload_bytes``).  With
    compression on this prices the PACKED layout (narrow payload codes +
    dictionaries), so the selector sees the sharded candidate's true,
    cheaper transfer."""
    num_parts = int(num_parts)
    tokens = tuple((name, column_token(rel[name])) for name in rel.names)
    with _LOCK:
        cache = rel.__dict__.get(_CACHE_ATTR)
        if cache is not None:
            entry = cache.get((key, num_parts, bool(sort_within)))
            if entry is not None and entry["tokens"] == tokens:
                return 0
    counts = partition_counts(rel, key, num_parts)
    bucket = partition_bucket(int(counts.max()) if len(counts) else 0)
    per_row = 0
    dict_bytes = 0
    if compress_enabled():
        from .table_cache import column_layout

        for name in rel.names:
            if name == key:
                per_row += 8
                continue
            lay = column_layout(rel, name)[0]
            per_row += lay.code_itemsize
            if lay.encoding == "dict":
                dict_bytes += dict_bucket(lay.card) * lay.logical_itemsize
    else:
        per_row = sum((8 if name == key else rel[name].dtype.itemsize)
                      for name in rel.names)
    return (int(num_parts * bucket * per_row) + dict_bytes
            + int(counts.nbytes))
