"""Logical-plan IR: what a query MEANS, decoupled from how it executes.

The seed front-end was the physical operator tree itself — users hand-built
``Scan/Filter/Join/Sort/Aggregate`` dataclasses, so the shape the engine
executed was exactly the shape the user typed, and *representation timing*
(the paper's core concern) was fixed at plan-assembly time.  The logical
layer breaks that coupling:

  * logical nodes (``LScan``, ``LFilter``, ``LProject``, ``LJoin``,
    ``LSort``, ``LAggregate``, ``LGroupBy``) describe intent; the rewrite
    planner (:mod:`repro.core.planner`) decides operator placement, column
    movement, and fragment boundaries *late*, against the actual relations;
  * filter predicates are preferably :class:`repro.core.expr.Expr` trees —
    introspectable (pushdown, pruning, canonical cache tokens) — but opaque
    callables remain accepted so every legacy plan still lowers;
  * :func:`from_physical` is the lowering shim: any seed-style physical
    dataclass tree converts to the IR, executes through the planner, and
    produces identical results (the executor also keeps its direct walk, so
    legacy call sites are untouched either way).

Schemas follow the engine's join naming contract: a join serves the probe
side's columns under their own names and the build side's non-key columns
prefixed ``b_``; name collisions resolve the same way the physical engine's
dict-merge does (the build column wins).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple, Union

from .relation import Relation

__all__ = ["LScan", "LFilter", "LProject", "LJoin", "LSort", "LAggregate",
           "LGroupBy", "LogicalNode", "schema", "is_scalar", "from_physical"]


@dataclasses.dataclass
class LScan:
    """Leaf: a named base relation."""

    relation: Relation
    name: str = "scan"


@dataclasses.dataclass
class LFilter:
    """Row selection.  ``predicate`` is an :class:`~repro.core.expr.Expr`
    (introspectable — the planner can push it down and prune around it) or
    any legacy callable ``view -> bool mask`` (kept in place, opaque)."""

    child: "LogicalNode"
    predicate: Union[Callable, object]


@dataclasses.dataclass
class LProject:
    """Column subset (declared projection; the planner also derives implicit
    projections from column usage)."""

    child: "LogicalNode"
    columns: Tuple[str, ...]


@dataclasses.dataclass
class LJoin:
    """Equi-join on one or more same-named key columns.

    Multi-key joins are a logical-only concept: the planner lowers them to a
    single-key physical join via key packing (see
    :func:`repro.core.planner.pack_pair`).
    """

    build: "LogicalNode"
    probe: "LogicalNode"
    on: Tuple[str, ...]


@dataclasses.dataclass
class LSort:
    child: "LogicalNode"
    keys: Tuple[str, ...]


@dataclasses.dataclass
class LAggregate:
    """Scalar reduction root (sum | count | min | max)."""

    child: "LogicalNode"
    column: str
    fn: str = "sum"


@dataclasses.dataclass
class LGroupBy:
    child: "LogicalNode"
    key: str
    values: Dict[str, str]  # column -> agg fn


LogicalNode = Union[LScan, LFilter, LProject, LJoin, LSort, LAggregate,
                    LGroupBy]


def join_schema(build_s: Sequence[str], probe_s: Sequence[str],
                on: Sequence[str]) -> Tuple[str, ...]:
    """Output schema of a join: probe columns, then ``b_``-prefixed build
    columns (key columns served once, from the probe side).  Mirrors the
    physical engine's dict merge, including its collision rule."""
    out = list(probe_s)
    for n in build_s:
        if n in on:
            continue
        bn = f"b_{n}"
        if bn not in out:
            out.append(bn)
    return tuple(out)


def schema(node: LogicalNode) -> Tuple[str, ...]:
    """Output column names of a logical node (``()`` for a scalar root)."""
    if isinstance(node, LScan):
        return node.relation.names
    if isinstance(node, (LFilter, LSort)):
        return schema(node.child)
    if isinstance(node, LProject):
        return tuple(node.columns)
    if isinstance(node, LJoin):
        return join_schema(schema(node.build), schema(node.probe), node.on)
    if isinstance(node, LAggregate):
        return ()
    if isinstance(node, LGroupBy):
        return (node.key,) + tuple(f"{fn}_{c}" for c, fn in node.values.items())
    raise TypeError(f"not a logical node: {node!r}")


def is_scalar(node: LogicalNode) -> bool:
    return isinstance(node, LAggregate)


def from_physical(plan) -> LogicalNode:
    """Lowering shim: seed-style physical dataclass trees → logical IR.

    Opaque predicates survive as-is (the planner keeps them in place); every
    structural node maps one-to-one, so a lowered-then-planned legacy tree
    executes the same operators over the same inputs.
    """
    from .executor import (Aggregate, Filter, GroupBy, Join, Project, Scan,
                           Sort)

    if isinstance(plan, Scan):
        return LScan(plan.relation, plan.name)
    if isinstance(plan, Filter):
        return LFilter(from_physical(plan.child), plan.predicate)
    if isinstance(plan, Project):
        return LProject(from_physical(plan.child), tuple(plan.columns))
    if isinstance(plan, Join):
        return LJoin(from_physical(plan.build), from_physical(plan.probe),
                     (plan.key,))
    if isinstance(plan, Sort):
        return LSort(from_physical(plan.child), tuple(plan.keys))
    if isinstance(plan, Aggregate):
        return LAggregate(from_physical(plan.child), plan.column, plan.fn)
    if isinstance(plan, GroupBy):
        return LGroupBy(from_physical(plan.child), plan.key,
                        dict(plan.values))
    raise TypeError(f"cannot lower {type(plan).__name__} to the logical IR")
