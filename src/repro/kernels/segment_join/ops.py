"""Jit'd wrappers: segment sum + fused aggregate join on the kernel path."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import segment_sum_pallas

__all__ = ["segment_sum", "join_aggregate_kernel"]


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("num_segments", "tblk", "interpret"))
def segment_sum(seg_ids, values, num_segments: int, tblk: int = 2048,
                interpret=None):
    return segment_sum_pallas(seg_ids.astype(jnp.int32),
                              values.astype(jnp.float32), num_segments,
                              tblk=min(tblk, seg_ids.shape[0]),
                              interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("num_segments", "interpret"))
def join_aggregate_kernel(build_keys, build_vals, probe_keys, probe_vals,
                          num_segments: int, interpret=None):
    """Σ over (virtual) join pairs of b·p — join output never materialized."""
    sb = segment_sum(build_keys, build_vals, num_segments, interpret=interpret)
    sp = segment_sum(probe_keys, probe_vals, num_segments, interpret=interpret)
    cb = segment_sum(build_keys, jnp.ones_like(build_vals, jnp.float32),
                     num_segments, interpret=interpret)
    cp = segment_sum(probe_keys, jnp.ones_like(probe_vals, jnp.float32),
                     num_segments, interpret=interpret)
    return {"count": jnp.dot(cb, cp), "sum_prod": jnp.dot(sb, sp),
            "sum_add": jnp.dot(sb, cp) + jnp.dot(cb, sp)}
