"""Pallas TPU kernel: bitonic tile sort (stable via index tie-break).

The tensor-path sort (§IV.B) runs stable per-axis passes; its run-generation
stage sorts tiles that fit VMEM.  This kernel is that stage: each grid step
sorts one tile of (key, payload) pairs entirely in VMEM with a bitonic
network — log²(n)/2 vectorized compare-exchange sweeps, no HBM round trips.
Stability comes from tie-breaking on the payload when payloads are the
original indices (the composite (key, idx) is unique, making bitonic —
normally unstable — order-preserving).

Inter-tile merging stays in XLA (jnp) — the classic two-level sort: VMEM
bitonic runs + a merge pass, mirroring how the linear engine generates
work_mem-sized runs before its disk merge (but here runs are VMEM-sized and
the merge never leaves HBM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitonic_tile_sort_pallas"]


def _composite_gt(k_a, i_a, k_b, i_b):
    return (k_a > k_b) | ((k_a == k_b) & (i_a > i_b))


def _bitonic_kernel(key_ref, val_ref, okey_ref, oval_ref, *, n):
    keys = key_ref[...]
    vals = val_ref[...]
    idx = jax.lax.iota(jnp.int32, n)
    stages = int(math.log2(n))
    for k_exp in range(1, stages + 1):
        for j_exp in range(k_exp - 1, -1, -1):
            j = 1 << j_exp
            partner = idx ^ j
            pk = jnp.take(keys, partner)
            pv = jnp.take(vals, partner)
            is_lower = (idx & j) == 0
            asc = (idx & (1 << k_exp)) == 0
            lo_k = jnp.where(is_lower, keys, pk)
            lo_v = jnp.where(is_lower, vals, pv)
            hi_k = jnp.where(is_lower, pk, keys)
            hi_v = jnp.where(is_lower, pv, vals)
            swap = _composite_gt(lo_k, lo_v, hi_k, hi_v) == asc
            keys = jnp.where(swap, pk, keys)
            vals = jnp.where(swap, pv, vals)
    okey_ref[...] = keys
    oval_ref[...] = vals


def bitonic_tile_sort_pallas(keys, vals, *, tile: int = 1024,
                             interpret: bool = False):
    """keys/vals [N] (N % tile == 0, tile a power of 2).  Sorts each tile
    independently (ascending, stable when vals are unique indices)."""
    n = keys.shape[0]
    tile = min(tile, n)
    assert n % tile == 0 and tile & (tile - 1) == 0, (n, tile)
    kernel = functools.partial(_bitonic_kernel, n=tile)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(keys.shape, keys.dtype),
            jax.ShapeDtypeStruct(vals.shape, vals.dtype),
        ],
        interpret=interpret,
    )(keys, vals)
