"""Mamba-2 (SSD, arXiv:2405.21060) in the chunked matmul ("state-space
duality") form.

The SSD reformulation is itself a structural cousin of the paper's thesis:
instead of collapsing the sequence dimension into a strictly sequential
recurrence (the "linearized" execution of an SSM), the sequence is kept as a
chunk × intra-chunk tensor structure; intra-chunk work becomes dense matmuls
(MXU-friendly) and only the O(S/chunk) inter-chunk recurrence stays
sequential.  Decode is the classic O(1) state update — no KV cache, which is
why the 500k-context shapes are assigned to the SSM/hybrid architectures.

Layout conventions:
  x-in   [B, S, H, P]    (H = d_inner/headdim heads, P = headdim)
  dt     [B, S, H]
  A      [H]             (negative; A = -exp(a_log))
  B, C   [B, S, G, N]    (G groups broadcast over heads, N = ssm_state)
  state  [B, H, P, N]
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import init_dense, init_rmsnorm, rmsnorm

__all__ = ["init_mamba2", "mamba2_forward", "mamba2_decode", "ssd_scan", "ssd_ref"]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, *, chunk: int = 128,
             init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)
    reps = h // g
    tril = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def body(state, inp):
        xk, dtk, Bk, Ck = inp  # [b,l,h,p], [b,l,h], [b,l,g,n] ×2
        dA = dtk.astype(jnp.float32) * A  # [b,l,h] (A negative)
        dA_cum = jnp.cumsum(dA, axis=1)
        dA_sum = dA_cum[:, -1, :]  # [b,h]

        # inter-chunk: contribution of the carried state (heads grouped as
        # h = g·reps + r, matching jnp.repeat(B, reps, axis=...) ordering)
        state_g = state.reshape(b, g, reps, p, n)
        y_inter = jnp.einsum("blgn,bgrpn->blgrp",
                             Ck.astype(jnp.float32), state_g,
                             preferred_element_type=jnp.float32
                             ).reshape(b, chunk, h, p)
        y_inter = y_inter * jnp.exp(dA_cum)[..., None]

        # intra-chunk: dense masked "attention-like" matmul over positions
        CB = jnp.einsum("bign,bjgn->bgij", Ck.astype(jnp.float32),
                        Bk.astype(jnp.float32),
                        preferred_element_type=jnp.float32)  # [b,g,l,l]
        decay = jnp.exp(dA_cum[:, :, None, :] - dA_cum[:, None, :, :])  # [b,i,j,h]
        decay = jnp.where(tril[None, :, :, None], decay, 0.0)
        Gmat = (CB[:, :, None, :, :]  # [b,g,1,i,j] broadcast over reps
                .repeat(reps, axis=2)
                .reshape(b, h, chunk, chunk))
        Gmat = Gmat * decay.transpose(0, 3, 1, 2) * dtk.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhij,bjhp->bihp", Gmat, xk.astype(jnp.float32),
                             preferred_element_type=jnp.float32)

        # state update: decay carried state across the chunk, add chunk mass
        ds = jnp.exp(dA_sum[:, None, :] - dA_cum) * dtk.astype(jnp.float32)  # [b,l,h]
        ds_g = ds.reshape(b, chunk, g, reps)
        x_g = xk.astype(jnp.float32).reshape(b, chunk, g, reps, p)
        inc = jnp.einsum("blgn,blgr,blgrp->bgrpn",
                         Bk.astype(jnp.float32), ds_g, x_g,
                         preferred_element_type=jnp.float32
                         ).reshape(b, h, p, n)
        state_new = jnp.exp(dA_sum)[:, :, None, None] * state + inc
        return state_new, (y_inter + y_intra)

    final_state, yc = jax.lax.scan(body, init_state, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_ref(x, dt, A, B, C, init_state=None):
    """Sequential-oracle SSD (O(S) scan over single steps) for tests."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    reps = h // g
    state = (jnp.zeros((b, h, p, n), jnp.float32)
             if init_state is None else init_state)
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t].astype(jnp.float32) * A)  # [b,h]
        Bt = jnp.repeat(B[:, t], reps, axis=1).astype(jnp.float32)  # [b,h,n]
        Ct = jnp.repeat(C[:, t], reps, axis=1).astype(jnp.float32)
        inc = (dt[:, t].astype(jnp.float32)[:, :, None, None]
               * x[:, t].astype(jnp.float32)[..., None] * Bt[:, :, None, :])
        state = dA[:, :, None, None] * state + inc
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, Ct))
    return jnp.stack(ys, axis=1).astype(x.dtype), state


def ssd_step(x, dt, A, B, C, state):
    """Single decode step. x [B,H,P], dt [B,H], B/C [B,G,N], state [B,H,P,N]."""
    b, h, p = x.shape
    g = B.shape[1]
    reps = h // g
    dA = jnp.exp(dt.astype(jnp.float32) * A)
    Bt = jnp.repeat(B, reps, axis=1).astype(jnp.float32)
    Ct = jnp.repeat(C, reps, axis=1).astype(jnp.float32)
    inc = dt.astype(jnp.float32)[:, :, None, None] * x.astype(jnp.float32)[..., None] * Bt[:, :, None, :]
    state = dA[:, :, None, None] * state + inc
    y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_ch = d_inner + 2 * g * n
    return d_inner, nheads, g, n, conv_ch


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, nheads, g, n, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 8)
    dt_floor = 1e-3
    dt_init = jnp.exp(jax.random.uniform(ks[6], (nheads,), jnp.float32)
                      * (math.log(0.1) - math.log(dt_floor)) + math.log(dt_floor))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "wz": init_dense(ks[0], d, d_inner, dtype),
        "wx": init_dense(ks[1], d, d_inner, dtype),
        "wb": init_dense(ks[2], d, g * n, dtype),
        "wc": init_dense(ks[3], d, g * n, dtype),
        "wdt": init_dense(ks[4], d, nheads, dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(1.0 + 15.0 * jax.random.uniform(ks[5], (nheads,), jnp.float32)),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "conv_w": (jax.random.normal(ks[7], (cfg.conv_width, conv_ch), jnp.float32)
                   * (1.0 / math.sqrt(cfg.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm": init_rmsnorm(d_inner, dtype),
        "wo": init_dense(jax.random.fold_in(key, 99), d_inner, d, dtype),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv via shifted adds. u [B,S,C], w [W,C], b [C]."""
    W = w.shape[0]
    out = u * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, : u.shape[1], :]
        out = out + shifted * w[W - 1 - i]
    return jax.nn.silu(out + b)


def mamba2_forward(params, x, cfg, *, chunk: int = 128,
                   seq_chunk: int = 2048):
    """x [B,S,d] -> (y [B,S,d], (conv_state, ssd_state)) for cache priming.

    Fully chunked over the sequence: projections, the causal conv (tail
    carried between chunks) and the SSD recurrence all run inside one scan,
    so peak memory is O(B · seq_chunk · d_inner) regardless of S — at
    Jamba-scale (d_inner 16k, S 32k) the unchunked formulation held ~5 copies
    of a 4.4 GB tensor per layer.
    """
    B_, S, _ = x.shape
    d_inner, nheads, g, n, conv_ch = _dims(cfg)
    W = cfg.conv_width
    A = -jnp.exp(params["a_log"])
    seq_chunk = min(seq_chunk, S)
    assert S % seq_chunk == 0, (S, seq_chunk)
    nsc = S // seq_chunk
    xs = x.reshape(B_, nsc, seq_chunk, x.shape[-1]).transpose(1, 0, 2, 3)

    def body(carry, xc):
        conv_tail, state = carry  # [B, W-1, C], [B, H, P, N]
        z = xc @ params["wz"]
        u_new = jnp.concatenate(
            [xc @ params["wx"], xc @ params["wb"], xc @ params["wc"]], axis=-1)
        u_ext = jnp.concatenate([conv_tail, u_new], axis=1)  # [B, W-1+sc, C]
        conv_out = u_ext[:, W - 1:, :] * params["conv_w"][W - 1]
        for i in range(1, W):
            conv_out = conv_out + u_ext[:, W - 1 - i:-i, :] * params["conv_w"][W - 1 - i]
        conv_out = jax.nn.silu(conv_out + params["conv_b"])
        new_tail = u_ext[:, -(W - 1):, :]
        xin, Bssm, Cssm = jnp.split(conv_out, [d_inner, d_inner + g * n], axis=-1)
        dt = jax.nn.softplus(
            (xc @ params["wdt"]).astype(jnp.float32) + params["dt_bias"])
        xh = xin.reshape(B_, seq_chunk, nheads, cfg.ssm_headdim)
        y, state = ssd_scan(xh, dt, A,
                            Bssm.reshape(B_, seq_chunk, g, n),
                            Cssm.reshape(B_, seq_chunk, g, n),
                            chunk=chunk, init_state=state)
        y = y + params["d_skip"][:, None].astype(y.dtype) * xh
        y = y.reshape(B_, seq_chunk, d_inner)
        y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
        return (new_tail, state), y @ params["wo"]

    tail0 = jnp.zeros((B_, W - 1, conv_ch), x.dtype)
    state0 = jnp.zeros((B_, nheads, cfg.ssm_headdim, n), jnp.float32)
    (conv_state, state), ys = jax.lax.scan(body, (tail0, state0), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B_, S, x.shape[-1])
    return y, (conv_state, state)


def mamba2_decode(params, x, cfg, conv_state, ssd_state):
    """One token. x [B,1,d]; conv_state [B,W-1,C]; ssd_state [B,H,P,N]."""
    B_ = x.shape[0]
    d_inner, nheads, g, n, conv_ch = _dims(cfg)
    xt = x[:, 0, :]
    z = xt @ params["wz"]
    u_new = jnp.concatenate(
        [xt @ params["wx"], xt @ params["wb"], xt @ params["wc"]], axis=-1)
    window = jnp.concatenate([conv_state, u_new[:, None, :]], axis=1)  # [B,W,C]
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32))
        + params["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_conv_state = window[:, 1:, :]
    xin, Bssm, Cssm = jnp.split(conv_out, [d_inner, d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(
        (xt @ params["wdt"]).astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    y, ssd_state = ssd_step(
        xin.reshape(B_, nheads, cfg.ssm_headdim), dt, A,
        Bssm.reshape(B_, g, n), Cssm.reshape(B_, g, n), ssd_state)
    y = y + params["d_skip"][:, None].astype(y.dtype) * xin.reshape(B_, nheads, cfg.ssm_headdim)
    y = y.reshape(B_, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return (y @ params["wo"])[:, None, :], (new_conv_state, ssd_state)
