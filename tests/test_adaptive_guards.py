"""Mid-query adaptive re-planning: execution-time guards and switches.

Covers the PR-9 tentpole contracts: guard-band hysteresis (a borderline
operator switches at most once, never oscillates), loss-free takeovers
(bit-for-bit results, balanced spill/tier books, reused partitions
byte-accounted), profile hygiene (a switched hybrid run never pollutes a
pure path's runtime-profile cell), and the chaos hammer — switches under
concurrent governed serving with fault injection keep every ledger
invariant."""
import numpy as np
import pytest

from repro.core import (FaultInjector, Relation, Session, TierConfig,
                        QueryServer)
from repro.core.cost_model import CostModel
from repro.core.guards import ExecutionGuard, SwitchPoint

MB = 1 << 20
STALE = 0.02  # fig14's mis-calibration: linear priced ~50x too cheap


def star_tables(n=250_000, seed=14):
    rng = np.random.default_rng(seed)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
    return build, probe


def stale_session(wm=256 * 1024, guards=True, **kw):
    """An auto session whose one-shot decision is mispriced toward the
    linear spill cliff — the premature lock-in the guards exist to undo."""
    s = Session(work_mem=wm, policy="auto", guards=guards, **kw)
    s.selector.model.c.linear_row_cost *= STALE
    s.selector.model.c.io_byte_cost *= STALE
    return s


def run_join(s, build, probe):
    s.register("b", build).register("p", probe)
    return (s.table("p").join("b", on="k").aggregate("b_v", "sum")).collect()


# ---------------------------------------------------------------------------
# Guard-band hysteresis: unit level
# ---------------------------------------------------------------------------

class _Spill:
    def __init__(self, written=0, live=0):
        self.bytes_written = written
        self.live_bytes = live


def _guard(**kw):
    kw.setdefault("op", "hash_join")
    kw.setdefault("t_linear", 1e-6)   # everything drifts immediately
    kw.setdefault("t_tensor", 1e-3)
    kw.setdefault("predicted_spill_bytes", 0)
    kw.setdefault("rows_in", 1 << 20)
    return ExecutionGuard(CostModel(), **kw)


def test_borderline_guard_never_fires():
    """Inside the hysteresis margin the guard stays put — the operator
    drifted (unpredicted spill) but the tiny remaining work can never pay
    the fixed switch cost, so 50 consecutive checkpoints all decline."""
    g = _guard(t_linear=10.0)  # wall never crosses the band; the spill does
    spill = _Spill(written=1 << 20, live=1 << 10)
    for _ in range(50):
        g.checkpoint(done=[], pending=[("b", "p", 4, 4)], spill=spill,
                     schema_hint=None)
    assert g.checkpoints == 50 and not g.fired


def test_profitable_guard_fires_exactly_once():
    g = _guard()
    spill = _Spill(written=64 * MB, live=64 * MB)
    pending = [("b", "p", 200_000, 200_000)] * 8
    with pytest.raises(SwitchPoint) as si:
        for _ in range(50):
            g.checkpoint(done=[], pending=pending, spill=spill,
                         schema_hint=None)
    assert g.checkpoints == 1 and g.fired
    assert si.value.op == "hash_join" and not si.value.restart
    # disarmed: the same drifted state can never fire a second switch
    for _ in range(50):
        g.checkpoint(done=[], pending=pending, spill=spill,
                     schema_hint=None)
    assert not any(m for m in [])  # no exception escaped the loop above


def test_restart_checkpoint_respects_allow_restart():
    g = _guard(allow_restart=False)
    spill = _Spill(written=64 * MB, live=64 * MB)
    for _ in range(20):
        g.checkpoint_partition(rows_done=100_000, rows_total=1 << 21,
                               files=["a", "b"], spill=spill)
    assert not g.fired
    g2 = _guard()
    with pytest.raises(SwitchPoint) as si:
        for _ in range(20):
            g2.checkpoint_partition(rows_done=100_000, rows_total=1 << 21,
                                    files=["a", "b"], spill=spill)
    assert si.value.restart and si.value.pending == ["a", "b"]


def test_disabled_guard_is_a_plain_token():
    g = _guard(enabled=False)
    spill = _Spill(written=64 * MB, live=64 * MB)
    g.checkpoint(done=[], pending=[("b", "p", 10 ** 6, 10 ** 6)] * 8,
                 spill=spill, schema_hint=None)
    g.checkpoint_partition(rows_done=1, rows_total=1 << 21, files=[],
                           spill=spill)
    g.checkpoint_sort(pending=["r"] * 8, spill=spill)
    g.check()  # PreemptToken protocol with no wrapped token: no-op
    assert not g.fired


# ---------------------------------------------------------------------------
# Loss-free switches: end to end
# ---------------------------------------------------------------------------

def _switched_metrics(res):
    return [m for m in res.metrics if m.switched]


def test_restart_switch_is_bit_for_bit():
    build, probe = star_tables(120_000)
    ref = run_join(Session(work_mem=64 * MB, policy="linear"), build, probe)
    res = run_join(stale_session(), build, probe)
    sw = _switched_metrics(res)
    assert len(sw) == 1, [m.op for m in res.metrics]
    m = sw[0]
    assert res.scalar == ref.scalar
    assert m.path == "tensor" and m.pre_switch_path == "linear"
    assert m.pre_switch_wall_s > 0
    assert m.wall_s >= m.pre_switch_wall_s
    # mid-partition restart reuses nothing; the partial spill is deleted
    # and the books balance
    assert m.spill.live_bytes == 0
    assert m.spill.bytes_written == m.spill.bytes_freed


def test_pair_boundary_switch_reuses_spilled_partitions():
    """With restarts disabled the guard can only fire at a pair boundary,
    where the takeover reads the already-spilled partitions back instead
    of re-partitioning — and those bytes are accounted as reused."""
    build, probe = star_tables(250_000)
    ref = run_join(Session(work_mem=64 * MB, policy="linear"), build, probe)
    s = stale_session()
    # eager hysteresis: whether a pair-boundary switch is *profitable* is
    # machine-dependent (page-cache warmth moves the observed per-pair
    # rate across the gate); this test pins the reuse ACCOUNTING, so take
    # the switch whenever the guard band is crossed
    s.selector.model.c.guard_hysteresis = 0.25
    orig = s.selector.make_guard

    def no_restart_guard(*a, **kw):
        g = orig(*a, **kw)
        if g is not None and hasattr(g, "allow_restart"):
            g.allow_restart = False
        return g

    s.selector.make_guard = no_restart_guard
    res = run_join(s, build, probe)
    sw = _switched_metrics(res)
    assert len(sw) == 1, [m.op for m in res.metrics]
    m = sw[0]
    assert res.scalar == ref.scalar
    assert m.reused_spill_bytes > 0
    # every reused byte went through the spill reader on the same account
    assert m.spill.bytes_read >= m.reused_spill_bytes
    # all temp files released: nothing leaks past the switch
    assert m.spill.live_bytes == 0
    assert m.spill.bytes_written == m.spill.bytes_freed


def test_sort_switch_is_loss_free():
    n = 200_000
    rng = np.random.default_rng(3)
    rel = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                    "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
    ref = Session(work_mem=64 * MB, policy="linear")
    ref.register("t", rel)
    want = ref.table("t").sort("k", "w").collect().relation
    s = stale_session(wm=128 * 1024)
    s.register("t", rel)
    res = s.table("t").sort("k", "w").collect()
    sw = _switched_metrics(res)
    assert len(sw) == 1, [m.op for m in res.metrics]
    assert sw[0].op == "sort"
    assert sw[0].spill.live_bytes == 0
    assert res.relation.equals(want)


def test_guards_off_never_switches():
    build, probe = star_tables(120_000)
    res = run_join(stale_session(guards=False), build, probe)
    assert not _switched_metrics(res)
    assert any(m.path in ("linear", "linear_tiered") and m.op == "hash_join"
               for m in res.metrics)


# ---------------------------------------------------------------------------
# Profile hygiene: a hybrid run enters no pure path's cell
# ---------------------------------------------------------------------------

def test_switched_run_does_not_pollute_profile():
    build, probe = star_tables(120_000)
    s = stale_session()
    res = run_join(s, build, probe)
    assert _switched_metrics(res), "scenario stopped switching; retune"
    prof = s.selector.profile
    polluted = [key for key in prof.snapshot() if key[0] == "hash_join"]
    assert not polluted, (
        f"switched hash_join recorded into profile cells {polluted}: a "
        f"part-linear part-tensor wall describes neither pure path")


# ---------------------------------------------------------------------------
# Chaos hammer: switches under governed concurrent serving + faults
# ---------------------------------------------------------------------------

def test_chaos_switch_hammer():
    """FaultInjector + memory pressure (preemption) + mid-query switches
    under an 8-worker closed-loop serve: every query is exactly one of
    served/failed, no grant ever exceeds the budget, the tier books
    balance, and every served result is bit-for-bit the ungoverned
    serial reference."""
    n = 60_000
    build, probe = star_tables(n)
    ref_sess = Session(work_mem=64 * MB)
    ref_sess.register("b", build).register("p", probe)
    expect = (ref_sess.table("p").join("b", on="k")
              .aggregate("b_v", "sum").scalar())

    srv = QueryServer(
        {"b": build, "p": probe}, total_mem=24 * MB, work_mem=512 * 1024,
        min_grant=256 * 1024, tiers=TierConfig(t1_latency_s=0.0,
                                               t1_gbps=1000.0),
        faults=FaultInjector(seed=7, spill_io_p=0.01, device_slow_p=0.05,
                             device_slow_s=0.002, grant_timeout_p=0.01,
                             spill_read_p=0.01))
    c = srv.session.selector.model.c
    c.linear_row_cost *= STALE
    c.io_byte_cost *= STALE
    c.guard_hysteresis = 0.5  # take borderline switches eagerly: the
    #                           ledger invariants must hold regardless
    q = (srv.session.table("p").join("b", on="k")
         .aggregate("b_v", "sum"))
    rep = srv.serve([q], concurrency=8, queries_per_worker=4, warmup=1)

    total = rep.counts["served"] + rep.counts["failed"]
    assert total == 8 * 4, rep.counts
    assert rep.counts["served"] > 0
    for sq in rep.queries:
        assert sq.scalar == expect  # bit-for-bit under chaos + switches
    assert rep.governor.over_budget_events == 0
    srv.session.tier_ledger.verify_balanced()
    assert sum(srv.faults.counts().values()) > 0, (
        "chaos run injected no faults; the gate would be vacuous")
    assert srv.broker.stats().switches >= 1, (
        "hammer stopped exercising mid-query switches; retune")


# ---------------------------------------------------------------------------
# Nightly: guards cost nothing when the model is right
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("n", [40_000, 120_000, 250_000])
def test_selector_regret_with_guards_stays_small(n):
    """fig9's contract, with guards armed: on a WELL-calibrated system
    the guards must be free — the auto policy makes the same decisions,
    never fires a switch, and the checkpoint polling costs no more than
    10% + fixed jitter over the identical guard-less session."""
    import time

    build, probe = star_tables(n)
    walls = {}
    for guards in (False, True):
        # one session per mode, like fig9: warm reps converge the
        # compile cache, device column cache and runtime profile
        s = Session(work_mem=8 * MB, policy="auto", guards=guards)
        s.register("b", build).register("p", probe)
        ts = []
        for rep in range(6):
            t0 = time.perf_counter()
            res = (s.table("p").join("b", on="k")
                   .aggregate("b_v", "sum")).collect()
            if rep >= 2:  # first reps absorb compiles and feedback lag
                ts.append(time.perf_counter() - t0)
            assert not any(m.switched for m in res.metrics), (
                "guard fired on a well-calibrated decision")
        walls[guards] = sorted(ts)[len(ts) // 2]
    assert walls[True] <= walls[False] * 1.10 + 0.010, walls
