"""Pure-jnp oracle for the MoE dispatch/combine kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dispatch_ref", "combine_ref"]


def dispatch_ref(x, eidx, slot, num_experts: int, capacity: int):
    """x [T,d]; eidx/slot [T] → buf [E, C, d] (slots >= capacity dropped)."""
    keep = slot < capacity
    onehot_e = jax.nn.one_hot(eidx, num_experts, dtype=x.dtype)
    onehot_c = jax.nn.one_hot(jnp.where(keep, slot, capacity), capacity,
                              dtype=x.dtype)
    mask = onehot_e[:, :, None] * onehot_c[:, None, :]          # [T, E, C]
    return jnp.einsum("tec,td->ecd", mask, x)


def combine_ref(buf, eidx, slot, w):
    """buf [E,C,d]; eidx/slot/w [T] → y [T, d]."""
    E, C, _ = buf.shape
    keep = slot < C
    onehot_e = jax.nn.one_hot(eidx, E, dtype=buf.dtype)
    onehot_c = jax.nn.one_hot(jnp.where(keep, slot, C), C, dtype=buf.dtype)
    mask = onehot_e[:, :, None] * onehot_c[:, None, :] * w[:, None, None].astype(buf.dtype)
    return jnp.einsum("tec,ecd->td", mask, buf)
