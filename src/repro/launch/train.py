"""Training driver.

Runs real training at reduced scale on whatever devices exist (CPU here), or
lowers the production config under the dry-run mesh.  The loop wires together
every substrate: data pipeline (relational preprocessing through the paper's
dual-path engine), trainer, checkpointing with resume, and the resilient-loop
fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=10)
    ap.add_argument("--moe-dispatch", default="auto")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import DataPipeline, PipelineConfig
    from repro.models import init_model
    from repro.train.checkpoint import Checkpointer, latest_step, restore_checkpoint
    from repro.train.optimizer import make_optimizer
    from repro.train.trainer import TrainPolicy, make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"(active {cfg.active_param_count() / 1e6:.1f}M)")

    policy = TrainPolicy(moe_dispatch=args.moe_dispatch, remat=False)
    opt = make_optimizer("adamw", lr=args.lr)
    step_fn = jax.jit(make_train_step(cfg, opt, policy))

    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    opt_state = opt.init(params)
    start = 0
    ckpt = Checkpointer(args.ckpt_dir, args.ckpt_interval) if args.ckpt_dir else None
    if ckpt and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    pipe = DataPipeline(PipelineConfig(
        num_docs=4000, vocab=cfg.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch))
    pipe.restore({"consumed": start, "seed": 0})
    it = iter(pipe)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(it)
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"|g| {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0):.1f}s)")
        if ckpt:
            ckpt.maybe_save(step + 1, (params, opt_state))
    tokens = (args.steps - start) * args.batch * args.seq_len
    dt = time.time() - t0
    print(f"done: {tokens} tokens in {dt:.1f}s "
          f"({tokens / max(dt, 1e-9):.0f} tok/s)")


if __name__ == "__main__":
    main()
