"""Attention: chunked (online-softmax) attention, GQA, MLA, local/global.

``chunked_attention`` is the pure-JAX flash-attention analogue: a scan over
KV chunks (and an outer scan over query chunks) with running max/denominator,
so peak memory is O(q_chunk · kv_chunk) per head instead of O(S²).  This is
what makes 32k-prefill lowering memory-sane; the Pallas kernel path (see
repro.kernels) targets the same contract on real TPUs.

MLA (DeepSeek-V2) keeps the compressed kv_lora cache and uses the *absorbed*
formulation at decode time: scores contract directly against the compressed
cache (rank+rope per token, 576 B vs 4 KB for equivalent GQA), which also
shards cleanly: the contraction dim is split over the "model" mesh axis and
GSPMD completes it with an all-reduce.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import apply_rope, init_dense, init_rmsnorm, rmsnorm, softcap
from .pspec import constrain, constrain_kv_cache

__all__ = [
    "chunked_attention", "decode_attention",
    "init_gqa", "gqa_forward", "gqa_decode",
    "init_mla", "mla_forward", "mla_decode",
]

_NEG_INF = -1e30


def chunked_attention(
    q: jnp.ndarray,            # [B, Sq, H, D]
    k: jnp.ndarray,            # [B, Sk, KH, D]
    v: jnp.ndarray,            # [B, Sk, KH, Dv]
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    scale: Optional[float] = None,
    q_chunk: int = 256,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Flat-H layout: GQA KV heads are repeated per KV *chunk* (chunk-sized
    copies only), so every score/accumulator tensor carries a single H axis
    that divides the "model" mesh axis.  With the factored (KH, G) layout
    GSPMD cannot tile the head product and silently REPLICATES the batch dim
    across the data axis inside the scan state — ~16× the attention-residual
    footprint at mesh scale (measured; see EXPERIMENTS.md §Dry-run)."""
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, q_chunk, Sk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    q = constrain(q, "dp", None, "model", None)
    k = constrain(k, "dp", None, None, None)
    v = constrain(v, "dp", None, None, None)
    qg = q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nk, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, KH, Dv).transpose(1, 0, 2, 3, 4)

    def q_block(_, inp):
        qi, qblk = inp  # qblk: [B, qc, H, D]
        pos_q = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kv_inp):
            m, l, acc = carry
            ki, kblk, vblk = kv_inp
            if G > 1:  # repeat KV heads: chunk-sized, keeps H axis flat
                kblk = jnp.repeat(kblk, G, axis=2)
                vblk = jnp.repeat(vblk, G, axis=2)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qblk, kblk,
                preferred_element_type=jnp.float32) * scale
            s = softcap(s, cap)
            s = constrain(s, "dp", "model", None, None)
            pos_k = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), dtype=bool)
            if causal:
                mask &= pos_q[:, None] >= pos_k[None, :]
            if window is not None:
                mask &= (pos_q[:, None] - pos_k[None, :]) < window
            s = jnp.where(mask, s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = constrain(jnp.full((B, H, q_chunk), _NEG_INF, jnp.float32),
                       "dp", "model", None)
        l0 = constrain(jnp.zeros((B, H, q_chunk), jnp.float32),
                       "dp", "model", None)
        a0 = constrain(jnp.zeros((B, H, q_chunk, Dv), jnp.float32),
                       "dp", "model", None, None)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out  # [B, H, qc, Dv]

    _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qg))
    # blocks: [nq, B, H, qc, Dv] -> [B, Sq, H, Dv]
    out = blocks.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,            # [B, 1, H, D]
    k_cache: jnp.ndarray,      # [B, S, KH, D]
    v_cache: jnp.ndarray,      # [B, S, KH, Dv]
    cur_pos: jnp.ndarray,      # scalar int: position of the new token
    *,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, _, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    # pin batch sharding: GSPMD otherwise batch-replicates the [B,·,·,S]
    # score/probability tensors at 32k–500k context
    s = constrain(s, "dp", None, None, None)
    s = softcap(s, cap)
    pos_k = jnp.arange(S)
    mask = pos_k <= cur_pos
    if window is not None:
        mask &= (cur_pos - pos_k) < window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = constrain(p, "dp", None, None, None)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, dtype=jnp.float32):
    d, H, KH, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, H * Dh, dtype),
        "wk": init_dense(ks[1], d, KH * Dh, dtype),
        "wv": init_dense(ks[2], d, KH * Dh, dtype),
        "wo": init_dense(ks[3], H * Dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((KH * Dh,), dtype)
        p["bv"] = jnp.zeros((KH * Dh,), dtype)
    return p


def _gqa_qkv(params, x, cfg, sin, cos):
    B, S, _ = x.shape
    H, KH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KH, Dh)
    v = v.reshape(B, S, KH, Dh)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def gqa_forward(params, x, cfg, sin, cos, *, window=None, is_causal=True,
                q_chunk=256, kv_chunk=1024):
    B, S, _ = x.shape
    q, k, v = _gqa_qkv(params, x, cfg, sin, cos)
    out = chunked_attention(
        q, k, v, causal=is_causal, window=window, cap=cfg.attn_logit_softcap,
        q_chunk=q_chunk, kv_chunk=kv_chunk)
    return out.reshape(B, S, cfg.num_heads * cfg.head_dim) @ params["wo"], (k, v)


class GQACacheUpdate(NamedTuple):
    k: jnp.ndarray  # [B, 1, KH, D]
    v: jnp.ndarray


def gqa_decode(params, x, cfg, sin, cos, k_cache, v_cache, cur_pos, *, window=None):
    """x: [B, 1, d]; caches [B, S, KH, D] already containing history.

    Returns (out, (k_new, v_new)) — the caller owns the cache write (so the
    cache update stays inside the jitted serve_step's dynamic_update_slice).
    """
    B = x.shape[0]
    q, k, v = _gqa_qkv(params, x, cfg, sin, cos)
    zero = jnp.zeros((), jnp.int32)
    pos32 = jnp.asarray(cur_pos, jnp.int32)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (zero, pos32, zero, zero))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (zero, pos32, zero, zero))
    # keep the cache in its canonical sharding through the in-place update —
    # otherwise GSPMD may re-layout (copy!) the whole multi-GB cache per step
    k_cache = constrain_kv_cache(k_cache)
    v_cache = constrain_kv_cache(v_cache)
    out = decode_attention(q, k_cache, v_cache, cur_pos, window=window,
                           cap=cfg.attn_logit_softcap)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim) @ params["wo"]
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    rank, nope, rp, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": init_dense(ks[0], d, H * (nope + rp), dtype),
        "w_dkv": init_dense(ks[1], d, rank + rp, dtype),
        "kv_norm": init_rmsnorm(rank, dtype),
        "w_uk": init_dense(ks[2], rank, H * nope, dtype),
        "w_uv": init_dense(ks[3], rank, H * vd, dtype),
        "wo": init_dense(ks[4], H * vd, d, dtype),
    }


def _mla_q(params, x, cfg, sin, cos):
    B, S, _ = x.shape
    H, nope, rp = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = (x @ params["wq"]).reshape(B, S, H, nope + rp)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _mla_ckv(params, x, cfg, sin, cos):
    rank, rp = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv = x @ params["w_dkv"]
    c, k_rope = ckv[..., :rank], ckv[..., rank:]
    c = rmsnorm(params["kv_norm"], c, cfg.norm_eps)
    # shared (single-head) rope key
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]
    return c, k_rope


def mla_forward(params, x, cfg, sin, cos, *, q_chunk=256, kv_chunk=1024):
    """Training/prefill MLA: expand k/v per head, chunked attention."""
    B, S, _ = x.shape
    H, nope, rp, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(params, x, cfg, sin, cos)
    c, k_rope = _mla_ckv(params, x, cfg, sin, cos)
    k_nope = (c @ params["w_uk"]).reshape(B, S, H, nope)
    v = (c @ params["w_uv"]).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rp))], axis=-1)
    out = chunked_attention(
        q, k, v, causal=True, scale=1.0 / math.sqrt(nope + rp),
        q_chunk=q_chunk, kv_chunk=kv_chunk)
    cache = jnp.concatenate([c, k_rope], axis=-1)  # compressed cache entry
    return out.reshape(B, S, H * vd) @ params["wo"], cache


def mla_decode(params, x, cfg, sin, cos, ckv_cache, cur_pos):
    """Absorbed-MLA decode against the compressed cache [B, S, rank+rope]."""
    B = x.shape[0]
    H, nope, rp, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(params, x, cfg, sin, cos)       # [B,1,H,*]
    c_new, k_rope_new = _mla_ckv(params, x, cfg, sin, cos)  # [B,1,rank],[B,1,rp]
    entry = jnp.concatenate([c_new, k_rope_new], axis=-1).astype(ckv_cache.dtype)
    zero = jnp.zeros((), jnp.int32)
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, entry, (zero, jnp.asarray(cur_pos, jnp.int32), zero))
    ckv_cache = constrain_kv_cache(ckv_cache)
    cache_c, cache_rope = ckv_cache[..., :rank], ckv_cache[..., rank:]

    # absorb W_uk into the query:  q_abs[b,h,r] = Σ_n q_nope[b,h,n]·W_uk[r,(h,n)]
    w_uk = params["w_uk"].reshape(rank, H, nope)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32)
    s = jnp.einsum("bhr,bsr->bhs", q_abs, cache_c.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bhp,bsp->bhs", q_rope[:, 0].astype(jnp.float32),
                    cache_rope.astype(jnp.float32))
    s = constrain(s, "dp", "model", None)
    s *= 1.0 / math.sqrt(nope + rp)
    mask = jnp.arange(ckv_cache.shape[1]) <= cur_pos
    s = jnp.where(mask[None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", p, cache_c.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    w_uv = params["w_uv"].reshape(rank, H, vd)
    out = jnp.einsum("bhr,rhv->bhv", o_c, w_uv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H * vd).astype(x.dtype) @ params["wo"]
    return out, ckv_cache
