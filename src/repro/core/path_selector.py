"""Execution-time path selection (paper §III.C), plan-level and feedback-driven.

The selector is *deliberately simple*: it looks only at indicators observable
cheaply at execution time — input scale, join-key cardinality, expected
intermediate size, and the memory budget — and asks one structural question:
**will the linear path's linearized intermediate exceed work_mem?**  If it
comfortably fits, the linear path wins (paper §V.B: at small scale the CPU
hash join is faster).  If it would spill, the regime-shift model predicts the
amplification cost α(N, M) and the tensor path is chosen when it avoids a
worse expected (and far worse tail) latency.

PR 2 adds two layers on top of the seed's per-operator, prediction-only
design:

  * **plan-level costing** — :meth:`choose_fragment` prices a whole
    ``Join→[Filter]→[Sort]→[Aggregate]`` fragment at once, so the fused
    pipeline's amortized fixed cost, single host sync, and (cache-aware) H2D
    transfer term compete against the *sum* of the linear operators, not
    against one join in isolation.  This is what removes the N=50k regret:
    per-operator costing charged the tensor path its fixed overhead three
    times and its H2D upload every query.
  * **runtime feedback** — every estimate is blended with the
    :class:`~repro.core.runtime_profile.RuntimeProfile`'s observed wall
    times for the same ``(op, path, size-bucket)``, so the crossover point
    self-corrects on hosts where the shipped constants are stale.

PR 5 adds **queue-aware pricing**: when the executor runs under a
:class:`~repro.core.resource_broker.ResourceBroker` it passes each decision
the broker's :class:`~repro.core.resource_broker.PressureQuote`\\ s — the
expected memory grant *and* expected admission wait (charged to the linear
path) plus the expected device-queue wait (charged to the tensor path).
``auto`` therefore stops choosing a small linear operator that then parks
in admission while the tensor path would run immediately, and stops piling
onto a deeply-queued device when the linear path is free.  The wait terms
are folded AFTER the feedback blend and never recorded into the profile:
load is a property of this instant's queues, not an execution cost.

Key-cardinality sampling is served by the cached sketch in
:mod:`repro.core.table_cache` — the seed re-ran a 65536-row ``np.unique``
on every ``choose_join`` call.

The selection never changes operator semantics — both paths produce identical
result sets (tests assert canonical equality).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import numpy as np

from .cost_model import CostModel
from .device_relation import DeviceRelation
from .relation import Relation
from .runtime_profile import RuntimeProfile
from .table_cache import key_stats, pending_upload_bytes

__all__ = ["Decision", "PathSelector"]

# Guards the per-relation filter-selectivity memo (concurrent sessions
# share probe relations); the sampled evaluation itself runs unlocked.
_SEL_LOCK = threading.Lock()


@dataclasses.dataclass
class Decision:
    path: str  # "linear" | "tensor"
    reason: str
    t_linear: float
    t_tensor: float
    predicted_spill_bytes: int
    h2d_bytes: int = 0  # pending upload bytes charged to the tensor estimate
    # Broker queue-wait terms folded into t_linear / t_tensor (0 when the
    # decision was priced without quotes — ungoverned, or queue-blind):
    mem_wait_s: float = 0.0  # expected memory-admission wait (linear path)
    dev_wait_s: float = 0.0  # expected device-queue wait (tensor path)
    # Device lanes the chosen tensor path should fan out over: 1 for the
    # single-device fused program, N when the sharded partition-parallel
    # program priced cheaper (requires path == "tensor").
    shards: int = 1
    # True when the linear candidate that won (or lost) was the TIERED
    # variant: its spill priced through the T0/T1/T2 staircase instead of
    # the all-disk cliff (requires a tier hierarchy on the session; the
    # executor then routes the operator's spill through the TierManager).
    tiered: bool = False


class PathSelector:
    def __init__(self, work_mem: int, cost_model: Optional[CostModel] = None,
                 force: Optional[str] = None,
                 profile: Optional[RuntimeProfile] = None,
                 tiers=None):
        self.work_mem = int(work_mem)
        self.model = cost_model or CostModel()
        if force not in (None, "linear", "tensor"):
            raise ValueError(force)
        self.force = force
        # Optional spill-tier hierarchy (a TierConfig): prices the
        # tiered-linear candidate even when a decision arrives without a
        # broker quote (ungoverned sessions).  Quotes from a tiered
        # governor carry fresher per-grant quotas and win when present.
        self.tiers = tiers
        # A fresh profile per selector by default: observations from one
        # query stream never leak into another's decisions.  Pass
        # runtime_profile.DEFAULT_PROFILE to share across executors.
        self.profile = RuntimeProfile() if profile is None else profile

    # -- broker quotes -------------------------------------------------------
    @staticmethod
    def _waits(mem_quote, dev_quote):
        """Queue-wait terms from the broker's quotes: expected memory-
        admission wait charges the LINEAR path (it is what the operator
        would stand in before its grant), expected device-queue wait
        charges the TENSOR path.  Folded AFTER the feedback blend — load is
        a property of this instant's queues, not an execution cost to
        learn."""
        mem_wait = 0.0 if mem_quote is None else float(mem_quote.expected_wait_s)
        dev_wait = 0.0 if dev_quote is None else float(dev_quote.expected_wait_s)
        return mem_wait, dev_wait

    def _resolve_wm(self, work_mem, mem_quote) -> int:
        """The work_mem this decision prices the linear path against: an
        explicit override wins, else the quote's expected grant (the
        governor's full-or-policy sizing), else the configured ceiling."""
        if work_mem is not None:
            return int(work_mem)
        if mem_quote is not None:
            return int(mem_quote.grant_bytes)
        return self.work_mem

    @staticmethod
    def _wait_note(mem_wait: float, dev_wait: float) -> str:
        if mem_wait < 1e-4 and dev_wait < 1e-4:
            return ""
        return (f"; queue-aware: +{mem_wait * 1e3:.0f}ms expected admission "
                f"wait on linear, +{dev_wait * 1e3:.0f}ms device queue on "
                f"tensor")

    # -- execution-time observables -----------------------------------------
    @staticmethod
    def _dup_estimate(build, key: str) -> float:
        """Key duplication factor from the cached cardinality sketch.

        A device-resident input is NOT sampled — pulling 64k keys to the
        host for planning would be exactly the regime-crossing round trip
        this layer exists to avoid; scale alone decides (dup ≈ 1).
        """
        if isinstance(build, DeviceRelation):
            return 1.0
        return key_stats(build, key).dup

    # -- execution-time guards (PR 9) ---------------------------------------
    def make_guard(self, decision: Decision, op: str, rows_in: int,
                   token=None, enabled: bool = True):
        """An :class:`~repro.core.guards.ExecutionGuard` re-checking this
        decision while the chosen linear operator runs.

        The selector owns re-decision policy for the same reason it owns the
        initial decision: the guard band, hysteresis margin, and switch
        pricing all come from the same :class:`CostModel` that priced the
        path in the first place, so a switch only fires when the model —
        fed *observed* drift instead of estimates — reverses its own
        verdict.  Forced decisions are never guarded (a forced path is the
        experiment's control, not a costed choice); neither are non-linear
        paths (the guard's escape hatch IS the tensor takeover).  Returns
        ``token`` unchanged when no guard applies, so the caller can pass
        the result straight through as the operator's cancel token.
        """
        if not enabled or self.force is not None or decision.path != "linear":
            return token
        from .guards import ExecutionGuard

        # the guard clocks execution wall AFTER admission; strip the folded
        # queue-wait term so drift is measured against execution cost only
        return ExecutionGuard(
            self.model, op=op,
            t_linear=max(0.0, decision.t_linear - decision.mem_wait_s),
            t_tensor=decision.t_tensor,
            predicted_spill_bytes=decision.predicted_spill_bytes,
            rows_in=rows_in, token=token)

    # -- join ---------------------------------------------------------------
    def choose_join(self, build: Relation, probe: Relation, key: str,
                    work_mem: Optional[int] = None,
                    mem_quote=None, dev_quote=None) -> Decision:
        """``work_mem`` overrides the selector's configured budget for THIS
        decision; under a shared governor the executor instead passes the
        broker's ``mem_quote`` (the grant a request would receive *right
        now* PLUS the expected admission wait) and ``dev_quote`` (expected
        device-queue wait), so contention shifts ``auto`` toward the tensor
        path both when the linear path would be squeezed into the spill
        regime AND when it would park in admission while the device is
        free."""
        if self.force:
            return Decision(self.force, "forced", 0.0, 0.0, 0)
        wm = self._resolve_wm(work_mem, mem_quote)
        mem_wait, dev_wait = self._waits(mem_quote, dev_quote)
        n_b, n_p = len(build), len(probe)
        dup = self._dup_estimate(build, key)
        est_out = int(n_p * dup)
        est = self.model.estimate_join(
            n_b, n_p, build.row_bytes(), probe.row_bytes(), est_out, wm)
        t_lin = self.profile.blend(est.t_linear, "hash_join", "linear",
                                   n_b + n_p) + mem_wait
        t_ten = self.profile.blend(est.t_tensor, "hash_join", "tensor",
                                   n_b + n_p) + dev_wait
        note = self._wait_note(mem_wait, dev_wait)
        if est.path_fits_mem and t_lin <= t_ten:
            return Decision(
                "linear",
                f"hash table fits work_mem ({wm} B); linear path has "
                f"no spill regime at this scale" + note,
                t_lin, t_ten, 0, mem_wait_s=mem_wait, dev_wait_s=dev_wait)
        path = "tensor" if t_ten < t_lin else "linear"
        return Decision(
            path,
            f"predicted spill {est.spill_bytes / 1e6:.1f} MB over {est.passes} "
            f"partition pass(es): α(N,M) makes T_linear={t_lin:.3f}s vs "
            f"T_tensor={t_ten:.3f}s (feedback-blended)" + note,
            t_lin, t_ten, est.spill_bytes,
            mem_wait_s=mem_wait, dev_wait_s=dev_wait)

    # -- sort ------------------------------------------------------------------
    def choose_sort(self, rel: Relation, keys,
                    work_mem: Optional[int] = None,
                    mem_quote=None, dev_quote=None) -> Decision:
        if self.force:
            return Decision(self.force, "forced", 0.0, 0.0, 0)
        wm = self._resolve_wm(work_mem, mem_quote)
        mem_wait, dev_wait = self._waits(mem_quote, dev_quote)
        est = self.model.estimate_sort(
            len(rel), rel.row_bytes(), len(keys), wm)
        t_lin = self.profile.blend(est.t_linear, "sort", "linear",
                                   len(rel)) + mem_wait
        t_ten = self.profile.blend(est.t_tensor, "sort", "tensor",
                                   len(rel)) + dev_wait
        note = self._wait_note(mem_wait, dev_wait)
        if est.path_fits_mem and t_lin <= t_ten:
            return Decision(
                "linear",
                "dataset fits work_mem; in-memory lexsort is cheapest" + note,
                t_lin, t_ten, 0, mem_wait_s=mem_wait, dev_wait_s=dev_wait)
        path = "tensor" if t_ten < t_lin else "linear"
        return Decision(
            path,
            f"predicted spill {est.spill_bytes / 1e6:.1f} MB / {est.passes} merge "
            f"pass(es); T_linear={t_lin:.3f}s vs T_tensor={t_ten:.3f}s" + note,
            t_lin, t_ten, est.spill_bytes,
            mem_wait_s=mem_wait, dev_wait_s=dev_wait)

    # -- fused fragment (plan-level, PR 2) ----------------------------------
    @staticmethod
    def _filter_selectivity(filter_fn, probe: Relation,
                            build=None) -> float:
        """Sampled selectivity of an introspectable (Expr) predicate.

        This is the observability the logical IR buys over opaque lambdas:
        when the predicate reads only probe-side columns, evaluating it over
        a small prefix sample predicts how many joined rows survive the
        fragment's filter — the linear path's sort/aggregate work shrinks
        accordingly.  Opaque callables (or build-side references, which
        would need the join) stay at selectivity 1.0."""
        from .expr import Expr
        from .relation import column_token

        if not isinstance(filter_fn, Expr) or not isinstance(probe, Relation):
            return 1.0  # opaque predicate, or device-resident input (no
            #             host sample without a regime-crossing fetch)
        cols = sorted(filter_fn.columns())
        if len(probe) == 0 or not (set(cols) <= set(probe.names)):
            return 1.0
        if build is not None and any(
                c.startswith("b_") and c[2:] in build.names for c in cols):
            # the join naming contract resolves this name to the BUILD side
            # (build wins collisions); the probe's same-named column is a
            # different column and would feed a wrong selectivity
            return 1.0
        # memoized like key_stats: warm serving queries must not pay a
        # per-query sample evaluation (entries shared with select() subs).
        # Same locking discipline as the other shared caches: the lock
        # guards the dict, the sample evaluation runs outside it
        tokens = tuple(column_token(probe[c]) for c in cols)
        tok = filter_fn.cache_token()
        with _SEL_LOCK:
            cache = probe.__dict__.setdefault("_sel_cache", {})
            hit = cache.get(tok)
            if hit is not None and hit[0] == tokens:
                return hit[1]
        # strided sample, not a prefix: tables sorted/clustered by the
        # filtered column (e.g. time-ordered facts filtered on recency)
        # would make a prefix systematically unrepresentative and pin the
        # selector on a mispriced path
        stride = max(1, len(probe) // 4096)
        sample = {c: probe[c][::stride] for c in cols}
        try:
            mask = np.asarray(filter_fn(sample), bool)
        except Exception:
            return 1.0
        sel = float(mask.mean()) if mask.ndim else 1.0
        with _SEL_LOCK:
            if len(cache) >= 64:
                cache.clear()  # tiny float entries; crude bound is enough
            cache[tok] = (tokens, sel)
        return sel

    def _sharded_candidate(self, spec, build, probe, max_shards: int):
        """``(shards, skew, pending_h2d)`` for the partition-parallel fused
        program, or ``(1, 1.0, 0)`` when it is not on the table: the caller
        did not opt in (``max_shards <= 1``), the mesh has a single device,
        an input is already device-resident (partitioning plans from host
        columns), or the fragment is outside the sharded path's bit-for-bit
        eligibility (:func:`repro.core.fused.sharded_supported`).  Skew and
        the pending-transfer bytes come from the partition cache's memoized
        counts — pricing stays O(1) on warm serving paths."""
        if max_shards <= 1:
            return 1, 1.0, 0
        if not (isinstance(build, Relation) and isinstance(probe, Relation)):
            return 1, 1.0, 0
        from ..distributed.sharding import available_partitions
        from .fused import sharded_supported
        from .partition import (partition_counts, partition_skew,
                                pending_partition_bytes)

        shards = min(int(max_shards), available_partitions())
        if shards <= 1 or not sharded_supported(spec, build, probe):
            return 1, 1.0, 0
        key = spec.join_key
        skew = partition_skew(partition_counts(build, key, shards))
        pend = (pending_partition_bytes(build, key, shards, True)
                + pending_partition_bytes(probe, key, shards, False))
        return shards, skew, pend

    def choose_fragment(self, spec, build: Relation, probe: Relation,
                        work_mem: Optional[int] = None,
                        mem_quote=None, dev_quote=None,
                        max_shards: int = 1) -> Decision:
        """Price a whole fusable fragment: ONE fixed dispatch, ONE host sync,
        and H2D transfer only for base-table columns not already resident in
        the device cache (warm serving queries charge 0).  Fragments arrive
        from the rewrite planner, so this prices the REWRITTEN plan — pruned
        scans carry smaller row_bytes, pushed-down filters carry sampled
        selectivity.  ``work_mem`` overrides the configured budget;
        ``mem_quote``/``dev_quote`` (broker quotes) carry the governor's
        current-grant estimate plus the expected admission/device-queue
        waits (queue-aware pricing).

        ``max_shards > 1`` additionally prices the partition-parallel
        sharded program (when the fragment is eligible): its estimate
        carries the lane fan-out, the measured partition skew, and the
        partitioned layout's own pending-transfer bytes, and its queue term
        is the GANG wait — the max over the quote's per-lane expected waits,
        because a gang dispatch blocks on its slowest lane."""
        if self.force:
            return Decision(self.force, "forced", 0.0, 0.0, 0)
        import math

        from .tensor_engine import capacity_bucket

        wm = self._resolve_wm(work_mem, mem_quote)
        mem_wait, dev_wait = self._waits(mem_quote, dev_quote)
        n_b, n_p = len(build), len(probe)
        dup = self._dup_estimate(build, spec.join_key)
        est_out = int(n_p * dup)
        h2d = (pending_upload_bytes(build, capacity_bucket(n_b))
               + pending_upload_bytes(probe, capacity_bucket(n_p)))
        shards, skew, sharded_h2d = self._sharded_candidate(
            spec, build, probe, max_shards)
        # tier staircase terms: a tiered governor's quote carries per-grant
        # quotas + per-byte service times; an ungoverned tiered session
        # derives them from the configured hierarchy
        tq = getattr(mem_quote, "tier_quotas", None)
        tbs = getattr(mem_quote, "tier_byte_s", None)
        if tq is None and self.tiers is not None:
            cap0 = int(self.tiers.t0_capacity)
            tq = (min(cap0, max(2 * wm, cap0 // 2)),
                  self.tiers.t1_capacity, None)
            tbs = self.tiers.byte_costs()
        est = self.model.estimate_fragment(
            n_b, n_p, build.row_bytes(), probe.row_bytes(), est_out,
            wm, num_sort_keys=len(spec.sort_keys),
            has_filter=spec.filter_fn is not None,
            has_agg=spec.agg is not None, h2d_bytes=h2d,
            filter_selectivity=self._filter_selectivity(spec.filter_fn,
                                                        probe, build),
            device_count=shards, partition_skew=skew,
            sharded_h2d_bytes=sharded_h2d,
            tier_quotas=tq, tier_byte_s=tbs)
        n = n_b + n_p
        t_lin = self.profile.blend(est.t_linear, "fragment", "linear",
                                   n) + mem_wait
        # Tiered-linear as a DISTINCT candidate with its own profile cell:
        # same CPU work, spill routed through the priced staircase.  It
        # competes against plain (disk-cliff) linear for the linear slot so
        # ``auto`` lands between the cliff and the tensor path.
        tiered = False
        if est.spill_bytes > 0 and math.isfinite(est.t_linear_tiered):
            t_tier = self.profile.blend(est.t_linear_tiered, "fragment",
                                        "linear_tiered", n) + mem_wait
            if t_tier < t_lin:
                note_tier = (f"; tiered-linear staircase priced "
                             f"{t_tier:.3f}s vs {t_lin:.3f}s disk-spill")
                t_lin, tiered = t_tier, True
            else:
                note_tier = ""
        else:
            note_tier = ""
        t_ten = self.profile.blend(est.t_tensor, "fragment", "tensor",
                                   n) + dev_wait
        t_sh, gang_wait = math.inf, 0.0
        if shards > 1 and math.isfinite(est.t_tensor_sharded):
            lane_waits = () if dev_quote is None else dev_quote.lane_waits
            gang_wait = max([lane_waits[i] if i < len(lane_waits) else 0.0
                             for i in range(shards)] + [dev_wait])
            t_sh = self.profile.blend(est.t_tensor_sharded, "fragment",
                                      "tensor_sharded", n) + gang_wait
        use_sharded = t_sh < t_ten
        t_dev = min(t_ten, t_sh)
        dec_shards = shards if use_sharded else 1
        note = self._wait_note(mem_wait, dev_wait) + note_tier
        if use_sharded:
            note += (f"; sharded over {shards} lanes priced "
                     f"{t_sh:.3f}s vs {t_ten:.3f}s single-device "
                     f"(partition skew {skew:.2f}, gang wait "
                     f"{gang_wait * 1e3:.0f}ms)")
        num_ops = 1 + (spec.filter_fn is not None) + bool(spec.sort_keys) \
            + (spec.agg is not None)
        if est.path_fits_mem and t_lin <= t_dev:
            return Decision(
                "linear",
                f"whole linear fragment fits work_mem ({wm} B) and "
                f"T_linear={t_lin:.3f}s <= T_tensor={t_dev:.3f}s" + note,
                t_lin, t_dev, 0, h2d,
                mem_wait_s=mem_wait, dev_wait_s=dev_wait, tiered=tiered)
        path = "tensor" if t_dev < t_lin else "linear"
        return Decision(
            path,
            f"fragment-level: T_linear={t_lin:.3f}s vs T_tensor={t_dev:.3f}s "
            f"(fixed cost amortized over {num_ops} fused ops, "
            f"{(sharded_h2d if use_sharded else h2d) / 1e6:.1f} MB pending "
            f"H2D, predicted spill "
            f"{est.spill_bytes / 1e6:.1f} MB, feedback-blended)" + note,
            t_lin, t_dev, est.spill_bytes,
            sharded_h2d if use_sharded else h2d,
            mem_wait_s=mem_wait, dev_wait_s=dev_wait,
            shards=dec_shards if path == "tensor" else 1,
            tiered=tiered if path == "linear" else False)
