"""Sharded fused execution: bit-for-bit parity, partitioning edge cases,
gang leases, lane stats, and the sharded cost/selector terms.

The partition-parallel path's contract is that ``run_fused(shards=N)``
returns EXACTLY the single-device program's answer — same float, not just
close — for every eligible fragment (``sharded_supported``).  These tests
drive that contract through the adversarial partition layouts: heavy skew,
empty partitions, row counts that don't divide the partition count, and a
capacity-overflow retry.
"""
import numpy as np
import pytest

from repro.core.expr import col
from repro.core.fused import FusedSpec, run_fused, sharded_supported
from repro.core.relation import Relation


def _rel(**cols) -> Relation:
    return Relation.from_dict({k: np.asarray(v) for k, v in cols.items()})


def _host_agg(build, probe, key, col_name, fn, filt=None):
    """Independent numpy reference for a Join→[Filter]→Agg fragment under
    the join naming contract (probe keeps names, build serves b_<x>)."""
    bk = np.asarray(build[key])
    pk = np.asarray(probe[key])
    order = np.argsort(bk, kind="stable")
    sbk = bk[order]
    left = np.searchsorted(sbk, pk, "left")
    right = np.searchsorted(sbk, pk, "right")
    cnt = right - left
    probe_idx = np.repeat(np.arange(len(pk)), cnt)
    build_pos = (np.concatenate([np.arange(l, r) for l, r in
                                 zip(left, right)])
                 if len(pk) and cnt.sum() else np.array([], dtype=np.int64))
    build_idx = order[build_pos.astype(np.int64)]
    joined = {name: np.asarray(probe[name])[probe_idx]
              for name in probe.names}
    for name in build.names:
        if name != key:
            joined[f"b_{name}"] = np.asarray(build[name])[build_idx]
    mask = (np.asarray(filt(joined), bool) if filt is not None
            else np.ones(len(probe_idx), bool))
    vals = joined[col_name][mask]
    if fn == "count":
        return float(mask.sum())
    if fn == "sum":
        return float(vals.sum())
    if fn == "min":
        return float(vals.min())
    if fn == "max":
        return float(vals.max())
    raise ValueError(fn)


AGG_CASES = [
    ("w", "sum", None),
    ("w", "sum", col("w") > 0),
    ("w", "count", None),
    ("w", "count", col("w") > 0),
    ("w", "min", None),
    ("w", "max", None),
    ("b_region", "max", None),
    ("b_region", "min", col("w") > 0),
]


@pytest.mark.parametrize("col_name,fn,filt", AGG_CASES)
def test_sharded_parity_vs_single_and_host(eight_device_mesh, col_name, fn,
                                           filt):
    rng = np.random.default_rng(7)
    n_b, n_p = 20_000, 30_000
    build = _rel(uid=rng.integers(-5_000, 5_000, n_b).astype(np.int64),
                 region=rng.integers(0, 10, n_b).astype(np.int64))
    probe = _rel(uid=rng.integers(-5_000, 5_000, n_p).astype(np.int64),
                 w=rng.integers(-100, 100, n_p).astype(np.int64))
    spec = FusedSpec(join_key="uid", filter_fn=filt, sort_keys=(),
                     agg=(col_name, fn))
    assert sharded_supported(spec, build, probe)
    single, m1 = run_fused(spec, build, probe)
    sharded, m8 = run_fused(spec, build, probe, shards=8)
    host = _host_agg(build, probe, "uid", col_name, fn, filt)
    assert m1.devices == 1
    assert m8.devices == 8
    assert m8.host_syncs == 1
    assert sharded == single  # bit-for-bit, not approx
    assert sharded == host


def test_sharded_parity_skewed_zipf_keys(eight_device_mesh):
    rng = np.random.default_rng(11)
    n = 50_000
    keys = np.minimum(rng.zipf(1.3, n), 1 << 40).astype(np.int64)
    build = _rel(uid=keys, region=rng.integers(0, 4, n).astype(np.int64))
    probe = _rel(uid=np.minimum(rng.zipf(1.3, n), 1 << 40).astype(np.int64),
                 w=rng.integers(-50, 50, n).astype(np.int64))
    spec = FusedSpec(join_key="uid", filter_fn=col("w") > 0, sort_keys=(),
                     agg=("w", "sum"))
    single, _ = run_fused(spec, build, probe)
    sharded, m8 = run_fused(spec, build, probe, shards=8)
    assert m8.devices == 8
    assert sharded == single


def test_sharded_parity_empty_partitions(eight_device_mesh):
    # a single distinct key puts EVERY row in one partition: 7 of the 8
    # shards run over all-sentinel padding and must contribute identities
    rng = np.random.default_rng(3)
    n = 5_000
    build = _rel(uid=np.full(n, 42, np.int64),
                 region=rng.integers(0, 4, n).astype(np.int64))
    probe = _rel(uid=np.full(n, 42, np.int64),
                 w=rng.integers(1, 9, n).astype(np.int64))
    for fn in ("sum", "count", "min", "max"):
        spec = FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                         agg=("w", fn))
        single, _ = run_fused(spec, build, probe)
        sharded, m8 = run_fused(spec, build, probe, shards=8)
        assert m8.devices == 8
        assert sharded == single


def test_sharded_rows_not_divisible_by_partitions(eight_device_mesh):
    rng = np.random.default_rng(5)
    n_b, n_p = 10_003, 7_919  # both prime: never divide 8
    build = _rel(uid=rng.integers(0, 2_000, n_b).astype(np.int64),
                 region=rng.integers(0, 3, n_b).astype(np.int64))
    probe = _rel(uid=rng.integers(0, 2_000, n_p).astype(np.int64),
                 w=rng.integers(-10, 10, n_p).astype(np.int64))
    spec = FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                     agg=("w", "sum"))
    single, _ = run_fused(spec, build, probe)
    sharded, m8 = run_fused(spec, build, probe, shards=8)
    assert m8.devices == 8
    assert sharded == single
    assert sharded == _host_agg(build, probe, "uid", "w", "sum")


def test_sharded_empty_min_raises_like_single(eight_device_mesh):
    # disjoint key domains: zero joined rows; min has no identity on both
    # paths
    build = _rel(uid=np.arange(0, 100, dtype=np.int64),
                 region=np.zeros(100, np.int64))
    probe = _rel(uid=np.arange(1_000, 1_100, dtype=np.int64),
                 w=np.ones(100, np.int64))
    spec = FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                     agg=("w", "min"))
    with pytest.raises(ValueError):
        run_fused(spec, build, probe)
    with pytest.raises(ValueError):
        run_fused(spec, build, probe, shards=8)


def test_sharded_partition_cache_warm_second_query(eight_device_mesh):
    rng = np.random.default_rng(9)
    n = 30_000
    build = _rel(uid=rng.integers(0, 10_000, n).astype(np.int64),
                 region=rng.integers(0, 4, n).astype(np.int64))
    probe = _rel(uid=rng.integers(0, 10_000, n).astype(np.int64),
                 w=rng.integers(-5, 5, n).astype(np.int64))
    spec = FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                     agg=("w", "sum"))
    r1, m_cold = run_fused(spec, build, probe, shards=8)
    assert m_cold.h2d_bytes > 0  # the partitioned layouts uploaded
    r2, m_warm = run_fused(spec, build, probe, shards=8)
    assert r2 == r1
    assert m_warm.h2d_bytes == 0  # layouts resident: the serving contract
    assert m_warm.host_syncs == 1


def test_sharded_capacity_overflow_retries_once(eight_device_mesh):
    # one hot key with 500 build-side duplicates, probe aimed entirely at
    # it: the sampled duplication factor massively underestimates the
    # critical partition's output, so the optimistic capacity overflows
    # and the driver must retry at the exact bucket — and still be right
    rng = np.random.default_rng(13)
    build_keys = np.concatenate([
        np.arange(1_000, 2_500, dtype=np.int64),  # 1500 singletons
        np.full(500, 7, np.int64)])               # the hot key
    build = _rel(uid=build_keys,
                 region=rng.integers(0, 3, len(build_keys)).astype(np.int64))
    probe = _rel(uid=np.full(200, 7, np.int64),
                 w=np.ones(200, np.int64))
    spec = FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                     agg=("w", "count"))
    sharded, m8 = run_fused(spec, build, probe, shards=8)
    assert sharded == 200.0 * 500.0
    assert m8.devices == 8
    assert m8.host_syncs == 2  # optimistic pass + one retry at exact bucket
    # the verified capacity is remembered: the next query of the same
    # fragment over the same data must NOT pay the retry again
    again, m_again = run_fused(spec, build, probe, shards=8)
    assert again == sharded
    assert m_again.host_syncs == 1


def test_sharded_supported_eligibility():
    rng = np.random.default_rng(1)
    n = 100
    ints = _rel(uid=rng.integers(0, 10, n).astype(np.int64),
                w=rng.integers(0, 10, n).astype(np.int64))
    floats = _rel(uid=rng.integers(0, 10, n).astype(np.int64),
                  w=rng.random(n))
    fkey = _rel(uid=rng.random(n), w=rng.integers(0, 10, n).astype(np.int64))

    def spec(agg):
        return FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                         agg=agg)

    assert sharded_supported(spec(("w", "sum")), ints, ints)
    # float sum reassociates under psum: excluded from the bit-for-bit set
    assert not sharded_supported(spec(("w", "sum")), ints, floats)
    # min/max/count stay exact for floats
    assert sharded_supported(spec(("w", "min")), ints, floats)
    assert sharded_supported(spec(("w", "max")), ints, floats)
    assert sharded_supported(spec(("w", "count")), ints, floats)
    # non-integer join key breaks the partition-hash/sentinel contract
    assert not sharded_supported(spec(("w", "sum")), fkey, ints)
    # relation roots need a global merge: not sharded
    no_agg = FusedSpec(join_key="uid", filter_fn=None, sort_keys=("w",),
                       agg=None)
    assert not sharded_supported(no_agg, ints, ints)


def test_unsupported_fragment_degrades_to_single_device(eight_device_mesh):
    rng = np.random.default_rng(2)
    n = 5_000
    build = _rel(uid=rng.integers(0, 100, n).astype(np.int64),
                 region=rng.integers(0, 4, n).astype(np.int64))
    probe = _rel(uid=rng.integers(0, 100, n).astype(np.int64),
                 w=rng.random(n))  # float agg column
    spec = FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                     agg=("w", "sum"))
    result, m = run_fused(spec, build, probe, shards=8)
    assert m.devices == 1  # silent degrade, not an error
    single, _ = run_fused(spec, build, probe)
    assert result == single


# ---------------------------------------------------------------------------
# Broker lanes: gang leases, ensure_lanes, per-lane stats
# ---------------------------------------------------------------------------

def test_gang_lease_acquire_release_order():
    from repro.core.resource_broker import ResourceBroker

    broker = ResourceBroker(None)
    broker.ensure_lanes(4)
    assert len(broker.lanes) == 4
    broker.ensure_lanes(2)  # never shrinks
    assert len(broker.lanes) == 4
    broker.ensure_lanes(4)  # idempotent
    assert len(broker.lanes) == 4
    # lane 0 IS the single-dispatch device queue
    assert broker.lanes[0] is broker.device

    gang = broker.device_lease(lanes=4)
    assert gang.lanes == 4
    assert len(gang.lane_waits) == 4
    for q in broker.lanes:
        assert q.stats()["depth"] >= 1
    gang.release()
    with pytest.raises(RuntimeError):
        gang.release()
    for q in broker.lanes:
        assert q.stats()["depth"] == 0
    # single-lane requests still return a plain lease
    lease = broker.device_lease()
    assert not hasattr(lease, "lane_waits")
    lease.release()


def test_gang_lease_auto_grows_lanes():
    from repro.core.resource_broker import ResourceBroker

    broker = ResourceBroker(None)
    with broker.device_lease(lanes=3) as gang:
        assert gang.lanes == 3
    assert len(broker.lanes) == 3


def test_lane_stats_in_broker_stats_and_since():
    from repro.core.resource_broker import ResourceBroker

    broker = ResourceBroker(None)
    broker.ensure_lanes(2)
    base = broker.stats()
    assert len(base.lanes) == 2
    broker.device_lease(lanes=2).release()
    broker.device_lease(lanes=2).release()
    delta = broker.stats().since(base)
    assert len(delta.lanes) == 2
    for lane in delta.lanes:
        assert lane["dispatches"] == 2
        assert "ewma_wait_s" in lane
        assert "peak_depth" in lane
        assert "coalesced" in lane


def test_price_quotes_per_lane_waits():
    from repro.core.resource_broker import ResourceBroker, ResourceRequest

    broker = ResourceBroker(None)
    broker.ensure_lanes(4)
    q1 = broker.price(ResourceRequest("device"))
    assert len(q1.lane_waits) == 1  # single-lane request: lane 0 only
    q4 = broker.price(ResourceRequest("device", lanes=4))
    assert len(q4.lane_waits) == 4
    assert q4.expected_wait_s == max(q4.lane_waits)
    # lanes beyond the current lane set price as empty queues
    q8 = broker.price(ResourceRequest("device", lanes=8))
    assert len(q8.lane_waits) == 8
    assert all(w == 0.0 for w in q8.lane_waits[4:])


# ---------------------------------------------------------------------------
# Cost model + selector: the sharded pricing term
# ---------------------------------------------------------------------------

def test_cost_model_sharded_term_ordering():
    import math

    from repro.core.cost_model import CostModel

    model = CostModel()
    kw = dict(n_build=1_000_000, n_probe=1_000_000, row_bytes_b=16,
              row_bytes_p=16, est_out=1_000_000, work_mem=32 << 20,
              has_agg=True)
    single = model.estimate_fragment(**kw)
    assert math.isinf(single.t_tensor_sharded)  # no fan-out requested
    sharded = model.estimate_fragment(**kw, device_count=8)
    assert sharded.t_tensor_sharded < sharded.t_tensor
    skewed = model.estimate_fragment(**kw, device_count=8, partition_skew=8.0)
    assert skewed.t_tensor_sharded > sharded.t_tensor_sharded
    # aggregate-free fragments never price a sharded plan
    no_agg = model.estimate_fragment(**{**kw, "has_agg": False},
                                     device_count=8)
    assert math.isinf(no_agg.t_tensor_sharded)


def test_selector_prices_and_picks_sharded(eight_device_mesh):
    from repro.core.path_selector import PathSelector

    rng = np.random.default_rng(17)
    n = 400_000
    build = _rel(uid=rng.integers(0, 100_000, n).astype(np.int64),
                 region=rng.integers(0, 10, n).astype(np.int64))
    probe = _rel(uid=rng.integers(0, 100_000, n).astype(np.int64),
                 w=rng.integers(-100, 100, n).astype(np.int64))
    spec = FusedSpec(join_key="uid", filter_fn=col("w") > 0, sort_keys=(),
                     agg=("w", "sum"))
    sel = PathSelector(work_mem=4 << 20)
    d1 = sel.choose_fragment(spec, build, probe)  # max_shards defaults to 1
    assert d1.shards == 1
    d8 = sel.choose_fragment(spec, build, probe, max_shards=8)
    assert d8.path == "tensor"
    assert d8.shards == 8
    assert "sharded over 8 lanes" in d8.reason


def test_selector_ineligible_fragment_stays_single(eight_device_mesh):
    from repro.core.path_selector import PathSelector

    rng = np.random.default_rng(19)
    n = 200_000
    build = _rel(uid=rng.integers(0, 50_000, n).astype(np.int64),
                 region=rng.integers(0, 10, n).astype(np.int64))
    probe = _rel(uid=rng.integers(0, 50_000, n).astype(np.int64),
                 w=rng.random(n))  # float sum: not bit-for-bit shardable
    spec = FusedSpec(join_key="uid", filter_fn=None, sort_keys=(),
                     agg=("w", "sum"))
    d = PathSelector(work_mem=4 << 20).choose_fragment(
        spec, build, probe, max_shards=8)
    assert d.shards == 1


# ---------------------------------------------------------------------------
# End-to-end: session + governed serving with lanes
# ---------------------------------------------------------------------------

def test_session_sharded_end_to_end_parity(eight_device_mesh):
    from repro.core.session import Session

    rng = np.random.default_rng(23)
    n = 400_000
    orders = _rel(uid=rng.integers(0, 100_000, n).astype(np.int64),
                  w=rng.integers(-100, 100, n).astype(np.int64))
    users = _rel(uid=rng.integers(0, 100_000, n).astype(np.int64),
                 region=rng.integers(0, 10, n).astype(np.int64))
    results = {}
    for shards in (1, 8):
        sess = Session(work_mem=4 << 20, max_shards=shards)
        sess.register("orders", orders).register("users", users)
        q = (sess.table("orders").join("users", on="uid")
             .filter(col("w") > 0).aggregate("w", "sum"))
        q.collect()  # cold pass: compile + partition
        res = q.collect()
        results[shards] = res
    assert results[1].scalar == results[8].scalar
    d = results[8].decisions[-1]
    assert d.path == "tensor" and d.shards == 8
    assert results[8].metrics[-1].devices == 8
    assert results[8].metrics[-1].host_syncs == 1


def test_governed_serve_with_lanes(eight_device_mesh):
    from repro.core.server import QueryServer

    rng = np.random.default_rng(29)
    n = 400_000
    tables = {
        "orders": _rel(uid=rng.integers(0, 100_000, n).astype(np.int64),
                       w=rng.integers(-100, 100, n).astype(np.int64)),
        "users": _rel(uid=rng.integers(0, 100_000, n).astype(np.int64),
                      region=rng.integers(0, 10, n).astype(np.int64)),
    }
    server = QueryServer(tables, total_mem=64 << 20, work_mem=8 << 20,
                         max_shards=8)
    assert len(server.broker.lanes) == 8  # pre-created at build
    q = (server.session.table("orders").join("users", on="uid")
         .filter(col("w") > 0).aggregate("w", "sum"))
    report = server.serve([q], concurrency=3, queries_per_worker=2)
    assert report.governor.over_budget_events == 0
    assert not report.failed
    assert len(report.broker.lanes) == 8
    # the sharded program fans out across every lane
    assert all(lane["dispatches"] > 0 for lane in report.broker.lanes)
    scalars = {rec.scalar for rec in report.queries}
    assert len(scalars) == 1  # every serve of the same query agrees
