"""Phi-3.5-MoE 42B/A6.6B [hf:microsoft/Phi-3.5-MoE-instruct]: 16 experts top-2."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    vocab_size=32_064,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    pattern=(("attn:global", "moe"),),
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=6400,
    norm_topk=True,
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

SMOKE = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    pattern=(("attn:global", "moe"),),
    capacity_factor=16.0,  # no-drop capacity for decode-equivalence smoke tests
    num_experts=4,
    experts_per_token=2,
    moe_d_ff=96,
)

register(CONFIG, SMOKE)
