"""Device-resident columnar relation with late materialization.

The seed engine lowered every intermediate back to a host-numpy
:class:`~repro.core.relation.Relation` between operators — exactly the
"premature materialization" the paper argues against.  A
:class:`DeviceRelation` keeps columns as JAX device arrays across operators
and carries two pieces of deferred state instead of moving payload bytes:

  * a **pending gather index** per column (late materialization): a join or
    sort does not shuffle payload columns, it composes an ``int`` index array;
    the gather runs on device only when a column is actually consumed;
  * a **validity mask** over the (statically shaped) physical rows: joins
    produce ``capacity``-padded index spaces, filters AND their predicate into
    the mask, and no compaction (a dynamic-shape operation jit cannot express)
    ever happens on device.

Host materialization happens exactly once, at the query root, via
:meth:`to_host` — a single batched ``jax.device_get`` for all columns plus the
mask.  Callers that track :class:`~repro.core.metrics.OpMetrics` count that as
one host sync.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .relation import Relation

__all__ = ["DeviceColumn", "DeviceRelation"]


@dataclasses.dataclass(frozen=True)
class DeviceColumn:
    """A device array plus an optional pending gather index and decode hook.

    The logical column is ``decode(base[gather])`` (gather/decode optional),
    but both are deferred until :meth:`force` — composing two takes costs
    one index gather, never a payload gather, and a packed column
    (:mod:`repro.core.codec_device`) stays narrow codes through every lazy
    composition: the decode to logical width runs on device only when a
    consumer actually reads values (the decode-at-fetch rule).
    """

    base: jnp.ndarray
    gather: Optional[jnp.ndarray] = None
    # device-side decode applied after the gather (packed codes → logical
    # values); None for plain columns.  ``out_dtype`` is the decoded dtype.
    decode: Optional[object] = None
    out_dtype: Optional[object] = None

    def force(self) -> jnp.ndarray:
        arr = self.force_codes()
        if self.decode is not None:
            arr = self.decode(arr)
        return arr

    def force_codes(self) -> jnp.ndarray:
        """The physical (still-packed) column — code-domain consumers
        (group-by factorization) skip the decode entirely."""
        if self.gather is None:
            return self.base
        return jnp.take(self.base, self.gather, axis=0)

    def take_lazy(self, idx: jnp.ndarray) -> "DeviceColumn":
        if self.gather is None:
            return DeviceColumn(self.base, idx, self.decode, self.out_dtype)
        return DeviceColumn(self.base, jnp.take(self.gather, idx, axis=0),
                            self.decode, self.out_dtype)

    @property
    def num_rows(self) -> int:
        arr = self.gather if self.gather is not None else self.base
        return int(arr.shape[0])

    @property
    def dtype(self):
        if self.decode is not None and self.out_dtype is not None:
            return jnp.dtype(self.out_dtype)
        return self.base.dtype


class DeviceRelation:
    """Columns on device; physical rows are static, logical rows are masked."""

    def __init__(self, columns: Dict[str, DeviceColumn],
                 valid: Optional[jnp.ndarray] = None):
        if not columns:
            raise ValueError("DeviceRelation needs at least one column")
        lengths = {k: c.num_rows for k, c in columns.items()}
        if len(set(lengths.values())) != 1:
            raise ValueError(f"ragged device columns: {lengths}")
        self.columns = columns
        self.valid = valid  # None = all physical rows are logical rows

    # -- construction ------------------------------------------------------
    @staticmethod
    def from_host(rel: Relation) -> "DeviceRelation":
        return DeviceRelation(
            {k: DeviceColumn(jnp.asarray(v)) for k, v in rel.columns.items()})

    @staticmethod
    def from_arrays(cols: Mapping[str, jnp.ndarray],
                    valid: Optional[jnp.ndarray] = None) -> "DeviceRelation":
        return DeviceRelation({k: DeviceColumn(v) for k, v in cols.items()},
                              valid=valid)

    @staticmethod
    def from_codes(cols: Mapping[str, object]) -> "DeviceRelation":
        """Lift packed device columns (:class:`~repro.core.codec_device.
        DeviceCodes`) into a relation of decode-deferred columns: storage
        stays at code width, the decode hook runs at :meth:`DeviceColumn.
        force` — i.e. only for columns a consumer actually touches."""
        out: Dict[str, DeviceColumn] = {}
        for k, dc in cols.items():
            if dc.encoding == "raw":
                out[k] = DeviceColumn(dc.codes)
            else:
                out[k] = DeviceColumn(dc.codes, decode=dc.decode,
                                      out_dtype=dc.layout.logical_dtype)
        return DeviceRelation(out)

    # -- properties --------------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self.columns.keys())

    @property
    def num_physical_rows(self) -> int:
        return next(iter(self.columns.values())).num_rows

    def __len__(self) -> int:
        # Upper bound on logical rows without a device sync; exact count
        # requires materializing the mask (the selector only needs scale).
        return self.num_physical_rows

    def row_bytes(self) -> int:
        return int(sum(c.dtype.itemsize for c in self.columns.values()))

    def col(self, name: str) -> jnp.ndarray:
        """The logical column as a device array (runs the pending gather)."""
        return self.columns[name].force()

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self.col(name)

    # -- transforms (all lazy / device-side, never a host sync) ------------
    def take_lazy(self, idx: jnp.ndarray,
                  valid: Optional[jnp.ndarray] = None) -> "DeviceRelation":
        """Row selection by device index array; payload gathers stay pending.

        Columns sharing one physical gather array compose it once.
        """
        composed: Dict[int, jnp.ndarray] = {}
        out: Dict[str, DeviceColumn] = {}
        for k, c in self.columns.items():
            if c.gather is None:
                out[k] = DeviceColumn(c.base, idx, c.decode, c.out_dtype)
                continue
            key = id(c.gather)
            if key not in composed:
                composed[key] = jnp.take(c.gather, idx, axis=0)
            out[k] = DeviceColumn(c.base, composed[key], c.decode,
                                  c.out_dtype)
        new_valid = valid
        if new_valid is None and self.valid is not None:
            new_valid = jnp.take(self.valid, idx, axis=0)
        return DeviceRelation(out, valid=new_valid)

    def with_valid(self, valid: jnp.ndarray) -> "DeviceRelation":
        return DeviceRelation(dict(self.columns), valid=valid)

    def mask_and(self, mask: jnp.ndarray) -> "DeviceRelation":
        valid = mask if self.valid is None else (self.valid & mask)
        return DeviceRelation(dict(self.columns), valid=valid)

    def select(self, names: Iterable[str]) -> "DeviceRelation":
        return DeviceRelation({k: self.columns[k] for k in names},
                              valid=self.valid)

    # -- the single host-materialization point -----------------------------
    def to_host(self) -> Relation:
        """Materialize to a host Relation with ONE batched device→host fetch."""
        forced = {k: c.force() for k, c in self.columns.items()}
        if self.valid is not None:
            payload = jax.device_get((forced, self.valid))
            cols, valid = payload
            keep = np.nonzero(np.asarray(valid))[0]
            return Relation({k: np.asarray(v)[keep] for k, v in cols.items()})
        cols = jax.device_get(forced)
        return Relation({k: np.asarray(v) for k, v in cols.items()})
