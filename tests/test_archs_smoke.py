"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward and one train step on CPU; output shapes and
numerics (no NaN) are asserted.  Full configs are exercised only via the
dry-run (launch/dryrun.py, ShapeDtypeStruct — no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, applicable, get_config, get_smoke_config, list_archs
from repro.models import (cross_entropy_loss, decode_step, forward, init_cache,
                          init_model, prefill)

ARCHS = list_archs()


def _batch(cfg, B, S, key):
    batch = {}
    if cfg.modality == "audio_stub":
        batch["features"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_structure(arch):
    cfg = get_config(arch)
    assert cfg.num_layers == len(cfg.prefix) + cfg.period * cfg.num_periods
    assert cfg.param_count() > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S, key)
    logits, aux, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One SGD step end to end: loss is finite, decreases over 3 steps."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S, key)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        logits, aux, _ = forward(p, cfg, batch)
        return cross_entropy_loss(logits, labels) + aux

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p = jax.tree.map(lambda w, g: w - 0.5 * g, p, grads)
        return p, loss

    losses = []
    for _ in range(3):
        params, loss = step(params)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step")
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    B, S = 2, 8
    batch = _batch(cfg, B, S, key)
    logits_full, _, _ = forward(params, cfg, batch)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        db = {k: (v[:, :, t:t + 1] if k == "positions" else v[:, t:t + 1])
              for k, v in batch.items()}
        lg, cache = decode_step(params, cfg, cache, db)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=5e-2, atol=5e-4)


def test_applicability_matrix():
    """DESIGN.md §5: 31 runnable cells, 9 skips with reasons."""
    cells = []
    skips = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = applicable(cfg, shape)
            (cells if ok else skips).append((arch, shape.name, why))
    assert len(cells) == 31, len(cells)
    assert len(skips) == 9, skips
    skipped_archs = {a for a, s, _ in skips if s == "long_500k"}
    assert "mamba2-370m" not in skipped_archs
    assert "jamba-1.5-large-398b" not in skipped_archs
    assert ("hubert-xlarge", "decode_32k") in {(a, s) for a, s, _ in skips}
