"""Tiered spill hierarchy: codec round-trips, CRC integrity, tier placement
and failover, prefetch overlap, grant quotas, and the balance invariant.

The fig16 benchmark asserts the serving-level gates; these tests pin the
mechanisms underneath them — a codec that is exact on every dtype corner,
reads that fail over DOWN the hierarchy on corruption/faults, promotions
that never race deletes, and books that balance to the byte.
"""
import dataclasses
import os
import time

import numpy as np
import pytest

from repro.core import (Relation, Session, SpillCorruptionError, SpillManager,
                        TierConfig, TierLedger, TierManager)
from repro.core.faults import FaultInjector, RetryPolicy, SpillIOError
from repro.core.metrics import SpillAccount
from repro.core.tier import decode_column, encode_column

MB = 1 << 20


def _fast_tiers(**kw):
    """A hierarchy whose emulated remote tier is effectively free, so tests
    exercise placement/failover logic without sleeping."""
    kw.setdefault("t1_latency_s", 0.0)
    kw.setdefault("t1_gbps", 1000.0)
    return TierConfig(**kw)


# ---------------------------------------------------------------------------
# Codec: property-style round trips
# ---------------------------------------------------------------------------

CODEC_CASES = [
    np.arange(1000, dtype=np.int64),                       # pack-friendly
    np.array([-5, -5, -5, 7, 7], dtype=np.int64),          # negative + dict
    np.array([np.iinfo(np.int64).min, np.iinfo(np.int64).max], np.int64),
    np.repeat(np.int64(42), 4096),                          # single value
    np.array([], dtype=np.int64),                           # empty
    np.array([], dtype=np.float64),
    np.array([0.0, -0.0, np.nan, np.inf, -np.inf], np.float64),
    np.random.default_rng(7).random(2048),                  # incompressible-ish
    np.random.default_rng(7).integers(0, 3, 5000).astype(np.int32),
    np.arange(100, dtype=np.uint64) + np.uint64(2**63),     # high uint range
    np.array([1.5], dtype=np.float32),
]


@pytest.mark.parametrize("arr", CODEC_CASES,
                         ids=[f"case{i}" for i in range(len(CODEC_CASES))])
def test_codec_roundtrip_exact(arr):
    enc = encode_column(arr)
    out = decode_column(enc)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    # bit-pattern equality: NaN payloads and signed zeros must round-trip,
    # which `==` cannot check
    assert np.array_equal(arr.view(f"u{arr.dtype.itemsize}"),
                          out.view(f"u{out.dtype.itemsize}"))


def test_codec_compresses_low_cardinality():
    arr = np.random.default_rng(0).integers(0, 16, 100_000).astype(np.int64)
    enc = encode_column(arr)
    assert enc.kind in ("dict", "pack")
    assert enc.nbytes < arr.nbytes / 4  # 4 bits/row vs 64


def test_codec_corruption_raises_typed_error():
    arr = np.arange(256, dtype=np.int64)
    enc = encode_column(arr)
    bad = dataclasses.replace(enc, crc=enc.crc ^ 0xDEAD)
    with pytest.raises(SpillCorruptionError):
        decode_column(bad)


# ---------------------------------------------------------------------------
# Disk CRC manifests (satellite: torn files must fail loudly)
# ---------------------------------------------------------------------------

def _flip_byte(base):
    npy = sorted(f for f in os.listdir(base) if f.endswith(".npy"))[0]
    path = os.path.join(base, npy)
    with open(path, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))


def test_disk_read_detects_bit_flip():
    rel = Relation({"a": np.arange(100, dtype=np.int64)})
    with SpillManager() as mgr:
        base = mgr.write_relation(rel, "p", SpillAccount())
        _flip_byte(base)
        with pytest.raises(SpillCorruptionError, match="CRC32"):
            mgr.read_relation(base, SpillAccount())
        with pytest.raises(SpillCorruptionError, match="CRC32"):
            mgr.open_run_reader(base, SpillAccount())


def test_disk_read_without_manifest_still_works():
    """Foreign/legacy spill dirs (no checksums.json) stay readable."""
    rel = Relation({"a": np.arange(10, dtype=np.int64)})
    with SpillManager() as mgr:
        base = mgr.write_relation(rel, "p", SpillAccount())
        os.remove(os.path.join(base, "checksums.json"))
        assert mgr.read_relation(base, SpillAccount()).equals(rel)


# ---------------------------------------------------------------------------
# Live temp-space tracking (satellite: delete must decrement)
# ---------------------------------------------------------------------------

def test_spill_account_live_bytes_tracks_delete():
    rel = Relation({"a": np.arange(1000, dtype=np.int64)})
    with SpillManager() as mgr:
        acct = SpillAccount()
        b1 = mgr.write_relation(rel, "p", acct)
        b2 = mgr.write_relation(rel, "p", acct)
        assert acct.live_bytes == 2 * rel["a"].nbytes
        assert acct.peak_live_bytes == 2 * rel["a"].nbytes
        mgr.delete(b1, acct)
        assert acct.live_bytes == rel["a"].nbytes
        mgr.delete(b2, acct)
        assert acct.live_bytes == 0
        assert acct.peak_live_bytes == 2 * rel["a"].nbytes  # peak sticks
    from repro.core import OpMetrics
    m = OpMetrics(op="x", path="linear", rows_in=0, rows_out=0, wall_s=0.0,
                  spill=acct)
    r = m.as_row()
    assert r["temp_live_mb"] == 0.0
    assert r["temp_peak_live_mb"] > 0.0


# ---------------------------------------------------------------------------
# TierManager placement, reads, deletes, balance
# ---------------------------------------------------------------------------

def _rel(n=1000, seed=0):
    rng = np.random.default_rng(seed)
    return Relation({"k": rng.integers(0, 50, n).astype(np.int64),
                     "v": rng.random(n)})


def test_placement_t0_then_t1_then_t2():
    rel = _rel(4096)  # 64 KB logical
    led = TierLedger()
    cfg = _fast_tiers(t0_capacity=80 * 1024, t1_capacity=80 * 1024)
    with TierManager(config=cfg, ledger=led) as mgr:
        acct = SpillAccount()
        bases = [mgr.write_relation(rel, "p", acct) for _ in range(6)]
        homes = [mgr._home[b] for b in bases]
        # compressed T0 fills first, then T1, then disk backstop
        assert homes[0] == "t0"
        assert "t1" in homes and "t2" in homes
        assert homes == sorted(homes)  # monotone down the hierarchy
        for b in bases:
            assert mgr.read_relation(b, acct).equals(rel)
            mgr.delete(b, acct)
        assert acct.live_bytes == 0
        assert mgr.pool_bytes == 0
    led.verify_balanced()
    snap = led.snapshot()
    for t in ("t0", "t1", "t2"):
        assert snap[t]["bytes_written"] == snap[t]["bytes_freed"]


def test_run_reader_from_memory_tiers_matches_disk_contract():
    rel = _rel(500)
    with TierManager(config=_fast_tiers(t0_capacity=4 * MB)) as mgr:
        acct = SpillAccount()
        base = mgr.write_relation(rel, "run", acct)
        assert mgr._home[base] == "t0"
        reader = mgr.open_run_reader(base, acct)
        chunks = []
        while not reader.exhausted:
            chunks.append(reader.read_rows(123))
        out = chunks[0]
        for c in chunks[1:]:
            out = out.concat(c)
        assert out.equals(rel)
        assert acct.bytes_read >= sum(c.nbytes for c in rel.columns.values())


def test_op_quota_caps_t0_admission():
    rel = _rel(4096)
    with TierManager(config=_fast_tiers(t0_capacity=32 * MB)) as mgr:
        mgr.set_op_quota({"t0": 0, "t1": None})
        acct = SpillAccount()
        base = mgr.write_relation(rel, "p", acct)
        assert mgr._home[base] == "t1"  # quota, not capacity, kept it out
        mgr.set_op_quota(None)
        base2 = mgr.write_relation(rel, "p", acct)
        assert mgr._home[base2] == "t0"
        mgr.delete(base, acct)
        mgr.delete(base2, acct)
        assert acct.live_bytes == 0


# ---------------------------------------------------------------------------
# Failover + fault injection on the read path
# ---------------------------------------------------------------------------

class _FlakyReads(FaultInjector):
    """Deterministic variant: fail the first N spill reads, then heal."""

    def __init__(self, fail_times):
        super().__init__()
        self.remaining = fail_times

    def on_spill_read(self, path=""):
        if self.remaining > 0:
            self.remaining -= 1
            raise SpillIOError(f"injected flaky read at {path!r}")


def test_transient_read_faults_retry_then_succeed():
    rel = _rel(512)
    faults = _FlakyReads(2)
    retry = RetryPolicy(max_attempts=4, base_s=1e-4, cap_s=1e-3)
    cfg = _fast_tiers(t0_capacity=0)  # force T1 home (the injected tier)
    with TierManager(config=cfg, faults=faults, retry=retry) as mgr:
        acct = SpillAccount()
        base = mgr.write_relation(rel, "p", acct)
        assert mgr._home[base] == "t1"
        assert mgr.read_relation(base, acct).equals(rel)
        assert mgr.tier_stats()["t1"]["read_faults"] == 2


def test_exhausted_retries_raise_after_failover():
    rel = _rel(512)
    faults = FaultInjector(spill_read_p=1.0)
    retry = RetryPolicy(max_attempts=3, base_s=1e-4, cap_s=1e-3)
    cfg = _fast_tiers(t0_capacity=0)
    with TierManager(config=cfg, faults=faults, retry=retry) as mgr:
        acct = SpillAccount()
        base = mgr.write_relation(rel, "p", acct)
        with pytest.raises(SpillIOError):
            mgr.read_relation(base, acct)
        assert mgr.tier_stats()["t1"]["read_faults"] == 3
        assert faults.counts()["spill_read"] >= 3
        mgr.delete(base, acct)


def test_corrupt_t0_copy_fails_over_to_authoritative_tier():
    rel = _rel(512)
    cfg = _fast_tiers(t0_capacity=4 * MB)
    with TierManager(config=cfg) as mgr:
        acct = SpillAccount()
        mgr.set_op_quota({"t0": 0, "t1": None})
        base = mgr.write_relation(rel, "p", acct)   # home = t1
        mgr.set_op_quota(None)
        mgr.prefetch([base])
        mgr.drain_prefetch()
        assert base in mgr._t0 and mgr.prefetches == 1
        # poison the promoted pool copy; the authoritative T1 copy survives
        name, enc = next(iter(mgr._t0[base].items()))
        mgr._t0[base][name] = dataclasses.replace(enc, crc=enc.crc ^ 1)
        out = mgr.read_relation(base, acct)
        assert out.equals(rel)
        assert mgr.tier_stats()["t0"]["corruptions"] == 1
        assert base not in mgr._t0  # damaged copy dropped
        mgr.delete(base, acct)
        assert mgr.pool_bytes == 0


def test_remote_slowdown_injection_is_counted():
    rel = _rel(256)
    faults = FaultInjector(remote_slow_p=1.0, remote_slow_s=0.02)
    cfg = _fast_tiers(t0_capacity=0)
    with TierManager(config=cfg, faults=faults) as mgr:
        acct = SpillAccount()
        base = mgr.write_relation(rel, "p", acct)
        t0 = time.perf_counter()
        mgr.read_relation(base, acct)
        assert time.perf_counter() - t0 >= 0.02
        assert faults.counts()["remote_slow"] >= 1
        mgr.delete(base, acct)


# ---------------------------------------------------------------------------
# Prefetch: background promotion overlaps the foreground
# ---------------------------------------------------------------------------

def test_prefetch_promotes_and_serves_from_t0():
    rel = _rel(2048)
    cfg = _fast_tiers(t0_capacity=8 * MB, t1_capacity=0)
    with TierManager(config=cfg) as mgr:
        acct = SpillAccount()
        mgr.set_op_quota({"t0": 0, "t1": 0})  # force the write to disk
        base = mgr.write_relation(rel, "p", acct)
        assert mgr._home[base] == "t2"
        mgr.set_op_quota(None)
        mgr.prefetch([base])
        mgr.drain_prefetch()
        assert mgr.prefetches == 1 and base in mgr._t0
        mgr.read_relation(base, acct).equals(rel)
        stats = mgr.tier_stats()
        assert stats["t0"]["bytes_read"] > 0          # served from the pool
        assert stats["t0"]["bytes_promoted"] > 0
        mgr.delete(base, acct)
        assert mgr.pool_bytes == 0  # promoted copy dropped with the base


def test_prefetch_delete_race_leaks_nothing():
    """A promotion in flight when its base is deleted must not publish into
    the pool afterwards (the promote-after-delete leak window)."""
    cfg = _fast_tiers(t0_capacity=8 * MB, t1_capacity=0)
    led = TierLedger()
    with TierManager(config=cfg, ledger=led) as mgr:
        acct = SpillAccount()
        for seed in range(8):
            base = mgr.write_relation(_rel(2048, seed), "p", acct)
            mgr.prefetch([base])
            mgr.delete(base, acct)  # often beats the promoter
        mgr.drain_prefetch()
        assert mgr.pool_bytes == 0
    led.verify_balanced()


def test_prefetch_disabled_is_noop():
    cfg = _fast_tiers(t0_capacity=8 * MB, t1_capacity=0, prefetch=False)
    with TierManager(config=cfg) as mgr:
        acct = SpillAccount()
        mgr.set_op_quota({"t0": 0, "t1": 0})  # force the write to disk
        base = mgr.write_relation(_rel(), "p", acct)
        mgr.set_op_quota(None)
        mgr.prefetch([base])
        mgr.drain_prefetch()
        assert mgr.prefetches == 0 and base not in mgr._t0
        mgr.delete(base, acct)


# ---------------------------------------------------------------------------
# Tiered grants + pricing
# ---------------------------------------------------------------------------

def test_governor_hands_out_tiered_grants():
    from repro.core import MemoryGovernor, TieredGrant

    cfg = _fast_tiers(t0_capacity=4 * MB)
    gov = MemoryGovernor(16 * MB, tiers=cfg)
    g = gov.acquire(2 * MB)
    try:
        assert isinstance(g, TieredGrant)
        # t0 quota: capacity-capped max(2x grant, half the pool)
        assert g.quotas["t0"] == min(4 * MB, max(2 * g.size, 2 * MB))
        assert g.quotas["t2"] is None  # disk backstop is unbounded
    finally:
        g.release()


def test_broker_quote_carries_tier_terms():
    from repro.core import (MemoryGovernor, ResourceBroker, ResourceRequest)

    cfg = _fast_tiers(t0_capacity=4 * MB)
    gov = MemoryGovernor(16 * MB, tiers=cfg)
    broker = ResourceBroker(gov)
    q = broker.price(ResourceRequest("memory", need_bytes=2 * MB))
    assert q.tier_quotas is not None and len(q.tier_quotas) == 3
    assert q.tier_byte_s == cfg.byte_costs()
    # an untiered governor quotes no staircase
    q2 = ResourceBroker(MemoryGovernor(16 * MB)).price(
        ResourceRequest("memory", need_bytes=2 * MB))
    assert q2.tier_quotas is None and q2.tier_byte_s is None


def test_cost_model_staircase_beats_disk_cliff():
    from repro.core import CostModel

    model = CostModel()
    spill = 64 * MB
    cheap = model.alpha_tiered(spill, tier_quotas=(spill, None, None),
                               tier_byte_s=None)
    disk = model.alpha(spill)
    assert cheap < disk  # all-T0 staircase undercuts the all-disk cliff
    est = model.estimate_fragment(
        500_000, 500_000, 16, 16, 500_000, 1 * MB,
        tier_quotas=(32 * MB, 256 * MB, None))
    assert est.spill_bytes > 0
    assert est.t_linear_tiered < est.t_linear
    est_plain = model.estimate_fragment(
        500_000, 500_000, 16, 16, 500_000, 1 * MB)
    assert est_plain.t_linear_tiered == float("inf")


# ---------------------------------------------------------------------------
# End-to-end: tiered session produces identical results
# ---------------------------------------------------------------------------

def _star_tables(n=60_000, seed=3):
    rng = np.random.default_rng(seed)
    fact = Relation({"k": rng.integers(0, 400, n).astype(np.int64),
                     "w": rng.random(n)})
    dim = Relation({"k": np.arange(400, dtype=np.int64),
                    "v": rng.random(400)})
    return fact, dim


def test_tiered_session_equals_plain_session():
    fact, dim = _star_tables()
    kw = dict(work_mem=1 * MB, policy="linear", fuse=False)

    def run(**extra):
        s = Session(**kw, **extra)
        s.register("fact", fact).register("dim", dim)
        q = (s.table("fact").join("dim", on="k").sort("k", "w")
             .aggregate("b_v", "sum"))
        return s, q.collect()

    s_plain, plain = run()
    s_tier, tiered = run(tiers=_fast_tiers(t0_capacity=2 * MB,
                                           t1_capacity=8 * MB))
    assert plain.scalar == pytest.approx(tiered.scalar)
    # the tiny budget genuinely spilled, and it spilled through the tiers
    assert any(m.spill.bytes_written > 0 for m in tiered.metrics)
    snap = s_tier.tier_ledger.snapshot()
    assert sum(snap[t]["bytes_written"] for t in ("t0", "t1", "t2")) > 0
    s_tier.tier_ledger.verify_balanced()


def test_tiered_sort_spill_matches_plain():
    fact, _ = _star_tables(40_000)
    kw = dict(work_mem=256 * 1024, policy="linear", fuse=False)

    def run(**extra):
        s = Session(**kw, **extra)
        s.register("fact", fact)
        return s, s.table("fact").sort("k", "w").collect()

    _, plain = run()
    s_tier, tiered = run(tiers=_fast_tiers(t0_capacity=1 * MB))
    assert plain.relation.equals(tiered.relation)
    s_tier.tier_ledger.verify_balanced()


# ---------------------------------------------------------------------------
# Nightly sweep: tier capacities x budgets (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("t0_mb", [0, 2, 16])
@pytest.mark.parametrize("budget_mb", [6, 24])
def test_tier_sweep_balances_under_concurrency(t0_mb, budget_mb):
    from repro.core import QueryServer

    fact, dim = _star_tables(120_000)
    srv = QueryServer(
        {"fact": fact, "dim": dim}, total_mem=budget_mb * MB,
        work_mem=8 * MB, min_grant=1 * MB, policy="linear",
        tiers=_fast_tiers(t0_capacity=t0_mb * MB, t1_capacity=64 * MB))
    q = (srv.session.table("fact").join("dim", on="k").sort("k", "w")
         .aggregate("b_v", "sum"))
    rep = srv.serve([q], concurrency=4, queries_per_worker=3)
    assert rep.counts["failed"] == 0
    assert rep.governor.over_budget_events == 0
    srv.session.tier_ledger.verify_balanced()
