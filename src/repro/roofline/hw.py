"""TPU v5e hardware constants (the assignment's target machine)."""

PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 50e9           # bytes/s per ICI link (per direction, approx.)

HBM_BYTES = 16 * 2**30       # 16 GiB HBM per v5e chip

# mesh sizes
SINGLE_POD_CHIPS = 256       # 16 x 16
MULTI_POD_CHIPS = 512        # 2 x 16 x 16
