"""GROUP BY (hash aggregate) with dual execution paths.

The third classic linearizing operator after join and sort: the linear path
builds a hash table of groups (spilling to grouped partitions under
work_mem), the tensor path segment-reduces along the key axis (the same
dimension-preserving structure as the fused join-aggregate).  Semantics are
identical; the executor treats it as another deferred decision point.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .linear_engine import _next_pow2, _splitmix64, table_bytes_estimate
from .metrics import OpMetrics, SpillAccount, Timer
from .relation import Relation
from .spill import SpillManager

__all__ = ["group_aggregate_linear", "group_aggregate_tensor"]

_AGGS = ("sum", "count", "min", "max")


def _agg_inmem(rel: Relation, key: str, values: Dict[str, str]) -> Relation:
    keys = rel[key]
    uniq, inv = np.unique(keys, return_inverse=True)
    out: Dict[str, np.ndarray] = {key: uniq}
    for col, fn in values.items():
        v = rel[col]
        if fn == "sum":
            out[f"{fn}_{col}"] = np.bincount(inv, weights=v.astype(np.float64),
                                             minlength=len(uniq))
        elif fn == "count":
            out[f"{fn}_{col}"] = np.bincount(inv, minlength=len(uniq)).astype(np.float64)
        elif fn in ("min", "max"):
            fill = np.inf if fn == "min" else -np.inf
            acc = np.full(len(uniq), fill)
            ufunc = np.minimum if fn == "min" else np.maximum
            ufunc.at(acc, inv, v.astype(np.float64))
            out[f"{fn}_{col}"] = acc
        else:
            raise ValueError(fn)
    return Relation(out)


def _merge_groups(parts: List[Relation], key: str, values: Dict[str, str]) -> Relation:
    merged = parts[0]
    for p in parts[1:]:
        merged = merged.concat(p)
    keys = merged[key]
    uniq, inv = np.unique(keys, return_inverse=True)
    out = {key: uniq}
    for col, fn in values.items():
        name = f"{fn}_{col}"
        v = merged[name]
        if fn in ("sum", "count"):
            out[name] = np.bincount(inv, weights=v, minlength=len(uniq))
        else:
            fill = np.inf if fn == "min" else -np.inf
            acc = np.full(len(uniq), fill)
            (np.minimum if fn == "min" else np.maximum).at(acc, inv, v)
            out[name] = acc
    return Relation(out)


def group_aggregate_linear(rel: Relation, key: str, values: Dict[str, str],
                           work_mem: int, mgr: SpillManager = None
                           ) -> Tuple[Relation, OpMetrics]:
    """Hash aggregate with work_mem discipline: when the group table would
    not fit, inputs hash-partition to disk and each partition aggregates
    independently (PostgreSQL's spill-to-disk hash aggregation)."""
    own = mgr is None
    mgr = mgr or SpillManager()
    spill = SpillAccount()
    peak = 0
    try:
        with Timer() as t:
            keys = rel[key].astype(np.int64)
            n_groups_est = min(len(rel), max(1, len(np.unique(
                keys[: min(len(keys), 65536)])) * max(1, len(keys) // 65536)))
            est = table_bytes_estimate(n_groups_est)
            if est <= work_mem or len(rel) <= 64:
                out = _agg_inmem(rel, key, values)
                peak = est
            else:
                fanout = min(64, max(2, _next_pow2(int(np.ceil(est / work_mem)))))
                spill.partition_passes += 1
                h = (_splitmix64(keys, salt=7) % np.uint64(fanout)).astype(np.int64)
                parts = []
                for f in range(fanout):
                    part = rel.take(np.nonzero(h == f)[0])
                    if len(part) == 0:
                        continue
                    path = mgr.write_relation(part, f"agg{f}", spill)
                    parts.append(path)
                peak = table_bytes_estimate(n_groups_est // fanout)
                results = []
                for path in parts:
                    part = mgr.read_relation(path, spill)
                    mgr.delete(path)
                    results.append(_agg_inmem(part, key, values))
                out = _merge_groups(results, key, values)
    finally:
        if own:
            mgr.cleanup()
    return out, OpMetrics(op="group_aggregate", path="linear",
                          rows_in=len(rel), rows_out=len(out),
                          wall_s=t.elapsed, spill=spill,
                          peak_working_set_bytes=peak)


def group_aggregate_tensor(rel: Relation, key: str, values: Dict[str, str],
                           key_domain: int = None) -> Tuple[Relation, OpMetrics]:
    """Dimension-preserving aggregate: segment reductions along the key axis
    (jit, static segment count) — no group hash table ever exists."""
    import jax
    import jax.numpy as jnp

    keys_np = np.asarray(rel[key], dtype=np.int64)
    uniq = np.unique(keys_np)
    with Timer() as t:
        # key axis = dense segment ids (host factorization, O(N log N))
        seg = np.searchsorted(uniq, keys_np)
        nseg = len(uniq)
        segs_j = jnp.asarray(seg, jnp.int32)
        out: Dict[str, np.ndarray] = {key: uniq}
        for col, fn in values.items():
            v = jnp.asarray(rel[col], jnp.float64)
            if fn == "sum":
                r = jax.ops.segment_sum(v, segs_j, num_segments=nseg)
            elif fn == "count":
                r = jax.ops.segment_sum(jnp.ones_like(v), segs_j, num_segments=nseg)
            elif fn == "min":
                r = jax.ops.segment_min(v, segs_j, num_segments=nseg)
            elif fn == "max":
                r = jax.ops.segment_max(v, segs_j, num_segments=nseg)
            else:
                raise ValueError(fn)
            out[f"{fn}_{col}"] = np.asarray(jax.block_until_ready(r))
    peak = rel.nbytes() + nseg * 8 * (1 + len(values))
    return Relation(out), OpMetrics(op="group_aggregate", path="tensor",
                                    rows_in=len(rel), rows_out=nseg,
                                    wall_s=t.elapsed, spill=SpillAccount(),
                                    peak_working_set_bytes=peak)
