"""Open-loop SLO serving, failure records, preemption, crash-consistent spill.

The PR-6 robustness layer: every submitted query ends as exactly one of
served / shed / failed, the budget invariant holds under storms, chaos and
preemption, and reservations never leak.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (ArrivalProcess, BrokerInvariantViolation,
                        FaultInjector, MemoryGovernor, QueryServer, Relation,
                        ResourceBroker, Session, SimulatedCrash, SpillManager,
                        TenantClass)
from repro.core.metrics import SpillAccount

MB = 1 << 20


def star_tables(n=30_000, seed=0):
    rng = np.random.default_rng(seed)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1000, n).astype(np.int64)})
    return build, probe


def make_server(n=30_000, total_mem=64 * MB, **kw):
    build, probe = star_tables(n)
    server = QueryServer({"b": build, "p": probe}, total_mem=total_mem,
                         work_mem=16 * MB, **kw)
    q_agg = (server.session.table("p").join("b", on="k")
             .aggregate("b_v", "sum"))
    q_sort = (server.session.table("p").join("b", on="k").sort("k", "w")
              .aggregate("b_v", "sum"))
    return server, q_agg, q_sort


def serial_scalars(n=30_000):
    build, probe = star_tables(n)
    s = Session(work_mem=64 * MB)
    s.register("b", build).register("p", probe)
    return {
        0: s.table("p").join("b", on="k").aggregate("b_v", "sum").scalar(),
        1: (s.table("p").join("b", on="k").sort("k", "w")
            .aggregate("b_v", "sum").scalar())}


# -- open loop: basics -------------------------------------------------------

def test_open_loop_light_load_serves_everything():
    server, q_agg, q_sort = make_server()
    ref = serial_scalars()
    t = TenantClass("t", deadline_s=10.0)
    rep = server.serve_open(
        workloads={"t": [q_agg, q_sort]},
        arrivals={"t": ArrivalProcess(rate_qps=25, seed=1)},
        duration_s=1.2, tenants=[t], workers=3, warmup=1)
    c = rep.counts
    assert c["submitted"] == len(ArrivalProcess(rate_qps=25, seed=1)
                                 .times(1.2))
    assert c["submitted"] == c["served"] + c["shed"] + c["failed"]
    assert c["shed"] == 0 and c["failed"] == 0 and c["served"] > 10
    for r in rep.queries:
        assert r.tenant == "t"
        assert r.scalar == ref[r.workload_idx]
        assert 0.0 <= r.arrival_s < 1.2
        assert r.wall_s >= r.service_s > 0  # sojourn includes queueing
        assert r.slo_ok
    assert rep.slo_attainment("t") == 1.0
    assert rep.tenant_counts("t") == c
    assert rep.tenant_latency("t").n == c["served"]
    assert rep.governor.over_budget_events == 0


def test_open_loop_validates_inputs():
    server, q_agg, _ = make_server()
    t = TenantClass("t", deadline_s=1.0)
    ap = ArrivalProcess(rate_qps=1)
    with pytest.raises(ValueError):  # workload key mismatch
        server.serve_open({"other": [q_agg]}, {"t": ap}, 1.0, [t])
    with pytest.raises(ValueError):  # empty workload
        server.serve_open({"t": []}, {"t": ap}, 1.0, [t])
    with pytest.raises(ValueError):  # duplicate tenants
        server.serve_open({"t": [q_agg]}, {"t": ap}, 1.0, [t, t])
    with pytest.raises(ValueError):
        server.serve_open({"t": [q_agg]}, {"t": ap}, 0.0, [t])
    with pytest.raises(ValueError):
        server.serve_open({"t": [q_agg]}, {"t": ap}, 1.0, [t], workers=0)


def test_open_loop_sheds_under_storm_but_does_not_starve():
    server, _, q_sort = make_server(n=60_000)
    be = TenantClass("be", deadline_s=0.06)
    rep = server.serve_open(
        workloads={"be": [q_sort]},
        arrivals={"be": ArrivalProcess(
            phases=[(0.25, 20), (0.5, 500), (0.5, 20)], seed=2)},
        duration_s=1.25, tenants=[be], workers=2, warmup=1)
    c = rep.counts
    assert c["submitted"] == c["served"] + c["shed"] + c["failed"]
    assert c["shed"] > 0, f"storm never shed: {c}"
    assert c["served"] > 0, f"tenant starved: {c}"
    for s in rep.shed:
        assert s.quoted_wait_s > s.deadline_s == 0.06
    # deadline misses that slipped past admission are failed, never served
    for f in rep.failed:
        assert f.error == "DeadlineExceeded"
    assert rep.governor.over_budget_events == 0


def test_open_loop_nonsheddable_tenant_always_runs():
    server, _, q_sort = make_server(n=60_000)
    prem = TenantClass("prem", deadline_s=0.02, priority=1, sheddable=False)
    rep = server.serve_open(
        workloads={"prem": [q_sort]},
        arrivals={"prem": ArrivalProcess(
            phases=[(0.4, 150)], seed=3)},
        duration_s=0.4, tenants=[prem], workers=2, warmup=1)
    c = rep.tenant_counts("prem")
    # never shed, never deadline-failed: every arrival is served, and the
    # (inevitable, deadline is 20ms) SLO misses land on the served records
    assert c["shed"] == 0 and c["failed"] == 0
    assert c["served"] == c["submitted"] > 0
    assert rep.slo_attainment("prem") < 1.0


def test_open_loop_priority_tenant_served_ahead():
    server, q_agg, q_sort = make_server(n=60_000)
    prem = TenantClass("prem", deadline_s=5.0, priority=2, sheddable=False)
    be = TenantClass("be", deadline_s=5.0, priority=0)
    rep = server.serve_open(
        workloads={"prem": [q_agg], "be": [q_sort]},
        arrivals={"prem": ArrivalProcess(rate_qps=15, seed=4),
                  "be": ArrivalProcess(
                      phases=[(0.3, 10), (0.5, 300), (0.4, 10)], seed=5)},
        duration_s=1.2, tenants=[prem, be], workers=2, warmup=1)
    prem_lat = rep.tenant_latency("prem")
    be_lat = rep.tenant_latency("be")
    assert prem_lat is not None and be_lat is not None
    # the priority queue drains premium first: through the same storm its
    # p99 sojourn stays well under the backlogged best-effort p99
    assert prem_lat.p99 < be_lat.p99
    assert rep.tenant_counts("prem")["shed"] == 0


# -- failure records ---------------------------------------------------------

def test_closed_loop_records_failures_and_keeps_serving():
    server, q_agg, _ = make_server()
    ref = serial_scalars()
    rep = server.serve([q_agg, object()], concurrency=2,
                       queries_per_worker=4, warmup=0)
    assert rep.submitted == 8
    assert len(rep.queries) == 4 and len(rep.failed) == 4
    assert rep.submitted == len(rep.queries) + len(rep.failed)
    for r in rep.queries:
        assert r.workload_idx == 0 and r.scalar == ref[0]
    for f in rep.failed:
        assert f.workload_idx == 1 and f.error  # typed, non-empty class name


def test_closed_loop_aborts_on_broker_invariant_violation():
    server, q_agg, _ = make_server()

    def poisoned(query):
        raise BrokerInvariantViolation("budget accounting corrupted")

    server.submit = poisoned
    with pytest.raises(BrokerInvariantViolation):
        server.serve([q_agg], concurrency=2, queries_per_worker=2, warmup=0)


def test_open_loop_records_failures_as_samples():
    server, q_agg, _ = make_server()
    t = TenantClass("t", deadline_s=10.0)
    rep = server.serve_open(
        workloads={"t": [q_agg, object()]},
        arrivals={"t": ArrivalProcess(rate_qps=30, seed=6)},
        duration_s=0.8, tenants=[t], workers=2, warmup=0)
    c = rep.counts
    assert c["failed"] > 0 and c["served"] > 0
    assert c["submitted"] == c["served"] + c["shed"] + c["failed"]
    for f in rep.failed:
        assert f.tenant == "t" and f.error


# -- preemption --------------------------------------------------------------

def test_preemption_requeues_degraded_linear_op_on_tensor_path():
    n = 400_000
    rng = np.random.default_rng(1)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1000, n).astype(np.int64)})
    ref = Session(work_mem=256 * MB)
    ref.register("b", build).register("p", probe)
    want = ref.table("p").join("b", on="k").aggregate("b_v", "sum").scalar()

    gov = MemoryGovernor(4 * MB, min_grant=1 * MB)
    broker = ResourceBroker(gov)
    sess = Session(work_mem=64 * MB, policy="linear", broker=broker)
    sess.register("b", build).register("p", probe)

    preempted = threading.Event()

    def watcher():
        deadline = time.time() + 30
        while time.time() < deadline and not preempted.is_set():
            if broker.preempt_degraded() > 0:
                preempted.set()
                return
            time.sleep(0.001)

    th = threading.Thread(target=watcher, daemon=True)
    th.start()
    # the 6.4 MB hash build against a 4 MB pool degrades to the floor and
    # enters the grace-join spill regime, where it polls its preempt token
    res = (sess.table("p").join("b", on="k").aggregate("b_v", "sum")
           .collect())
    preempted.set()
    th.join(timeout=5)
    assert res.scalar == want
    assert any(m.preempted for m in res.metrics), \
        "the degraded linear join was never preempted onto the tensor path"
    s = broker.stats()
    assert s.preemptions >= 1 and s.preempt_registered >= 1
    # the abandoned spill released everything it held
    assert gov.stats().over_budget_events == 0
    assert gov.in_use == 0 and gov.held_bytes == 0


# -- crash-consistent spill finalize ----------------------------------------

def test_spill_write_is_atomic_under_midwrite_crash(tmp_path):
    inj = FaultInjector(seed=0)
    mgr = SpillManager(root=str(tmp_path), faults=inj)
    rel = Relation({"a": np.arange(100), "b": np.arange(100) * 2,
                    "c": np.arange(100) * 3})
    acct = SpillAccount()
    inj.arm_spill_kill(after_columns=2)  # die mid-write, after one column
    with pytest.raises(SimulatedCrash):
        mgr.write_relation(rel, "run", acct)
    # the wreck is quarantined in .tmp; no final-named dir ever appeared,
    # so no reader can observe a truncated relation
    entries = sorted(os.listdir(mgr.dir))
    assert entries and all(e.endswith(".tmp") for e in entries)
    assert acct.files_created == 0
    # the manager keeps working after the crash, and the published run is
    # complete and bit-for-bit intact
    base = mgr.write_relation(rel, "run", SpillAccount())
    got = mgr.read_relation(base, SpillAccount())
    for name in rel.columns:
        assert np.array_equal(got[name], rel[name])
    mgr.cleanup()


def test_spill_write_cleans_tmp_on_ordinary_failure(tmp_path):
    inj = FaultInjector(seed=0, spill_io_p=1.0)
    mgr = SpillManager(root=str(tmp_path), faults=inj)
    rel = Relation({"a": np.arange(10)})
    with pytest.raises(OSError):
        mgr.write_relation(rel, "run", SpillAccount())
    # a survivable failure runs its handlers: no staging dir leaks
    assert os.listdir(mgr.dir) == []
    mgr.cleanup()


# -- the hammer: invariants under storm + chaos + preemption -----------------

def _hammer(duration_s, storm_qps, n=60_000):
    inj = FaultInjector(seed=3, spill_io_p=0.01, device_fail_p=0.02,
                        device_slow_p=0.03, device_slow_s=0.002,
                        grant_timeout_p=0.01)
    build, probe = star_tables(n)
    server = QueryServer({"b": build, "p": probe}, total_mem=12 * MB,
                         work_mem=8 * MB, min_grant=1 * MB,
                         full_grant_wait_s=0.01, faults=inj)
    q_agg = (server.session.table("p").join("b", on="k")
             .aggregate("b_v", "sum"))
    q_sort = (server.session.table("p").join("b", on="k").sort("k", "w")
              .aggregate("b_v", "sum"))
    s = Session(work_mem=64 * MB)
    s.register("b", build).register("p", probe)
    ref = {0: s.table("p").join("b", on="k").aggregate("b_v", "sum")
              .scalar(),
           1: (s.table("p").join("b", on="k").sort("k", "w")
               .aggregate("b_v", "sum").scalar())}
    prem = TenantClass("prem", deadline_s=5.0, priority=2, sheddable=False)
    be = TenantClass("be", deadline_s=0.08)
    rep = server.serve_open(
        workloads={"prem": [q_agg, q_sort], "be": [q_sort, q_agg]},
        arrivals={"prem": ArrivalProcess(rate_qps=10, seed=7),
                  "be": ArrivalProcess(
                      phases=[(0.3, 20), (duration_s - 0.6, storm_qps),
                              (0.3, 20)], seed=8)},
        duration_s=duration_s, tenants=[prem, be], workers=3, warmup=1)
    return server, rep, ref


def check_hammer_invariants(server, rep, ref):
    c = rep.counts
    # 1. exactly-one-of accounting: nothing lost, nothing double-counted
    assert c["submitted"] == c["served"] + c["shed"] + c["failed"]
    # 2. never over budget, even while shedding / preempting / faulting
    g = server.governor.stats()
    assert g.over_budget_events == 0
    assert g.peak_in_use <= server.governor.total_bytes
    # 3. no leaked reservations: every hold converted, expired or cancelled,
    #    and nothing is still held at quiesce
    assert g.holds == (g.holds_converted + g.holds_expired
                       + g.holds_cancelled)
    assert server.governor.held_bytes == 0
    assert server.governor.in_use == 0
    # 4. what was served is bit-for-bit right, chaos or not
    for r in rep.queries:
        assert r.scalar == ref[r.workload_idx]
    # 5. non-sheddable tenant served everything it submitted
    prem = rep.tenant_counts("prem")
    assert prem["shed"] == 0
    # 6. failures (if any) are typed, not raw crashes of the harness
    for f in rep.failed:
        assert f.error


def test_hammer_storm_chaos_invariants():
    server, rep, ref = _hammer(duration_s=1.4, storm_qps=250)
    check_hammer_invariants(server, rep, ref)
    assert rep.counts["shed"] > 0  # the storm genuinely overloaded the pool


@pytest.mark.slow
def test_hammer_storm_chaos_invariants_nightly():
    # the nightly-scale variant: a longer storm, more arrivals, same gates
    server, rep, ref = _hammer(duration_s=6.0, storm_qps=400)
    check_hammer_invariants(server, rep, ref)
    assert rep.counts["shed"] > 0
    assert rep.counts["served"] > 50

