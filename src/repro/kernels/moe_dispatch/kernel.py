"""Pallas TPU kernel: one-hot MoE dispatch/combine as masked matmuls.

The pure-JAX tensor path materializes the dispatch mask [T, E, C] in HBM
(repro.models.moe._dispatch_einsum).  This kernel is the paper's
"delay materialization" applied at the kernel level: the one-hot tile is
built *in VMEM registers* from the routing indices (iota compares) and
consumed immediately by the MXU matmul — the [T, E, C] tensor never exists
in HBM.  HBM traffic drops from O(T·E·C) to O(T·d + E·C·d).

Dispatch:  buf[e, c, :]  = Σ_t  1[eidx_t = e ∧ slot_t = c] · x[t, :]
Combine:   y[t, :]       = Σ_e  w_t · 1[eidx_t = e] · buf[e, slot_t, :]

Grid/BlockSpec layout (dispatch):
  grid = (E, d/dblk, T/tblk)  — t is the innermost (reduction) axis; the
  output block for a fixed (e, dblk) stays resident in VMEM across all t
  steps and accumulates (classic revisiting-output reduction pattern).
  VMEM working set per step: tblk·dblk (x tile) + C·dblk (out tile)
  + tblk (indices) — sized well under 16 MB for the default tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dispatch_pallas", "combine_pallas"]


def _dispatch_kernel(eidx_ref, slot_ref, x_ref, out_ref, *, capacity, tblk):
    e = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    eidx = eidx_ref[...]          # [tblk] i32
    slot = slot_ref[...]          # [tblk] i32
    x = x_ref[...]                # [tblk, dblk]
    # build the one-hot tile in VMEM: [tblk, C]; slot >= C never matches the
    # iota → overflow assignments drop, same semantics as the jnp paths
    hit = (eidx == e)
    onehot = jnp.where(
        hit[:, None] & (slot[:, None] == jax.lax.iota(jnp.int32, capacity)[None, :]),
        1.0, 0.0).astype(x.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)[None]  # [1, C, dblk]


def dispatch_pallas(x, eidx, slot, num_experts: int, capacity: int,
                    *, tblk: int = 512, dblk: int = 512,
                    interpret: bool = False):
    """x [T, d]; eidx/slot [T] (single routing slot; caller loops k).
    Returns buf [E, C, d]."""
    T, d = x.shape
    tblk = min(tblk, T)
    dblk = min(dblk, d)
    assert T % tblk == 0 and d % dblk == 0, (T, tblk, d, dblk)
    grid = (num_experts, d // dblk, T // tblk)
    kernel = functools.partial(_dispatch_kernel, capacity=capacity, tblk=tblk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tblk,), lambda e, j, t: (t,)),
            pl.BlockSpec((tblk,), lambda e, j, t: (t,)),
            pl.BlockSpec((tblk, dblk), lambda e, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((1, capacity, dblk), lambda e, j, t: (e, 0, j)),
        out_shape=jax.ShapeDtypeStruct((num_experts, capacity, d), x.dtype),
        interpret=interpret,
    )(eidx, slot, x)


def _combine_kernel(eidx_ref, slot_ref, w_ref, buf_ref, out_ref, *, capacity):
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    eidx = eidx_ref[...]          # [tblk]
    slot = slot_ref[...]          # [tblk]
    w = w_ref[...]                # [tblk]
    buf = buf_ref[...][0]         # [C, dblk]
    hit = (eidx == e)
    onehot = jnp.where(
        hit[:, None] & (slot[:, None] == jax.lax.iota(jnp.int32, capacity)[None, :]),
        1.0, 0.0).astype(buf.dtype) * w[:, None].astype(buf.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot, buf, (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)  # [tblk, dblk]


def combine_pallas(buf, eidx, slot, w, *, tblk: int = 512, dblk: int = 512,
                   interpret: bool = False):
    """buf [E, C, d]; eidx/slot/w [T].  Returns y [T, d]."""
    E, C, d = buf.shape
    T = eidx.shape[0]
    tblk = min(tblk, T)
    dblk = min(dblk, d)
    assert T % tblk == 0 and d % dblk == 0, (T, tblk, d, dblk)
    grid = (T // tblk, d // dblk, E)
    kernel = functools.partial(_combine_kernel, capacity=C)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tblk,), lambda t, j, e: (t,)),
            pl.BlockSpec((tblk,), lambda t, j, e: (t,)),
            pl.BlockSpec((tblk,), lambda t, j, e: (t,)),
            pl.BlockSpec((1, C, dblk), lambda t, j, e: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((tblk, dblk), lambda t, j, e: (t, j)),
        out_shape=jax.ShapeDtypeStruct((T, d), buf.dtype),
        interpret=interpret,
    )(eidx, slot, w, buf)
