"""Pallas kernel validation: interpret=True vs pure-jnp oracles, with
shape/dtype sweeps (assignment requirement: per kernel, sweep shapes/dtypes
and assert_allclose against the ref.py oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moe_dispatch.ops import combine, dispatch, moe_dispatch_pallas
from repro.kernels.moe_dispatch.ref import combine_ref, dispatch_ref
from repro.kernels.multikey_sort.ops import multikey_sort_lsd, tile_sort
from repro.kernels.multikey_sort.ref import tile_sort_ref
from repro.kernels.segment_join.ops import join_aggregate_kernel, segment_sum
from repro.kernels.segment_join.ref import segment_sum_ref


# ---------------------------------------------------------------------------
# moe_dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,d,E,C", [
    (256, 128, 4, 64),
    (512, 256, 8, 128),
    (1024, 128, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_dispatch_sweep(T, d, E, C, dtype):
    rng = np.random.default_rng(T + E)
    x = jnp.asarray(rng.normal(size=(T, d)), dtype)
    eidx = jnp.asarray(rng.integers(0, E, T), jnp.int32)
    slot = jnp.asarray(rng.integers(0, C + C // 4, T), jnp.int32)  # overflow mix
    w = jnp.asarray(rng.random(T), jnp.float32)
    buf = dispatch(x, eidx, slot, E, C, interpret=True)
    buf_r = dispatch_ref(x, eidx, slot, E, C)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(buf, np.float32),
                               np.asarray(buf_r, np.float32), rtol=tol, atol=tol)
    y = combine(buf_r, eidx, slot, w, interpret=True)
    y_r = combine_ref(buf_r, eidx, slot, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32), rtol=tol, atol=tol)


def test_moe_dispatch_matches_model_einsum_path():
    """The kernel path reproduces the model's einsum dispatch end to end."""
    from repro.configs import get_smoke_config
    from repro.models.moe import (_dispatch_einsum, _expert_ffn, _route,
                                  capacity_per_expert, init_moe)
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    T = 128
    x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model), jnp.float32)
    topk_idx, topk_w, _ = _route(params, x, cfg)
    cap = capacity_per_expert(T, cfg.num_experts, cfg.experts_per_token,
                              cfg.capacity_factor)
    y_einsum = _dispatch_einsum(params, x, topk_idx, topk_w, cfg, cap)
    y_kernel = moe_dispatch_pallas(params, x, topk_idx, topk_w, cfg, cap,
                                   _expert_ffn, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_einsum),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# multikey_sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,tile", [(256, 64), (1024, 256), (2048, 2048)])
@pytest.mark.parametrize("domain", [8, 1 << 20])
def test_bitonic_tile_sort_sweep(n, tile, domain):
    rng = np.random.default_rng(n + domain)
    keys = jnp.asarray(rng.integers(0, domain, n), jnp.int32)
    vals = jnp.asarray(rng.permutation(n), jnp.int32)
    ks, vs = tile_sort(keys, vals, tile=tile, interpret=True)
    kr, vr = tile_sort_ref(keys, vals, tile)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vr))


def test_bitonic_stability_via_index_payload():
    n = 512
    keys = jnp.zeros(n, jnp.int32)  # all equal keys
    vals = jnp.arange(n, dtype=jnp.int32)
    ks, vs = tile_sort(keys, vals, tile=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(vs), np.arange(n))


@pytest.mark.parametrize("nkeys", [1, 2, 3])
def test_multikey_sort_lsd_matches_lexsort(nkeys):
    rng = np.random.default_rng(nkeys)
    n = 1024
    cols = tuple(jnp.asarray(rng.integers(0, 16, n), jnp.int32)
                 for _ in range(nkeys))
    perm = multikey_sort_lsd(cols, tile=256, interpret=True)
    ref = np.lexsort([np.asarray(c) for c in cols[::-1]])
    got = np.stack([np.asarray(c)[np.asarray(perm)] for c in cols])
    want = np.stack([np.asarray(c)[ref] for c in cols])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# segment_join
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,S,tblk", [(2048, 64, 512), (4096, 256, 1024),
                                      (1024, 1024, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_segment_sum_sweep(n, S, tblk, dtype):
    rng = np.random.default_rng(n + S)
    seg = jnp.asarray(rng.integers(0, S, n), jnp.int32)
    val = jnp.asarray(rng.normal(size=n), dtype)
    got = segment_sum(seg, val, S, tblk=tblk, interpret=True)
    want = segment_sum_ref(seg, val, S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_join_aggregate_kernel_matches_core():
    """Kernel-path fused aggregate join == relational-core tensor path."""
    from repro.core import Relation, tensor_join_aggregate
    rng = np.random.default_rng(9)
    nb, npr, dom = 2048, 4096, 128
    bk = rng.integers(0, dom, nb)
    pk = rng.integers(0, dom, npr)
    bv = rng.integers(0, 50, nb).astype(np.float64)
    pv = rng.integers(0, 50, npr).astype(np.float64)
    agg = join_aggregate_kernel(
        jnp.asarray(bk, jnp.int32), jnp.asarray(bv, jnp.float32),
        jnp.asarray(pk, jnp.int32), jnp.asarray(pv, jnp.float32),
        dom, interpret=True)
    core, _ = tensor_join_aggregate(
        Relation({"k": bk.astype(np.int64), "v": bv}),
        Relation({"k": pk.astype(np.int64), "w": pv}),
        "k", "v", "w", key_domain=dom)
    np.testing.assert_allclose(float(agg["count"]), core["count"], rtol=1e-6)
    np.testing.assert_allclose(float(agg["sum_prod"]), core["sum_prod"], rtol=1e-5)
    np.testing.assert_allclose(float(agg["sum_add"]), core["sum_add"], rtol=1e-5)
