"""Regime-shift cost model (paper §VI).

    T_rel(N)    = O(N) + α(N, M)
    T_tensor(N) ≈ O(N)

α(N, M) is the spill-amplification term: once the linearized intermediate
(hash table / sort working set) exceeds the memory budget M, the operator
repartitions and re-materializes data through temp files.  Both the number of
partitioning/merge passes and the re-materialized volume grow with the memory
deficit W/M, making α superlinear in it.

The constants (seconds/row, seconds/byte of temp I/O) are host-dependent; the
model ships with conservative defaults and a ``calibrate()`` routine that fits
them from micro-runs of both engines — mirroring how the paper's selector uses
"indicators that are relatively easy to observe at the time of execution"
rather than a full optimizer-grade cost model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .linear_engine import MAX_FANOUT, MERGE_BUFFER_BYTES, table_bytes_estimate

__all__ = ["CostConstants", "CostModel"]


@dataclasses.dataclass
class CostConstants:
    # CPU work per row (seconds/row)
    linear_row_cost: float = 2.0e-8
    tensor_row_cost: float = 6.0e-8  # tensor path pays sort overhead at small N
    # temp-file I/O cost (seconds/byte, counts write+read)
    io_byte_cost: float = 1.2e-9
    # fixed dispatch overhead of launching the tensor path (jit call, transfers)
    tensor_fixed_cost: float = 3.0e-3


@dataclasses.dataclass
class JoinEstimate:
    path_fits_mem: bool
    spill_bytes: int
    passes: int
    t_linear: float
    t_tensor: float


@dataclasses.dataclass
class SortEstimate:
    path_fits_mem: bool
    spill_bytes: int
    passes: int
    t_linear: float
    t_tensor: float


class CostModel:
    def __init__(self, constants: Optional[CostConstants] = None):
        self.c = constants or CostConstants()

    # -- α(N, M) -------------------------------------------------------------
    def join_spill_bytes(self, n_build: int, n_probe: int, row_bytes_b: int,
                         row_bytes_p: int, work_mem: int) -> tuple:
        """Grace-join spill volume: every partitioning level rewrites both inputs."""
        table = table_bytes_estimate(n_build)
        if table <= work_mem:
            return 0, 0
        fanout = min(MAX_FANOUT, max(2, 2 ** math.ceil(math.log2(table / work_mem))))
        depth = max(1, math.ceil(math.log(table / work_mem, fanout)))
        data = n_build * row_bytes_b + n_probe * row_bytes_p
        written = data * depth
        return int(written), depth

    def sort_spill_bytes(self, n_rows: int, row_bytes: int, work_mem: int) -> tuple:
        """External-sort spill: initial runs + one full rewrite per merge pass."""
        data = n_rows * row_bytes
        if data <= work_mem:
            return 0, 0
        runs = math.ceil(data / work_mem)
        fan_in = max(2, work_mem // MERGE_BUFFER_BYTES - 1)
        merge_passes = max(0, math.ceil(math.log(runs, fan_in)))
        written = data * (1 + max(0, merge_passes - 1))  # final pass streams out
        return int(written), merge_passes

    def alpha(self, spill_bytes: int) -> float:
        # write + read back: 2x the written volume crosses the I/O boundary
        return self.c.io_byte_cost * 2 * spill_bytes

    # -- operator estimates ------------------------------------------------
    def estimate_join(self, n_build: int, n_probe: int, row_bytes_b: int,
                      row_bytes_p: int, est_out: int, work_mem: int) -> JoinEstimate:
        n = n_build + n_probe
        spill, passes = self.join_spill_bytes(
            n_build, n_probe, row_bytes_b, row_bytes_p, work_mem)
        t_linear = self.c.linear_row_cost * (n + est_out) + self.alpha(spill)
        logn = max(1.0, math.log2(max(2, n_build)))
        t_tensor = (self.c.tensor_fixed_cost
                    + self.c.tensor_row_cost * (n_build * logn / 20 + n_probe + est_out))
        return JoinEstimate(spill == 0, spill, passes, t_linear, t_tensor)

    def estimate_sort(self, n_rows: int, row_bytes: int, num_keys: int,
                      work_mem: int) -> SortEstimate:
        spill, passes = self.sort_spill_bytes(n_rows, row_bytes, work_mem)
        logn = max(1.0, math.log2(max(2, n_rows)))
        t_linear = self.c.linear_row_cost * n_rows * logn / 4 + self.alpha(spill)
        t_tensor = (self.c.tensor_fixed_cost
                    + self.c.tensor_row_cost * n_rows * logn / 16 * num_keys)
        return SortEstimate(spill == 0, spill, passes, t_linear, t_tensor)

    # -- calibration -----------------------------------------------------------
    def calibrate(self, n: int = 200_000, seed: int = 0) -> CostConstants:
        """Fit constants from micro-runs of both engines (paper: selector inputs
        are execution-time observables, not optimizer statistics)."""
        from .linear_engine import hash_join_linear, sort_linear
        from .relation import Relation
        from .tensor_engine import tensor_join, tensor_sort

        rng = np.random.default_rng(seed)
        build = Relation({"k": rng.permutation(n).astype(np.int64),
                          "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
        probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                          "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
        big_mem = 1 << 34
        _, m_lin = hash_join_linear(build, probe, "k", big_mem)
        # warm the jit cache, then measure
        tensor_join(build, probe, "k")
        _, m_ten = tensor_join(build, probe, "k")
        self.c.linear_row_cost = max(1e-9, m_lin.wall_s / (3 * n))
        logn = math.log2(n)
        self.c.tensor_row_cost = max(
            1e-9, (m_ten.wall_s - self.c.tensor_fixed_cost) / (n * logn / 20 + 2 * n))

        # io cost: spilled sort vs in-memory sort on identical data
        rel = Relation({"a": rng.integers(0, 1000, n).astype(np.int64),
                        "b": rng.integers(0, 1 << 40, n).astype(np.int64),
                        "p": rng.integers(0, 1 << 40, n).astype(np.int64)})
        _, m_mem = sort_linear(rel, ["a", "b"], big_mem)
        _, m_spill = sort_linear(rel, ["a", "b"], 1 << 20)
        io_bytes = m_spill.spill.bytes_written + m_spill.spill.bytes_read
        if io_bytes:
            self.c.io_byte_cost = max(
                1e-11, (m_spill.wall_s - m_mem.wall_s) / io_bytes)
        return self.c
