"""Regime-shift cost model (paper §VI).

    T_rel(N)    = O(N) + α(N, M)
    T_tensor(N) ≈ O(N)

α(N, M) is the spill-amplification term: once the linearized intermediate
(hash table / sort working set) exceeds the memory budget M, the operator
repartitions and re-materializes data through temp files.  Both the number of
partitioning/merge passes and the re-materialized volume grow with the memory
deficit W/M, making α superlinear in it.

The constants (seconds/row, seconds/byte of temp I/O) are host-dependent; the
model ships with conservative defaults and a ``calibrate()`` routine that fits
them from micro-runs of both engines — mirroring how the paper's selector uses
"indicators that are relatively easy to observe at the time of execution"
rather than a full optimizer-grade cost model.

These estimates price *execution*.  Under a :class:`~repro.core.
resource_broker.ResourceBroker` the selector additionally folds the broker's
**queue-wait terms** (expected memory-admission wait onto T_rel, expected
device-queue wait onto T_tensor) on top of the feedback-blended estimates —
current load is a property of this instant's queues, not a cost to learn,
which is why it is added after the blend and never recorded into the profile
(see ``docs/costing.md``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .linear_engine import MAX_FANOUT, MERGE_BUFFER_BYTES, table_bytes_estimate

__all__ = ["CostConstants", "CostModel", "FragmentEstimate"]


@dataclasses.dataclass
class CostConstants:
    """Host-dependent constants; defaults retuned (PR 2) from micro-runs of
    the *actual* engines on the development host, not the seed's estimates.
    The seed constants described a hypothetical fast linear path (20 ns/row,
    1.2 ns/B of temp I/O) that underestimated the real spilling engine ~30x —
    the direct cause of the N=50k selector regret.  ``calibrate()`` refits
    everything here; the runtime feedback profile corrects residual drift."""

    # CPU work per row (seconds/row)
    linear_row_cost: float = 1.8e-7
    tensor_row_cost: float = 2.5e-7  # per-operator device-resident path
    # temp-file I/O cost (seconds/byte, counts write+read).  Dominated by the
    # partition/merge bookkeeping around the I/O, not raw disk bandwidth.
    io_byte_cost: float = 2.0e-8
    # fixed dispatch overhead of launching one tensor-path operator
    tensor_fixed_cost: float = 1.5e-3
    # -- v2: fused device-resident fragment terms ---------------------------
    # ONE dispatch for a whole Join→[Filter]→[Sort]→[Aggregate] fragment:
    # fusion amortizes the fixed cost across its operators
    fused_fixed_cost: float = 8.0e-4
    fused_row_cost: float = 2.0e-7   # per row through the fused program
    # each device→host synchronization (blocking scalar read / result fetch)
    host_sync_cost: float = 5.0e-5
    # host→device transfer (seconds/byte); multiplied by the *pending* upload
    # bytes — zero for base tables already resident in the device cache
    h2d_byte_cost: float = 1.0e-10
    # -- v7: sharded (partition-parallel) fragment terms --------------------
    # per-lane dispatch overhead of a gang launch, as a fraction of
    # fused_fixed_cost per mesh device
    shard_lane_cost: float = 0.15
    # per-row discount of partition-resident work: each partition's run
    # fits a cache level the monolithic working set overflows
    shard_residency_discount: float = 0.75
    # -- v8: spill-tier terms (the priced staircase) ------------------------
    # seconds/byte through the compressed host-RAM tier (T0): a codec pass
    # (dict-encode + bit-pack), not an fsync — an order of magnitude under
    # io_byte_cost
    t0_byte_cost: float = 1.5e-9
    # seconds/byte through the emulated remote tier (T1): bandwidth-capped
    # transfer + amortized latency; overridden per-quote by the hierarchy's
    # configured service model when one is attached
    t1_byte_cost: float = 6.0e-9
    # fraction of T1/T2 *re-read* latency hidden by the async T2→T0
    # prefetcher (build partitions stream back up while the probe side is
    # still being consumed)
    tier_prefetch_overlap: float = 0.5
    # -- v9: execution-time guard terms (mid-query re-planning) -------------
    # relative drift an ExecutionGuard tolerates before it even considers
    # switching: observed wall / spill may exceed the decision's estimate by
    # this fraction without firing.  Wide enough that ordinary estimate
    # noise (the runtime profile's own residual) stays inside the band.
    guard_band: float = 0.35
    # margin the priced tensor takeover must win by before a SwitchPoint is
    # taken: switch only when t_switch * guard_hysteresis < t_remaining.
    # >1 makes a borderline operator stay put — combined with the guard's
    # fire-once disarm, the decision can never flip twice.
    guard_hysteresis: float = 1.25
    # fixed overhead of abandoning a linear operator mid-query (tearing
    # down its partial state, re-entering the executor's tensor path)
    switch_fixed_cost: float = 2.0e-3


@dataclasses.dataclass
class JoinEstimate:
    path_fits_mem: bool
    spill_bytes: int
    passes: int
    t_linear: float
    t_tensor: float


@dataclasses.dataclass
class SortEstimate:
    path_fits_mem: bool
    spill_bytes: int
    passes: int
    t_linear: float
    t_tensor: float


@dataclasses.dataclass
class FragmentEstimate:
    """Plan-level estimate for a Join→[Filter]→[Sort]→[Aggregate] fragment."""

    path_fits_mem: bool   # whole linear fragment (join AND sort) avoids spill
    spill_bytes: int      # total predicted temp bytes across the fragment
    passes: int
    t_linear: float
    t_tensor: float       # the FUSED device-resident pipeline
    # pending host→device bytes charged to the tensor path — PHYSICAL bytes:
    # under packed device layouts (core/codec_device) the caller's
    # pending_upload_bytes/pending_partition_bytes price codes +
    # dictionaries, so a compressible table makes the tensor candidate
    # cheaper by exactly the bytes the bus is spared
    h2d_bytes: int
    # the partition-parallel fused pipeline over device_count mesh lanes
    # (inf when the fragment is not sharded-eligible or device_count <= 1)
    t_tensor_sharded: float = math.inf
    # the linear fragment with its spill routed through the tier staircase
    # (T0 compressed RAM → T1 emulated remote → T2 disk) instead of the
    # all-disk cliff (inf when no tier hierarchy is configured)
    t_linear_tiered: float = math.inf


class CostModel:
    def __init__(self, constants: Optional[CostConstants] = None):
        self.c = constants or CostConstants()

    # -- linearized-intermediate footprints ---------------------------------
    # One source of truth for "how much memory will this linear operator
    # actually need": the executor sizes its grant requests with these, and
    # the ResourceBroker prices admission (grant + expected wait) against
    # the SAME numbers — a quote probed with a different footprint than the
    # grant request would price the linear path against a queue it will
    # never stand in.

    @staticmethod
    def hash_need_bytes(n_rows: int) -> int:
        """Open-addressing hash-table footprint for an n-row build side
        (also the group-table footprint for n distinct groups)."""
        return table_bytes_estimate(n_rows)

    @staticmethod
    def sort_need_bytes(n_rows: int, row_bytes: int) -> int:
        """External-sort working set: input + run buffers ≈ 2× data."""
        return 2 * max(1, int(n_rows)) * max(1, int(row_bytes))

    # -- α(N, M) -------------------------------------------------------------
    def join_spill_bytes(self, n_build: int, n_probe: int, row_bytes_b: int,
                         row_bytes_p: int, work_mem: int) -> tuple:
        """Grace-join spill volume: every partitioning level rewrites both inputs."""
        table = table_bytes_estimate(n_build)
        if table <= work_mem:
            return 0, 0
        fanout = min(MAX_FANOUT, max(2, 2 ** math.ceil(math.log2(table / work_mem))))
        depth = max(1, math.ceil(math.log(table / work_mem, fanout)))
        data = n_build * row_bytes_b + n_probe * row_bytes_p
        written = data * depth
        return int(written), depth

    def sort_spill_bytes(self, n_rows: int, row_bytes: int, work_mem: int) -> tuple:
        """External-sort spill: initial runs + one full rewrite per merge pass."""
        data = n_rows * row_bytes
        if data <= work_mem:
            return 0, 0
        runs = math.ceil(data / work_mem)
        fan_in = max(2, work_mem // MERGE_BUFFER_BYTES - 1)
        merge_passes = max(0, math.ceil(math.log(runs, fan_in)))
        written = data * (1 + max(0, merge_passes - 1))  # final pass streams out
        return int(written), merge_passes

    def alpha(self, spill_bytes: int) -> float:
        # write + read back: 2x the written volume crosses the I/O boundary
        return self.c.io_byte_cost * 2 * spill_bytes

    def alpha_tiered(self, spill_bytes: int, tier_quotas=None,
                     tier_byte_s=None) -> float:
        """α with the spill volume routed through the tier staircase.

        Fills the predicted volume through (T0, T1, T2) in order: each tier
        absorbs up to its quota at its per-byte service time, the disk tier
        is the unbounded backstop.  ``tier_quotas``/``tier_byte_s`` are the
        (t0, t1, t2) tuples a tiered :class:`~repro.core.resource_broker.
        PressureQuote` carries; missing entries fall back to the model's
        ``t0_byte_cost``/``t1_byte_cost``/``io_byte_cost`` constants.  The
        prefetcher hides ``tier_prefetch_overlap`` of the *re-read* half on
        the I/O tiers (T1/T2); the T0 re-read is a decode, nothing to hide.
        """
        quotas = list(tier_quotas) if tier_quotas is not None else [None, None, None]
        quotas += [None] * (3 - len(quotas))
        costs = list(tier_byte_s) if tier_byte_s is not None else [None, None, None]
        costs += [None] * (3 - len(costs))
        defaults = (self.c.t0_byte_cost, self.c.t1_byte_cost,
                    self.c.io_byte_cost)
        overlap = min(1.0, max(0.0, self.c.tier_prefetch_overlap))
        remaining = max(0, int(spill_bytes))
        t = 0.0
        for i in range(3):
            if remaining <= 0:
                break
            cap = quotas[i]
            take = remaining if (cap is None or i == 2) else min(remaining, int(cap))
            cost = costs[i] if costs[i] is not None else defaults[i]
            # write + read, with the prefetcher discounting I/O-tier re-reads
            read_factor = 1.0 if i == 0 else (1.0 - overlap)
            t += take * cost * (1.0 + read_factor)
            remaining -= take
        return t

    # -- operator estimates ------------------------------------------------
    def estimate_join(self, n_build: int, n_probe: int, row_bytes_b: int,
                      row_bytes_p: int, est_out: int, work_mem: int) -> JoinEstimate:
        n = n_build + n_probe
        spill, passes = self.join_spill_bytes(
            n_build, n_probe, row_bytes_b, row_bytes_p, work_mem)
        t_linear = self.c.linear_row_cost * (n + est_out) + self.alpha(spill)
        logn = max(1.0, math.log2(max(2, n_build)))
        t_tensor = (self.c.tensor_fixed_cost
                    + self.c.tensor_row_cost * (n_build * logn / 20 + n_probe + est_out))
        return JoinEstimate(spill == 0, spill, passes, t_linear, t_tensor)

    def estimate_sort(self, n_rows: int, row_bytes: int, num_keys: int,
                      work_mem: int) -> SortEstimate:
        spill, passes = self.sort_spill_bytes(n_rows, row_bytes, work_mem)
        logn = max(1.0, math.log2(max(2, n_rows)))
        t_linear = self.c.linear_row_cost * n_rows * logn / 4 + self.alpha(spill)
        t_tensor = (self.c.tensor_fixed_cost
                    + self.c.tensor_row_cost * n_rows * logn / 16 * num_keys)
        return SortEstimate(spill == 0, spill, passes, t_linear, t_tensor)

    def estimate_fragment(self, n_build: int, n_probe: int, row_bytes_b: int,
                          row_bytes_p: int, est_out: int, work_mem: int,
                          num_sort_keys: int = 0, has_filter: bool = False,
                          has_agg: bool = False, h2d_bytes: int = 0,
                          filter_selectivity: float = 1.0,
                          device_count: int = 1,
                          partition_skew: float = 1.0,
                          sharded_h2d_bytes: int = 0,
                          tier_quotas=None,
                          tier_byte_s=None) -> FragmentEstimate:
        """Cost a whole fusable fragment instead of its operators in isolation.

        The linear side is the sum of its per-operator costs (join + sort over
        the join output + filter/aggregate scans), each with its own spill
        term.  The tensor side is the FUSED pipeline: ``fused_fixed_cost`` is
        paid once for the entire fragment (fusion amortizes dispatch overhead
        across operators), exactly one host sync is charged, and H2D transfer
        is an explicit term over the *pending* upload bytes — zero when the
        base tables are already device-resident.

        ``filter_selectivity`` (an IR-only observable: the selector samples
        introspectable ``Expr`` predicates, something opaque lambdas never
        allowed) shrinks the rows the LINEAR side sorts/aggregates *after*
        its filter.  The fused tensor side is unaffected by design — its
        shapes are static capacity buckets, filtered rows are masked, not
        removed — which is exactly why a selective filter tilts the
        comparison toward the linear path at small scale.
        """
        join_spill, passes = self.join_spill_bytes(
            n_build, n_probe, row_bytes_b, row_bytes_p, work_mem)
        t_lin = (self.c.linear_row_cost * (n_build + n_probe + est_out)
                 + self.alpha(join_spill))
        spill = join_spill
        post_filter = est_out
        if has_filter:
            t_lin += self.c.linear_row_cost * est_out
            post_filter = int(est_out * min(1.0, max(0.0, filter_selectivity)))
        logo = max(1.0, math.log2(max(2, post_filter)))
        if num_sort_keys:
            out_row_bytes = row_bytes_b + row_bytes_p
            s_spill, s_passes = self.sort_spill_bytes(
                post_filter, out_row_bytes, work_mem)
            t_lin += (self.c.linear_row_cost * post_filter * logo / 4
                      + self.alpha(s_spill))
            spill += s_spill
            passes += s_passes
        if has_agg:
            t_lin += self.c.linear_row_cost * post_filter

        logb = max(1.0, math.log2(max(2, n_build)))
        logo_cap = max(1.0, math.log2(max(2, est_out)))  # static capacity
        rows = n_build * logb / 20 + n_probe + est_out
        if has_filter:
            rows += est_out
        if num_sort_keys:
            rows += est_out * logo_cap / 16 * num_sort_keys
        rows += est_out  # aggregate reduction / root materialization gather
        t_ten = (self.c.fused_fixed_cost + self.c.host_sync_cost
                 + self.c.h2d_byte_cost * h2d_bytes
                 + self.c.fused_row_cost * rows)

        # Sharded tensor path (aggregate roots only): the build-side
        # n·log n sort term DISAPPEARS — the partitioned layout caches
        # key-sorted runs, so per-query work is a searchsorted probe over
        # cache-resident partitions — and the remaining per-row work takes
        # the residency discount.  ``partition_skew`` (max/mean partition
        # fill) inflates the expansion/aggregate terms: the padded
        # capacity, and on a real mesh the critical path, follow the
        # fullest partition.  A sort stage costs nothing here (the
        # supported aggregates are order-independent; the per-shard
        # program skips it).  The gang launch pays a per-lane slice of
        # fixed cost on top of the fused dispatch.
        t_sh = math.inf
        if device_count > 1 and has_agg:
            skew = max(1.0, float(partition_skew))
            disc = self.c.shard_residency_discount
            rows_sh = n_build / 4  # residual touch of the cached runs
            rows_sh += (n_probe + est_out * skew) * disc
            if has_filter:
                rows_sh += est_out * skew * disc
            rows_sh += est_out * disc  # aggregate reduction
            t_sh = (self.c.fused_fixed_cost
                    * (1 + self.c.shard_lane_cost * device_count)
                    + self.c.host_sync_cost
                    + self.c.h2d_byte_cost * sharded_h2d_bytes
                    + self.c.fused_row_cost * rows_sh)
        # Tiered-linear: same CPU work, but the spill volume crosses the
        # tier staircase instead of the all-disk cliff.  α is linear in
        # bytes, so subtracting the fragment's combined disk α and adding
        # the staircase α over the combined volume re-prices exactly the
        # I/O term (the staircase is priced over the fragment's total spill
        # because its operators share one grant's quotas).
        t_tiered = math.inf
        if tier_quotas is not None or tier_byte_s is not None:
            t_tiered = (t_lin - self.alpha(spill)
                        + self.alpha_tiered(spill, tier_quotas, tier_byte_s))
        return FragmentEstimate(spill == 0, int(spill), passes, t_lin, t_ten,
                                int(h2d_bytes), t_tensor_sharded=t_sh,
                                t_linear_tiered=t_tiered)

    # -- execution-time guard pricing ---------------------------------------
    def price_switch(self, rows_pending: int, pending_bytes: int,
                     pairs: int) -> tuple:
        """Price finishing a drifted linear operator vs. a tensor takeover.

        Called from an :class:`~repro.core.guards.ExecutionGuard` checkpoint
        with *observed* remaining work: ``rows_pending`` rows across
        ``pairs`` still-spilled partition pairs occupying ``pending_bytes``
        of live temp space.  Returns ``(t_remaining_linear, t_switch)``.

        The linear remainder must at least read the pending bytes back and
        hash/probe the pending rows; partitions that recurse further pay
        more, so this is a *lower bound* on the linear side — conservative
        in exactly the safe direction (the guard under-fires, never
        over-fires).  The takeover concatenates every reused pair and runs
        ONE gang tensor join (partitions are key-disjoint, so the result
        is byte-identical to per-pair joins), so it pays the fixed switch
        cost, a single dispatch (+2 syncs), per-row tensor work, and the
        H2D transfer of the pending bytes — ``pairs`` does NOT multiply
        the dispatch cost; per-pair takeovers were priced out because
        their fixed cost rivals the linear loop's per-pair work.  The
        read-back is priced at the H2D rate alone: ``io_byte_cost`` is
        fitted on the partition pass (hash + scatter + bookkeeping per
        byte) and overprices a plain sequential spill read by an order
        of magnitude, which would make every takeover look unaffordable.
        """
        c = self.c
        t_rem = (c.linear_row_cost * max(0, int(rows_pending))
                 + c.io_byte_cost * max(0, int(pending_bytes)))
        t_switch = (c.switch_fixed_cost
                    + c.tensor_fixed_cost + 2 * c.host_sync_cost
                    + c.tensor_row_cost * max(0, int(rows_pending))
                    + c.h2d_byte_cost * max(0, int(pending_bytes)))
        return t_rem, t_switch

    # -- calibration -----------------------------------------------------------
    def calibrate(self, n: int = 200_000, seed: int = 0) -> CostConstants:
        """Fit constants from micro-runs of both engines (paper: selector inputs
        are execution-time observables, not optimizer statistics)."""
        from .linear_engine import hash_join_linear, sort_linear
        from .relation import Relation
        from .tensor_engine import tensor_join, tensor_sort

        rng = np.random.default_rng(seed)
        build = Relation({"k": rng.permutation(n).astype(np.int64),
                          "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
        probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                          "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
        big_mem = 1 << 34
        _, m_lin = hash_join_linear(build, probe, "k", big_mem)
        # warm the jit cache, then measure
        tensor_join(build, probe, "k")
        _, m_ten = tensor_join(build, probe, "k")
        self.c.linear_row_cost = max(1e-9, m_lin.wall_s / (3 * n))
        logn = math.log2(n)
        self.c.tensor_row_cost = max(
            1e-9, (m_ten.wall_s - self.c.tensor_fixed_cost) / (n * logn / 20 + 2 * n))

        # io cost: spilled sort vs in-memory sort on identical data
        rel = Relation({"a": rng.integers(0, 1000, n).astype(np.int64),
                        "b": rng.integers(0, 1 << 40, n).astype(np.int64),
                        "p": rng.integers(0, 1 << 40, n).astype(np.int64)})
        _, m_mem = sort_linear(rel, ["a", "b"], big_mem)
        _, m_spill = sort_linear(rel, ["a", "b"], 1 << 20)
        io_bytes = m_spill.spill.bytes_written + m_spill.spill.bytes_read
        if io_bytes:
            self.c.io_byte_cost = max(
                1e-11, (m_spill.wall_s - m_mem.wall_s) / io_bytes)
        self._calibrate_fused(n, rng)
        return self.c

    def _calibrate_fused(self, n: int, rng) -> None:
        """Fit the v2 terms by micro-running the FUSED executor (PR 2): one
        blocking scalar fetch for ``host_sync_cost``, a fresh column upload
        for ``h2d_byte_cost``, and warm fused-fragment runs at two scales to
        separate ``fused_fixed_cost`` from ``fused_row_cost``."""
        import time

        import jax
        import jax.numpy as jnp

        from .fused import FusedSpec, run_fused
        from .relation import Relation

        dev = jnp.asarray(1.0) + 0  # a 0-d value resident on device
        jax.device_get(dev)
        t0 = time.perf_counter()
        reps = 64
        for _ in range(reps):
            jax.device_get(dev)
        self.c.host_sync_cost = max(1e-7, (time.perf_counter() - t0) / reps)

        col = rng.integers(0, 1 << 40, max(n, 1 << 16)).astype(np.int64)
        best = math.inf
        for _ in range(3):
            fresh = col.copy()  # a new buffer cannot be device-cached
            t0 = time.perf_counter()
            jax.block_until_ready(jnp.asarray(fresh))
            best = min(best, time.perf_counter() - t0)
        self.c.h2d_byte_cost = max(1e-13, best / col.nbytes)

        # warm fused Join→Sort→Aggregate fragments at two scales.  With the
        # fragment's row-work model r(m), two walls give two unknowns:
        #   wall(m) = fixed + sync + row_cost * r(m)
        spec = FusedSpec(join_key="k", filter_fn=None, sort_keys=("k",),
                         agg=("b_v", "sum"))

        def rows_model(m: int) -> float:
            logm = max(1.0, math.log2(max(2, m)))
            return m * logm / 20 + m + m + m * logm / 16 + m

        n_small = 4096
        walls = {}
        for m in (n_small, n):
            build = Relation({"k": rng.permutation(m).astype(np.int64),
                              "v": rng.integers(0, 1 << 30, m).astype(np.int64)})
            probe = Relation({"k": rng.integers(0, m, m).astype(np.int64),
                              "w": rng.integers(0, 1 << 30, m).astype(np.int64)})
            run_fused(spec, build, probe)  # cold: compile + upload
            best = math.inf
            for _ in range(3):
                _, metrics = run_fused(spec, build, probe)
                best = min(best, metrics.wall_s)
            walls[m] = best
        d_rows = rows_model(n) - rows_model(n_small)
        if n > n_small and d_rows > 0:
            self.c.fused_row_cost = max(
                1e-10, (walls[n] - walls[n_small]) / d_rows)
        self.c.fused_fixed_cost = max(
            1e-5, walls[n_small] - self.c.fused_row_cost * rows_model(n_small)
            - self.c.host_sync_cost)
