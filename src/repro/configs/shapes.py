"""Assigned input shapes and the (arch × shape) applicability matrix."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from .base import ArchConfig

__all__ = ["ShapeSpec", "SHAPES", "applicable", "all_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Returns (runs?, reason-if-skipped). Skips are per DESIGN.md §5."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k requires sub-quadratic attention; this arch "
                       "has full-attention layers throughout")
    return True, ""


def all_cells():
    """Every runnable (arch, shape) cell, plus the skip list."""
    from .base import get_config, list_archs
    cells, skips = [], []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = applicable(cfg, shape)
            (cells if ok else skips).append((arch, shape.name, why))
    return cells, skips
