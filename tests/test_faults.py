"""Fault-injection harness: taxonomy contracts, retry policy, injector sites."""
import pytest

from repro.core import (DeviceDispatchError, FaultInjector, GrantTimeout,
                        PreemptedError, QueryRejected, RetryPolicy,
                        SimulatedCrash, SpillIOError, TransientError)


# -- taxonomy ----------------------------------------------------------------

def test_transient_subtypes():
    assert issubclass(SpillIOError, TransientError)
    assert issubclass(DeviceDispatchError, TransientError)
    assert issubclass(GrantTimeout, TransientError)


def test_grant_timeout_is_a_timeout_error():
    # fig12's batch tenant catches TimeoutError around memory_lease; an
    # injected grant timeout must keep flowing through that handler
    assert issubclass(GrantTimeout, TimeoutError)
    with pytest.raises(TimeoutError):
        raise GrantTimeout("injected")


def test_spill_io_error_is_an_os_error():
    assert issubclass(SpillIOError, OSError)


def test_simulated_crash_skips_except_exception():
    # a killed worker runs no cleanup handlers: `except Exception` must not
    # see it, only an explicit BaseException handler may
    assert issubclass(SimulatedCrash, BaseException)
    assert not issubclass(SimulatedCrash, Exception)
    with pytest.raises(SimulatedCrash):
        try:
            raise SimulatedCrash("killed")
        except Exception:  # pragma: no cover - must NOT catch
            pytest.fail("except Exception caught a simulated crash")


def test_admission_outcomes_are_not_transient():
    # shedding and deadline misses are final classifications, not retryable
    assert not issubclass(QueryRejected, TransientError)
    assert not issubclass(PreemptedError, TransientError)


# -- retry policy ------------------------------------------------------------

def test_backoff_within_jitter_envelope():
    p = RetryPolicy(max_attempts=5, base_s=0.01, cap_s=0.05, seed=3)
    for attempt in range(1, 10):
        ceiling = min(0.05, 0.01 * 2 ** (attempt - 1))
        for _ in range(20):
            d = p.backoff(attempt)
            assert 0.0 <= d <= ceiling


def test_backoff_is_seeded():
    a = RetryPolicy(seed=11)
    b = RetryPolicy(seed=11)
    assert [a.backoff(i) for i in (1, 2, 3, 4)] == \
           [b.backoff(i) for i in (1, 2, 3, 4)]


def test_retry_policy_validates():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


# -- injector ----------------------------------------------------------------

def test_injector_validates_probabilities():
    with pytest.raises(ValueError):
        FaultInjector(spill_io_p=1.5)
    with pytest.raises(ValueError):
        FaultInjector(device_fail_p=-0.1)


def test_injector_off_by_default():
    inj = FaultInjector(seed=0)
    for _ in range(50):
        inj.on_spill_column("x")
        inj.on_device_dispatch()
        inj.on_memory_grant()
    assert inj.total_injected == 0


def test_injector_certain_faults_fire_and_count():
    inj = FaultInjector(seed=0, spill_io_p=1.0, device_fail_p=1.0,
                        grant_timeout_p=1.0)
    with pytest.raises(SpillIOError):
        inj.on_spill_column("p")
    with pytest.raises(DeviceDispatchError):
        inj.on_device_dispatch()
    with pytest.raises(GrantTimeout):
        inj.on_memory_grant()
    c = inj.counts()
    assert (c["spill_io"], c["device_fail"], c["grant_timeout"]) == (1, 1, 1)
    assert inj.total_injected == 3


def test_injector_schedule_is_seeded():
    def schedule(seed):
        inj = FaultInjector(seed=seed, spill_io_p=0.3)
        fired = []
        for i in range(100):
            try:
                inj.on_spill_column(str(i))
                fired.append(False)
            except SpillIOError:
                fired.append(True)
        return fired

    assert schedule(5) == schedule(5)
    assert schedule(5) != schedule(6)
    assert any(schedule(5))


def test_sites_roll_independent_rngs():
    # enabling one fault class must not perturb another's schedule
    def spill_schedule(with_device: bool):
        inj = FaultInjector(seed=9, spill_io_p=0.3,
                            device_fail_p=0.5 if with_device else 0.0)
        fired = []
        for i in range(60):
            if with_device:
                try:
                    inj.on_device_dispatch()
                except DeviceDispatchError:
                    pass
            try:
                inj.on_spill_column(str(i))
                fired.append(False)
            except SpillIOError:
                fired.append(True)
        return fired

    assert spill_schedule(False) == spill_schedule(True)


def test_arm_spill_kill_counts_down_and_disarms():
    inj = FaultInjector(seed=0)
    inj.arm_spill_kill(after_columns=3)
    inj.on_spill_column("a")
    inj.on_spill_column("b")
    with pytest.raises(SimulatedCrash):
        inj.on_spill_column("c")
    # one-shot: disarmed after firing
    for i in range(10):
        inj.on_spill_column(str(i))
    assert inj.counts()["spill_kill"] == 1
    with pytest.raises(ValueError):
        inj.arm_spill_kill(after_columns=0)
