"""Cross-path differential fuzzing against a numpy oracle.

Every execution path — host linear, device tensor, tiered linear and the
sharded auto configuration — must produce the SAME multiset of rows for
the same logical plan.  The paper's whole premise (one deferred decision
point, many physical routes) only holds if the routes are semantically
interchangeable, so this harness generates random plans over random
tables (duplicate-heavy keys, empty inputs, negative values) and checks
each configuration bit-for-bit against an independent oracle written in
plain numpy/dict Python that shares no code with the engines.

The generator is seeded ``numpy.random`` — no external fuzzing
dependency — so the tier-1 profile is deterministic and fast.  When
``hypothesis`` IS available (it is not baked into the CI image; the test
importorskips) a property-based variant drives the same differential
check from minimized counterexamples.  The ``slow`` variant widens the
case count, sizes and value domains for the nightly run.
"""
import collections

import numpy as np
import pytest

from repro.core import Relation, Session, TierConfig

MB = 1 << 20
AGGS = ("sum", "count", "min", "max")


# ---------------------------------------------------------------------------
# Case generation
# ---------------------------------------------------------------------------

class Case:
    """One generated plan: join -> optional filter -> optional root op."""

    def __init__(self, rng, max_rows=800, neg_keys=False):
        n1 = int(rng.integers(0, max_rows))
        n2 = int(rng.integers(0, max_rows))
        # duplicate-heavy but bounded fan-out: kmax >= n2/8 keeps the
        # joined row count within ~8x the probe side
        lo = -max(1, n2 // 16) if neg_keys else 0
        kmax = max(lo + 1, int(rng.integers(max(1, n2 // 8),
                                            max(2, 2 * max(n1, n2) + 2))))
        self.probe = {
            "k": rng.integers(lo, kmax, n1).astype(np.int64),
            "w": rng.integers(-1000, 1000, n1).astype(np.int64)}
        self.build = {
            "k": rng.integers(lo, kmax, n2).astype(np.int64),
            "v": rng.integers(-1000, 1000, n2).astype(np.int64)}
        self.filter_thr = (int(rng.integers(-500, 500))
                          if rng.random() < 0.6 else None)
        self.root = str(rng.choice(["none", "sort", "group", "agg"]))
        # aggregate over a maybe-empty join: only sum/count are total
        self.fn = str(rng.choice(AGGS[:2] if self.root == "agg" else AGGS))

    def describe(self):
        return (f"n_probe={len(self.probe['k'])} "
                f"n_build={len(self.build['k'])} "
                f"filter={self.filter_thr} root={self.root} fn={self.fn}")


def run_case(sess: Session, case: Case):
    """Build and run the case's plan through one session configuration."""
    from repro.core.expr import col

    sess.register("p", Relation(dict(case.probe)))
    sess.register("b", Relation(dict(case.build)))
    q = sess.table("p").join("b", on="k")
    if case.filter_thr is not None:
        q = q.filter(col("w") > case.filter_thr)
    if case.root == "sort":
        q = q.sort("k", "w")
    elif case.root == "group":
        q = q.group_by("k", {"b_v": case.fn})
    elif case.root == "agg":
        q = q.aggregate("b_v", case.fn)
    res = q.collect()
    return res.scalar if case.root == "agg" else res.relation


# ---------------------------------------------------------------------------
# Oracle: plain numpy/dicts, no engine code
# ---------------------------------------------------------------------------

def oracle(case: Case):
    p, b = case.probe, case.build
    by_key = collections.defaultdict(list)
    for j, k in enumerate(b["k"].tolist()):
        by_key[k].append(j)
    pi, bi = [], []
    for i, k in enumerate(p["k"].tolist()):
        for j in by_key.get(k, ()):
            pi.append(i)
            bi.append(j)
    pi = np.asarray(pi, dtype=np.int64)
    bi = np.asarray(bi, dtype=np.int64)
    cols = {"k": p["k"][pi], "w": p["w"][pi], "b_v": b["v"][bi]}
    if case.filter_thr is not None:
        keep = cols["w"] > case.filter_thr
        cols = {name: c[keep] for name, c in cols.items()}
    if case.root == "agg":
        v = cols["b_v"].astype(np.float64)
        return float(v.sum()) if case.fn == "sum" else float(len(v))
    if case.root == "group":
        uniq, inv = np.unique(cols["k"], return_inverse=True)
        v = cols["b_v"].astype(np.float64)
        if case.fn == "sum":
            agg = np.bincount(inv, weights=v, minlength=len(uniq))
        elif case.fn == "count":
            agg = np.bincount(inv, minlength=len(uniq)).astype(np.float64)
        else:
            fill = np.inf if case.fn == "min" else -np.inf
            agg = np.full(len(uniq), fill)
            (np.minimum if case.fn == "min" else np.maximum).at(agg, inv, v)
        return {"k": uniq, f"{case.fn}_b_v": agg}
    return cols  # "none" and "sort" share a multiset; sortedness is
    #              asserted separately on the engine output


# ---------------------------------------------------------------------------
# Comparison: canonical row order, exact values
# ---------------------------------------------------------------------------

def canon(cols):
    """Rows sorted lexicographically over all columns, column-name order
    fixed — a canonical form under which multiset equality is array
    equality.  All values are exact (int64, or float64 sums far below
    2**53), so no tolerance is needed."""
    names = sorted(cols)
    arrs = [np.asarray(cols[n]) for n in names]
    if len(arrs[0]) == 0:
        return names, arrs
    order = np.lexsort(arrs[::-1])
    return names, [a[order] for a in arrs]


def assert_same(got, want, ctx):
    if isinstance(want, float):
        assert float(got) == want, ctx
        return
    got_cols = {n: got[n] for n in got.names}
    assert set(got_cols) == set(want), (ctx, sorted(got_cols), sorted(want))
    gn, ga = canon(got_cols)
    wn, wa = canon(want)
    for name, g, w in zip(gn, ga, wa):
        np.testing.assert_array_equal(g, w, err_msg=f"{ctx} col={name}")


def assert_sorted(rel, keys):
    cols = [np.asarray(rel[k]) for k in keys]
    if len(cols[0]) < 2:
        return
    for i in range(len(cols[0]) - 1):
        a = tuple(c[i] for c in cols)
        b = tuple(c[i + 1] for c in cols)
        assert a <= b, f"row {i} out of order: {a} > {b}"


# ---------------------------------------------------------------------------
# Session configurations under test
# ---------------------------------------------------------------------------

def configurations(tier_wm=32 * 1024):
    return {
        "linear": Session(work_mem=64 * MB, policy="linear", fuse=False),
        "tensor": Session(work_mem=64 * MB, policy="tensor"),
        "tiered": Session(work_mem=tier_wm, policy="linear",
                          tiers=TierConfig(t1_latency_s=0.0, t1_gbps=1000.0),
                          fuse=False),
        "sharded": Session(work_mem=64 * MB, policy="auto", max_shards=4),
    }


def check_case(case: Case, tier_wm=32 * 1024, compress=None):
    """Run every configuration against the oracle.  ``compress`` pins the
    packed-device-layout toggle for the whole sweep (None = leave the
    process default, which is on): the same plans must agree with the
    oracle whether uploads move logical-width columns or packed codes."""
    import os

    want = oracle(case)
    saved = os.environ.get("REPRO_DEVICE_COMPRESS")
    if compress is not None:
        os.environ["REPRO_DEVICE_COMPRESS"] = "1" if compress else "0"
    try:
        for name, sess in configurations(tier_wm).items():
            got = run_case(sess, case)
            assert_same(got, want, f"[{name}] {case.describe()}")
            if case.root == "sort":
                assert_sorted(got, ("k", "w"))
            if name == "tiered":
                sess.tier_ledger.verify_balanced()
    finally:
        if compress is not None:
            if saved is None:
                os.environ.pop("REPRO_DEVICE_COMPRESS", None)
            else:
                os.environ["REPRO_DEVICE_COMPRESS"] = saved


# ---------------------------------------------------------------------------
# Tier-1 quick profile: deterministic seeded sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_differential_fuzz_quick(seed):
    case = Case(np.random.default_rng(1000 + seed))
    check_case(case)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("compress", [True, False])
def test_differential_fuzz_compression_toggle(seed, compress):
    """The SAME plans, both upload modes: packed codes (dictionary / FOR)
    and raw logical-width columns must be oracle-identical — compression
    is a physical-layout decision, never a semantic one."""
    case = Case(np.random.default_rng(3000 + seed))
    check_case(case, compress=compress)


def test_differential_fuzz_pinned_edges():
    """Edges the random sweep may miss: empty sides, single rows, one
    hot key on every row (maximal duplication)."""
    rng = np.random.default_rng(7)
    for n1, n2, kmax in [(0, 40, 5), (40, 0, 5), (0, 0, 1),
                         (1, 1, 1), (200, 150, 1)]:
        case = Case(rng)
        case.probe = {"k": rng.integers(0, kmax, n1).astype(np.int64),
                      "w": rng.integers(-1000, 1000, n1).astype(np.int64)}
        case.build = {"k": rng.integers(0, kmax, n2).astype(np.int64),
                      "v": rng.integers(-1000, 1000, n2).astype(np.int64)}
        case.filter_thr = None
        case.root = "group"
        case.fn = "sum"
        check_case(case)


def test_differential_fuzz_hypothesis():
    """Property-based variant; runs only where hypothesis is installed
    (it is not part of the baked CI image)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
    def prop(seed):
        check_case(Case(np.random.default_rng(seed), max_rows=300))

    prop()


# ---------------------------------------------------------------------------
# Nightly deep profile
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("seed", range(40))
def test_differential_fuzz_deep(seed):
    rng = np.random.default_rng(50_000 + seed)
    case = Case(rng, max_rows=12_000, neg_keys=True)
    # a work_mem small enough that the bigger draws genuinely spill
    # through the tier staircase
    check_case(case, tier_wm=16 * 1024)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(12))
def test_differential_fuzz_deep_compressed_mix(seed):
    """Nightly: compression crossed with the full configuration matrix —
    tiered spill under a tiny work_mem AND the 4-shard partition-parallel
    path run the same big duplicate-heavy draws in both upload modes, all
    against the numpy oracle."""
    rng = np.random.default_rng(90_000 + seed)
    case = Case(rng, max_rows=12_000, neg_keys=True)
    for compress in (True, False):
        check_case(case, tier_wm=16 * 1024, compress=compress)
