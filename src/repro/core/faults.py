"""Fault injection, the typed serving-error taxonomy, and retry policy.

The serving layer's robustness claims (open-loop SLO serving, fig13's chaos
gate) are only claims if the failure modes can be *produced on demand*.
This module is the single switchboard for that:

  * a typed **error taxonomy** — :class:`QueryRejected` (admission-control
    load shedding), :class:`DeadlineExceeded` (an admitted query missed its
    SLO deadline), and :class:`TransientError` (retryable infrastructure
    faults: :class:`SpillIOError`, :class:`DeviceDispatchError`,
    :class:`GrantTimeout`) — so the serving layer can *classify* every
    failure instead of aborting a whole run on the first worker exception;
  * a seeded, thread-safe :class:`FaultInjector` with one hook per
    infrastructure fault site: spill-file writes (transient I/O errors and
    simulated mid-write crashes), device dispatch (failures and slowdowns),
    and memory-grant acquisition (forced admission timeouts).  Injection is
    probabilistic per site with an independent deterministic RNG, so a
    seeded chaos run replays the same fault schedule;
  * a :class:`RetryPolicy` — exponential backoff with full jitter, the
    classic thundering-herd-safe retry discipline — that the executor
    applies to :class:`TransientError` only.  Repeated *device* failures
    additionally trigger **path fallback**: the executor pins the failing
    query onto the linear path, trading speed for completion (the device
    being sick must degrade service, not abort it).

:class:`PreemptedError` is control flow, not a failure: it is how a
floor-degraded linear operator abandons its spill mid-flight when the
broker preempts it, and the executor requeues the operator on the tensor
path (see ``docs/serving.md``).  :class:`SimulatedCrash` deliberately
derives from ``BaseException``: it models a *killed* worker, and ordinary
``except Exception`` cleanup handlers must not get a chance to tidy up
state a real death would have left behind (the crash-consistent spill
finalize test depends on exactly this).
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, Optional

__all__ = [
    "QueryRejected", "DeadlineExceeded", "TransientError", "SpillIOError",
    "SpillCorruptionError", "DeviceDispatchError", "GrantTimeout",
    "PreemptedError", "SimulatedCrash", "RetryPolicy", "FaultInjector",
]


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class QueryRejected(Exception):
    """Admission control shed this query: its quoted wait already exceeded
    its deadline, so running it would only burn capacity on a result nobody
    can use.  Recorded as a *shed* sample, never a failure."""


class DeadlineExceeded(Exception):
    """An admitted query missed its SLO deadline while queued (admission let
    it through, then load grew).  Recorded as a *failed* sample — distinct
    from shedding, because it represents an admission mistake."""


class TransientError(Exception):
    """A retryable infrastructure fault.  The executor retries these with
    exponential backoff + jitter; anything else propagates immediately."""


class SpillIOError(TransientError, OSError):
    """A spill-file read or write failed transiently (injected or real EIO)."""


class SpillCorruptionError(TransientError):
    """A spilled column failed its CRC32 check on read — a torn or
    bit-flipped tier-1/2 file.  Typed (never silently wrong rows), and
    transient on purpose: a corrupt TEMP file is recoverable — a tiered read
    fails over to the next copy down the hierarchy, and a whole-operator
    retry simply re-spills."""


class DeviceDispatchError(TransientError):
    """A device dispatch failed transiently.  Repeated occurrences trigger
    path fallback: the executor pins the query onto the linear path."""


class GrantTimeout(TransientError, TimeoutError):
    """A memory-grant acquisition timed out in admission control.  Also a
    ``TimeoutError`` so callers that already handle governor timeouts keep
    working unchanged."""


class PreemptedError(Exception):
    """A floor-degraded linear operator was preempted mid-spill.  Control
    flow, not a failure: the executor catches it and requeues the operator
    on the tensor path."""


class SimulatedCrash(BaseException):
    """A fault-injected worker death (SIGKILL analogue).  BaseException on
    purpose: ``except Exception`` cleanup paths must not run — a killed
    process would not have run them either, which is the whole point of
    testing crash consistency."""


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------

class RetryPolicy:
    """Exponential backoff with full jitter for :class:`TransientError`.

    ``backoff(attempt)`` for attempt 1, 2, ... draws uniformly from
    ``[0, min(cap_s, base_s * 2**(attempt-1))]`` — full jitter, the variant
    that de-synchronizes retry storms best (all-jitter beats equal-jitter
    when many workers fail together, which is exactly the injected-fault
    case).  ``device_fallback_after`` is the path-fallback threshold: that
    many device-dispatch failures within one query pins the query linear.
    Seeded so a chaos run's backoff schedule replays.
    """

    def __init__(self, max_attempts: int = 4, base_s: float = 0.01,
                 cap_s: float = 0.25, device_fallback_after: int = 2,
                 seed: int = 0):
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.max_attempts = int(max_attempts)
        self.base_s = float(base_s)
        self.cap_s = float(cap_s)
        self.device_fallback_after = int(device_fallback_after)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def backoff(self, attempt: int) -> float:
        ceiling = min(self.cap_s, self.base_s * (2 ** max(0, attempt - 1)))
        with self._lock:
            return self._rng.uniform(0.0, ceiling)


# ---------------------------------------------------------------------------
# Fault injector
# ---------------------------------------------------------------------------

class FaultInjector:
    """Deterministic, thread-safe fault switchboard.

    One hook per infrastructure fault site; each site rolls an independent
    seeded RNG so enabling one fault class never perturbs another's
    schedule (and a fixed seed replays the same chaos run):

      * :meth:`on_spill_column` — called before every spill column write;
        raises :class:`SpillIOError` with probability ``spill_io_p``, or
        :class:`SimulatedCrash` when a one-shot kill armed via
        :meth:`arm_spill_kill` counts down to zero (the crash-consistency
        regression);
      * :meth:`on_device_dispatch` — called on device-lease acquisition;
        sleeps ``device_slow_s`` with probability ``device_slow_p`` (a slow
        device is survivable and must NOT error), and raises
        :class:`DeviceDispatchError` with probability ``device_fail_p``;
      * :meth:`on_memory_grant` — called on memory-lease acquisition;
        raises :class:`GrantTimeout` with probability ``grant_timeout_p``;
      * :meth:`on_spill_read` — called before every spill column read on an
        I/O-backed tier; raises :class:`SpillIOError` with probability
        ``spill_read_p`` (the tiered read path fails over down the
        hierarchy);
      * :meth:`on_remote_read` — emulated remote-tier slowdown: sleeps
        ``remote_slow_s`` with probability ``remote_slow_p``.

    ``counts()`` reports how many faults each site actually injected — the
    chaos gate asserts they are nonzero, so "survived chaos" can never mean
    "chaos never happened".
    """

    def __init__(self, seed: int = 0, spill_io_p: float = 0.0,
                 device_fail_p: float = 0.0, device_slow_p: float = 0.0,
                 device_slow_s: float = 0.02, grant_timeout_p: float = 0.0,
                 spill_read_p: float = 0.0, remote_slow_p: float = 0.0,
                 remote_slow_s: float = 0.01):
        for name, p in (("spill_io_p", spill_io_p),
                        ("device_fail_p", device_fail_p),
                        ("device_slow_p", device_slow_p),
                        ("grant_timeout_p", grant_timeout_p),
                        ("spill_read_p", spill_read_p),
                        ("remote_slow_p", remote_slow_p)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        self.spill_io_p = float(spill_io_p)
        self.device_fail_p = float(device_fail_p)
        self.device_slow_p = float(device_slow_p)
        self.device_slow_s = float(device_slow_s)
        self.grant_timeout_p = float(grant_timeout_p)
        self.spill_read_p = float(spill_read_p)
        self.remote_slow_p = float(remote_slow_p)
        self.remote_slow_s = float(remote_slow_s)
        self._lock = threading.Lock()
        self._rngs = {site: random.Random((seed, site).__hash__() & 0x7FFFFFFF)
                      for site in ("spill_io", "device_fail", "device_slow",
                                   "grant_timeout", "spill_read",
                                   "remote_slow")}
        self._counts: Dict[str, int] = {
            "spill_io": 0, "spill_kill": 0, "device_fail": 0,
            "device_slow": 0, "grant_timeout": 0, "spill_read": 0,
            "remote_slow": 0}
        self._kill_countdown: Optional[int] = None

    def _roll(self, site: str, p: float) -> bool:
        if p <= 0.0:
            return False
        with self._lock:
            if self._rngs[site].random() < p:
                self._counts[site] += 1
                return True
        return False

    # -- arming ---------------------------------------------------------------
    def arm_spill_kill(self, after_columns: int = 1) -> None:
        """One-shot: the ``after_columns``-th subsequent spill column write
        dies with :class:`SimulatedCrash` (then disarms)."""
        if after_columns < 1:
            raise ValueError(f"after_columns must be >= 1, got {after_columns}")
        with self._lock:
            self._kill_countdown = int(after_columns)

    # -- fault sites ----------------------------------------------------------
    def on_spill_column(self, path: str = "") -> None:
        with self._lock:
            if self._kill_countdown is not None:
                self._kill_countdown -= 1
                if self._kill_countdown <= 0:
                    self._kill_countdown = None
                    self._counts["spill_kill"] += 1
                    raise SimulatedCrash(
                        f"injected worker death mid-spill at {path!r}")
        if self._roll("spill_io", self.spill_io_p):
            raise SpillIOError(f"injected spill I/O error at {path!r}")

    def on_device_dispatch(self) -> None:
        if self._roll("device_slow", self.device_slow_p):
            time.sleep(self.device_slow_s)
        if self._roll("device_fail", self.device_fail_p):
            raise DeviceDispatchError("injected device dispatch failure")

    def on_memory_grant(self) -> None:
        if self._roll("grant_timeout", self.grant_timeout_p):
            raise GrantTimeout("injected memory-grant admission timeout")

    def on_spill_read(self, path: str = "") -> None:
        """Called before every spill column *read* on an I/O-backed tier
        (disk / emulated remote); raises :class:`SpillIOError` with
        probability ``spill_read_p``.  The tiered read path catches this,
        retries per :class:`RetryPolicy`, and fails over down the hierarchy
        to the next resident copy."""
        if self._roll("spill_read", self.spill_read_p):
            raise SpillIOError(f"injected spill read error at {path!r}")

    def on_remote_read(self, nbytes: int = 0) -> None:
        """Called on emulated remote-tier (T1) transfers; sleeps
        ``remote_slow_s`` with probability ``remote_slow_p`` (a slow remote
        is survivable and must NOT error — it just makes the tier's priced
        latency show up in the tail)."""
        if self._roll("remote_slow", self.remote_slow_p):
            time.sleep(self.remote_slow_s)

    # -- observability --------------------------------------------------------
    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counts)

    @property
    def total_injected(self) -> int:
        with self._lock:
            return sum(self._counts.values())
