"""Sort correctness: linear (in-memory + external) vs tensor multi-key path."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis; pip install -r requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.core import Relation, sort_linear, tensor_sort


def _lex_ok(rel: Relation, keys) -> bool:
    cols = [rel[k] for k in keys]
    n = len(rel)
    if n < 2:
        return True
    le = np.zeros(n - 1, dtype=bool)
    undecided = np.ones(n - 1, dtype=bool)
    for c in cols:
        lt = c[:-1] < c[1:]
        gt = c[:-1] > c[1:]
        le |= undecided & lt
        undecided &= ~(lt | gt)
    return bool(np.all(le | undecided))


def _mk(rng, n, domains):
    cols = {f"k{i}": rng.integers(0, d, n).astype(np.int64) for i, d in enumerate(domains)}
    cols["payload"] = rng.integers(0, 1 << 40, n).astype(np.int64)
    return Relation(cols)


@pytest.mark.parametrize("work_mem", [1 << 30, 64 * 1024, 16 * 1024])
@pytest.mark.parametrize("domains", [(1000,), (40, 1 << 35), (8, 8, 8)])
def test_sort_paths_agree(work_mem, domains):
    rng = np.random.default_rng(3)
    rel = _mk(rng, 20_000, domains)
    keys = [f"k{i}" for i in range(len(domains))]
    lin, m_lin = sort_linear(rel, keys, work_mem)
    ten, m_ten = tensor_sort(rel, keys)
    assert _lex_ok(lin, keys)
    assert _lex_ok(ten, keys)
    assert lin.sort_canonical().equals(ten.sort_canonical())
    assert m_ten.spill.temp_bytes == 0
    if work_mem >= rel.nbytes():
        assert m_lin.spill.temp_bytes == 0
    else:
        assert m_lin.spill.temp_bytes > 0  # external sort really spilled


def test_external_sort_multi_pass():
    """Tiny work_mem forces multiple merge passes (spill amplification)."""
    rng = np.random.default_rng(5)
    rel = _mk(rng, 60_000, (100, 1 << 30))
    _, m_small = sort_linear(rel, ["k0", "k1"], 16 * 1024)
    _, m_large = sort_linear(rel, ["k0", "k1"], 512 * 1024)
    assert m_small.spill.partition_passes > m_large.spill.partition_passes
    assert m_small.spill.bytes_written > m_large.spill.bytes_written


def test_sort_stability_on_payload_order():
    """Tensor sort's stable LSD passes preserve input order for equal keys."""
    n = 1000
    rel = Relation({
        "k0": np.zeros(n, dtype=np.int64),
        "payload": np.arange(n, dtype=np.int64),
    })
    out, _ = tensor_sort(rel, ["k0"])
    assert np.array_equal(out["payload"], np.arange(n))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(0, 500),
    nkeys=st.integers(1, 3),
    domain=st.integers(1, 30),
    work_mem=st.sampled_from([4 * 1024, 1 << 30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_sort_paths_agree(n, nkeys, domain, work_mem, seed):
    if n == 0:
        return
    rng = np.random.default_rng(seed)
    rel = _mk(rng, n, tuple([domain] * nkeys))
    keys = [f"k{i}" for i in range(nkeys)]
    lin, _ = sort_linear(rel, keys, work_mem)
    ten, _ = tensor_sort(rel, keys)
    assert _lex_ok(lin, keys) and _lex_ok(ten, keys)
    assert lin.sort_canonical().equals(ten.sort_canonical())
