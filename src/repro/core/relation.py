"""Columnar relation abstraction.

A :class:`Relation` is a named set of equal-length 1-D columns (numpy arrays on
the host side; the tensor engine converts to jax arrays lazily).  Columns are
kept *separate* — this is the "multi-attribute structure" the paper argues the
execution layer should preserve: each attribute is its own axis/column until an
operator genuinely needs a linearized form.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Iterable, Mapping, Sequence

import numpy as np

__all__ = ["Relation", "column_token"]


def column_token(arr: np.ndarray) -> tuple:
    """Cheap content fingerprint of a column: O(32) sampled elements.

    Combines the buffer address, length, dtype, and a CRC over a strided
    sample (always including the first and last element).  Device caches key
    on this token, so an in-place mutation of a cached column is detected —
    with sampled (not cryptographic) confidence — and forces a fresh
    transfer.  Callers that mutate columns between queries should also call
    :meth:`Relation.invalidate_device_cache` for a guaranteed refresh.
    """
    n = len(arr)
    dt = str(arr.dtype)
    if n == 0:
        return (0, 0, dt, 0)
    stride = max(1, n // 32)
    sample = np.concatenate([arr[::stride], arr[-1:]])
    crc = zlib.crc32(np.ascontiguousarray(sample).tobytes())
    try:
        ptr = arr.__array_interface__["data"][0]
    except (AttributeError, KeyError):
        ptr = id(arr)
    return (ptr, n, dt, crc)


@dataclasses.dataclass
class Relation:
    """An immutable columnar relation."""

    columns: Dict[str, np.ndarray]

    def __post_init__(self) -> None:
        if not self.columns:
            raise ValueError("Relation needs at least one column")
        lengths = {k: len(v) for k, v in self.columns.items()}
        if len(set(lengths.values())) != 1:
            raise ValueError(f"ragged columns: {lengths}")
        # normalize to contiguous numpy arrays
        self.columns = {k: np.ascontiguousarray(v) for k, v in self.columns.items()}

    # -- construction ------------------------------------------------------
    @staticmethod
    def from_dict(cols: Mapping[str, Sequence]) -> "Relation":
        return Relation({k: np.asarray(v) for k, v in cols.items()})

    # -- basic properties ----------------------------------------------------
    @property
    def num_rows(self) -> int:
        return len(next(iter(self.columns.values())))

    @property
    def names(self) -> tuple:
        return tuple(self.columns.keys())

    def __len__(self) -> int:
        return self.num_rows

    def __getitem__(self, name: str) -> np.ndarray:
        return self.columns[name]

    def nbytes(self) -> int:
        return int(sum(c.nbytes for c in self.columns.values()))

    def fingerprint(self) -> tuple:
        """Aggregate of the per-column tokens (see :func:`column_token`).

        The device base-table cache and key-cardinality sketch key on the
        individual column tokens (so mutating one column only invalidates
        that column); this whole-relation aggregate is the convenience form
        for callers that want to snapshot/compare table versions.
        """
        return tuple((name, column_token(col))
                     for name, col in self.columns.items())

    def invalidate_device_cache(self) -> None:
        """Drop cached device uploads and key sketches for this relation.

        The caches invalidate automatically via sampled content tokens; this
        is the explicit, guaranteed path for callers that mutate columns
        in place between queries.  The cache dicts are cleared *in place*
        and kept (not popped): sub-relations made with :meth:`select` share
        them by reference, so clearing invalidates every selection while
        preserving the shared-object contract for later warm-sharing.
        """
        for attr in ("_device_cache", "_key_stats", "_packed_cols",
                     "_sel_cache", "_partition_cache", "_layout_cache"):
            store = self.__dict__.get(attr)
            if store is not None:
                store.clear()
        self.__dict__.pop("_device_cols", None)  # pre-PR2 attr name

    def row_bytes(self) -> int:
        return int(sum(c.dtype.itemsize for c in self.columns.values()))

    # -- row-wise ops ---------------------------------------------------------
    def take(self, idx: np.ndarray) -> "Relation":
        return Relation({k: v[idx] for k, v in self.columns.items()})

    def select(self, names: Iterable[str]) -> "Relation":
        """Column subset that SHARES this relation's device-cache state.

        A selected sub-relation holds the same numpy column objects, so its
        device uploads and key-cardinality sketches are interchangeable with
        the parent's: both point at the parent's cache dicts (same object,
        not a copy).  Projection-pruned scans therefore reuse columns the
        parent already uploaded — and uploads made through a pruned scan
        warm the parent and every sibling selection, across queries, even
        though the planner builds a fresh sub-relation per query.
        (Entries are token-checked per column, so staleness detection is
        unchanged; ``invalidate_device_cache`` on the *parent* drops the
        shared state for all of them.)
        """
        sub = Relation({k: self.columns[k] for k in names})
        for attr in ("_device_cache", "_key_stats", "_packed_cols",
                     "_sel_cache", "_partition_cache", "_layout_cache"):
            sub.__dict__[attr] = self.__dict__.setdefault(attr, {})
        return sub

    def rename(self, mapping: Mapping[str, str]) -> "Relation":
        return Relation({mapping.get(k, k): v for k, v in self.columns.items()})

    def concat(self, other: "Relation") -> "Relation":
        if set(self.names) != set(other.names):
            raise ValueError(f"schema mismatch: {self.names} vs {other.names}")
        return Relation(
            {k: np.concatenate([self.columns[k], other.columns[k]]) for k in self.names}
        )

    def head(self, n: int) -> "Relation":
        return Relation({k: v[:n] for k, v in self.columns.items()})

    def equals(self, other: "Relation") -> bool:
        """Column-order-insensitive equality (spill round-trips alphabetize)."""
        return set(self.names) == set(other.names) and all(
            np.array_equal(self.columns[k], other.columns[k]) for k in self.names
        )

    def sort_canonical(self) -> "Relation":
        """Row/column-order-insensitive canonical form (result-set comparison)."""
        names = sorted(self.names)
        keys = [self.columns[k] for k in reversed(names)]
        order = np.lexsort(keys)
        return Relation({k: self.columns[k][order] for k in names})
