"""Roofline HLO walker: flop/trip-count accounting against known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analyze import analyze_hlo, roofline_terms
from repro.roofline.model_flops import model_flops
from repro.configs import SHAPES, get_config


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_counted():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    r = analyze_hlo(_hlo_of(lambda x, y: x @ y, a, b))
    want = 2 * 128 * 256 * 512
    assert abs(r["flops"] - want) / want < 0.05, (r["flops"], want)


def test_scan_trip_count_scaling():
    """A matmul inside a scan must be counted trip_count times."""
    a = jnp.zeros((64, 64), jnp.float32)

    def body(c, _):
        return c @ a, None

    def fn(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    r = analyze_hlo(_hlo_of(fn, a))
    want = 10 * 2 * 64**3
    assert abs(r["flops"] - want) / want < 0.05, (r["flops"], want)


def test_bytes_reasonable_for_elementwise():
    """y = x + 1 should move ~2·|x|, not orders of magnitude more."""
    x = jnp.zeros((1 << 20,), jnp.float32)
    r = analyze_hlo(_hlo_of(lambda v: v + 1.0, x))
    assert r["bytes"] <= 4 * x.nbytes
    assert r["bytes"] >= x.nbytes


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e9, 0.0)  # exactly 1s compute, 1s memory
    assert t["dominant"] in ("compute", "memory")
    t = roofline_terms(1.0, 1.0, 50e9 * 10)
    assert t["dominant"] == "collective"
    assert 0 <= t["roofline_fraction"] <= 1


@pytest.mark.parametrize("arch", ["yi-9b", "jamba-1.5-large-398b"])
def test_model_flops_sane(arch):
    cfg = get_config(arch)
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_prefill = model_flops(cfg, SHAPES["prefill_32k"])
    f_decode = model_flops(cfg, SHAPES["decode_32k"])
    # train ≈ 3× prefill per token; decode ≪ prefill
    assert f_train > f_prefill > f_decode > 0
    # 6·N_active·tokens lower bound
    assert f_train >= 6 * cfg.active_param_count() * 256 * 4096 * 0.99
