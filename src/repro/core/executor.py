"""Mini cost-based execution engine with *deferred decision points*.

A tiny physical-operator tree (Scan / Filter / Join / Sort / Aggregate) that
models the structure the paper critiques and the fix it proposes:

  * a traditional plan fixes each operator's execution path at *plan time*
    (``policy="linear"`` or ``"tensor"`` pins every operator);
  * the paper's design (``policy="auto"``) leaves join/sort decision points
    *open* and resolves them at execution time via :class:`PathSelector`,
    using the actually-observed input relations.

The executor records per-operator :class:`OpMetrics` so benchmarks can report
latency, Temp_MB and working-set peaks per path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from .linear_engine import hash_join_linear, sort_linear
from .metrics import OpMetrics
from .path_selector import Decision, PathSelector
from .relation import Relation
from .spill import SpillManager
from .tensor_engine import tensor_join, tensor_sort

__all__ = ["Scan", "Filter", "Join", "Sort", "Aggregate", "Executor", "QueryResult"]


# -- logical plan nodes ------------------------------------------------------

@dataclasses.dataclass
class Scan:
    relation: Relation
    name: str = "scan"


@dataclasses.dataclass
class Filter:
    child: object
    predicate: Callable[[Relation], np.ndarray]  # rows mask
    name: str = "filter"


@dataclasses.dataclass
class Join:
    build: object
    probe: object
    key: str
    name: str = "join"


@dataclasses.dataclass
class Sort:
    child: object
    keys: Sequence[str]
    name: str = "sort"


@dataclasses.dataclass
class Aggregate:
    child: object
    column: str
    fn: str = "sum"  # sum | count | min | max
    name: str = "aggregate"


@dataclasses.dataclass
class GroupBy:
    child: object
    key: str
    values: dict  # column -> agg fn
    name: str = "group_by"


@dataclasses.dataclass
class QueryResult:
    relation: Optional[Relation]
    scalar: Optional[float]
    metrics: List[OpMetrics]
    decisions: List[Decision]

    @property
    def total_wall_s(self) -> float:
        return sum(m.wall_s for m in self.metrics)

    @property
    def total_temp_mb(self) -> float:
        return sum(m.spill.temp_mb for m in self.metrics)


class Executor:
    """Walks a plan; resolves deferred join/sort decision points at run time."""

    def __init__(self, work_mem: int, policy: str = "auto",
                 selector: Optional[PathSelector] = None,
                 spill_root: Optional[str] = None):
        if policy not in ("auto", "linear", "tensor"):
            raise ValueError(policy)
        force = None if policy == "auto" else policy
        self.selector = selector or PathSelector(work_mem, force=force)
        if selector is not None and force is not None:
            self.selector.force = force
        self.work_mem = work_mem
        self.spill_root = spill_root

    def execute(self, plan) -> QueryResult:
        metrics: List[OpMetrics] = []
        decisions: List[Decision] = []
        with SpillManager(self.spill_root) as mgr:
            out = self._exec(plan, metrics, decisions, mgr)
        if isinstance(out, Relation):
            return QueryResult(out, None, metrics, decisions)
        return QueryResult(None, float(out), metrics, decisions)

    # -- node dispatch -----------------------------------------------------
    def _exec(self, node, metrics, decisions, mgr):
        if isinstance(node, Scan):
            return node.relation
        if isinstance(node, Filter):
            child = self._exec(node.child, metrics, decisions, mgr)
            mask = node.predicate(child)
            return child.take(np.nonzero(mask)[0])
        if isinstance(node, Join):
            build = self._exec(node.build, metrics, decisions, mgr)
            probe = self._exec(node.probe, metrics, decisions, mgr)
            decision = self.selector.choose_join(build, probe, node.key)
            decisions.append(decision)
            if decision.path == "tensor":
                out, m = tensor_join(build, probe, node.key)
            else:
                out, m = hash_join_linear(build, probe, node.key, self.work_mem, mgr)
            m.decision_reason = decision.reason
            metrics.append(m)
            return out
        if isinstance(node, Sort):
            child = self._exec(node.child, metrics, decisions, mgr)
            decision = self.selector.choose_sort(child, node.keys)
            decisions.append(decision)
            if decision.path == "tensor":
                out, m = tensor_sort(child, node.keys)
            else:
                out, m = sort_linear(child, node.keys, self.work_mem, mgr)
            m.decision_reason = decision.reason
            metrics.append(m)
            return out
        if isinstance(node, GroupBy):
            child = self._exec(node.child, metrics, decisions, mgr)
            from .aggregate import group_aggregate_linear, group_aggregate_tensor
            # GROUP BY is the third linearizing operator: the group hash
            # table is the linearized intermediate; selection mirrors sort
            decision = self.selector.choose_sort(child, [node.key])
            decisions.append(decision)
            if decision.path == "tensor":
                out, m = group_aggregate_tensor(child, node.key, node.values)
            else:
                out, m = group_aggregate_linear(child, node.key, node.values,
                                                self.work_mem, mgr)
            m.decision_reason = decision.reason
            metrics.append(m)
            return out
        if isinstance(node, Aggregate):
            child = self._exec(node.child, metrics, decisions, mgr)
            col = child[node.column]
            if node.fn == "sum":
                return float(col.sum())
            if node.fn == "count":
                return float(len(col))
            if node.fn == "min":
                return float(col.min())
            if node.fn == "max":
                return float(col.max())
            raise ValueError(node.fn)
        raise TypeError(f"unknown plan node {node!r}")
