"""Core of the reproduction: tensor-based execution paths for high-dimensional
relational operations, with execution-time path selection (the paper's
contribution), plus the faithful linear (spilling) baseline it is measured
against."""
from .cost_model import CostConstants, CostModel, FragmentEstimate
from .aggregate import (group_aggregate_device, group_aggregate_linear,
                        group_aggregate_tensor)
from .device_relation import DeviceColumn, DeviceRelation
from .executor import Aggregate, Executor, Filter, GroupBy, Join, QueryResult, Scan, Sort
from .fused import (FusedSpec, match_fragment, pipeline_cache_clear,
                    pipeline_cache_info, run_fused)
from .linear_engine import HashTable, hash_join_linear, sort_linear, table_bytes_estimate
from .metrics import BLOCK_BYTES, LatencyStats, OpMetrics, SpillAccount, latency_stats
from .path_selector import Decision, PathSelector
from .relation import Relation, column_token
from .runtime_profile import DEFAULT_PROFILE, RuntimeProfile, size_bucket
from .spill import SpillManager
from .table_cache import (KeyStats, get_device_columns, key_stats,
                          pending_upload_bytes, table_cache_clear,
                          table_cache_info)
from .tensor_engine import (
    aligned_join_indices,
    capacity_bucket,
    join_capacity,
    tensor_join,
    tensor_join_aggregate,
    tensor_join_device,
    tensor_sort,
    tensor_sort_device,
)

__all__ = [
    "Aggregate", "BLOCK_BYTES", "CostConstants", "CostModel",
    "DEFAULT_PROFILE", "Decision", "DeviceColumn", "DeviceRelation",
    "Executor", "Filter", "FragmentEstimate", "FusedSpec", "GroupBy",
    "HashTable", "Join", "KeyStats", "LatencyStats", "OpMetrics",
    "PathSelector", "QueryResult", "Relation", "RuntimeProfile", "Scan",
    "Sort", "SpillAccount", "SpillManager", "aligned_join_indices",
    "capacity_bucket", "column_token", "get_device_columns",
    "hash_join_linear", "join_capacity", "key_stats",
    "group_aggregate_device", "group_aggregate_linear", "group_aggregate_tensor",
    "latency_stats", "match_fragment", "pending_upload_bytes",
    "pipeline_cache_clear", "pipeline_cache_info", "run_fused", "size_bucket",
    "sort_linear", "table_bytes_estimate", "table_cache_clear",
    "table_cache_info", "tensor_join", "tensor_join_aggregate",
    "tensor_join_device", "tensor_sort", "tensor_sort_device",
]
