"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf]: MLA (kv_lora=512) + MoE
64 routed top-6 + 2 shared experts; first layer dense (hf config)."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    vocab_size=102_400,
    num_heads=16,
    num_kv_heads=16,
    head_dim=192,                # qk_nope(128) + qk_rope(64)
    d_ff=10_944,                 # dense prefix layer (hf first_k_dense_replace=1)
    prefix=(("attn:global", "dense"),),
    pattern=(("attn:global", "moe"),),
    attn_type="mla",
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    norm_topk=False,             # v2-lite: unnormalized top-k weights
    rope_theta=10_000.0,
    source="arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2-Lite "
           "(assignment header '64e top-6'; '160 routed' applies to full V2 — "
           "see DESIGN.md §8)",
)

SMOKE = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=3,
    d_model=64,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=4,
    head_dim=24,
    d_ff=192,
    prefix=(("attn:global", "dense"),),
    pattern=(("attn:global", "moe"),),
    attn_type="mla",
    kv_lora_rank=32,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    capacity_factor=16.0,  # no-drop capacity for decode-equivalence smoke tests
    num_experts=8,
    experts_per_token=3,
    num_shared_experts=2,
    moe_d_ff=48,
    norm_topk=False,
)

register(CONFIG, SMOKE)
