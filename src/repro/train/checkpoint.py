"""Checkpointing: atomic, resumable, numpy-backed.

Layout:
  <dir>/step_<N>.tmp/   (being written)
  <dir>/step_<N>/       (atomic rename after fsync: a crash never leaves a
                         half-written checkpoint visible)
      arrays.npz        (flattened "a/b/c" path → array)
      manifest.json     (step, leaf count, per-leaf shape/dtype checksums)

``latest_step`` scans for the newest *valid* manifest, so restore skips any
checkpoint that fails integrity checks (fault tolerance: a node dying during
save costs one interval, never a corrupt restore).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "Checkpointer"]

_SEP = "|"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "num_leaves": len(flat),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sum": float(np.asarray(v, np.float64).sum())
                       if v.dtype.kind in "fiu" else 0.0}
                   for k, v in flat.items()},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic visibility
    # retention
    steps = sorted(_valid_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
    return str(final)


def _valid_steps(ckpt_dir: pathlib.Path):
    out = []
    for p in ckpt_dir.glob("step_*"):
        if p.suffix == ".tmp" or not (p / "manifest.json").exists():
            continue
        try:
            m = json.loads((p / "manifest.json").read_text())
            out.append(int(m["step"]))
        except Exception:
            continue
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = _valid_steps(d)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (shape structs or arrays)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    path = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = _SEP.join(
            str(q.key) if hasattr(q, "key") else str(q.idx) for q in p)
        arr = data[key]
        want = manifest["leaves"][key]
        if list(arr.shape) != want["shape"]:
            raise ValueError(f"checkpoint corrupt: {key} shape mismatch")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), int(manifest["step"])


class Checkpointer:
    """Interval-based checkpointing helper for the train loop."""

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.interval = interval
        self.keep = keep

    def maybe_save(self, step: int, tree: Any) -> Optional[str]:
        if step % self.interval == 0 and step > 0:
            return save_checkpoint(self.dir, step, tree, self.keep)
        return None

    def restore_or_init(self, template: Any, init_fn):
        s = latest_step(self.dir)
        if s is None:
            return init_fn(), 0
        return restore_checkpoint(self.dir, template, s)
