"""Expression language: numpy/jnp lowering parity, canonical cache tokens.

Property-style but hypothesis-free (the optional dependency must not gate
this coverage): a grid of expression builders × data profiles, each asserted
equal between the host numpy evaluation and the jitted jnp evaluation —
including NaN propagation and int/float promotion edges — plus token
stability across rebuilt-but-equal trees.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.expr import BinOp, Col, Expr, IsIn, Lit, col, lit

# ---------------------------------------------------------------------------
# Data profiles: the dtype/value edges lowering must agree on
# ---------------------------------------------------------------------------


def _profiles():
    rng = np.random.default_rng(7)
    n = 257
    return {
        "ints": {
            "x": rng.integers(-50, 50, n).astype(np.int64),
            "y": rng.integers(1, 20, n).astype(np.int64),
        },
        "mixed_int_float": {
            "x": rng.integers(-50, 50, n).astype(np.int64),
            "y": rng.normal(0, 10, n),
        },
        "floats_with_nan": {
            "x": np.where(rng.random(n) < 0.2, np.nan, rng.normal(0, 5, n)),
            "y": np.where(rng.random(n) < 0.2, np.nan, rng.normal(0, 5, n)),
        },
        "int32_narrow": {
            "x": rng.integers(-5, 5, n).astype(np.int32),
            "y": rng.integers(1, 4, n).astype(np.int32),
        },
    }


# builders: name -> expression over columns x, y
EXPRS = {
    "cmp_gt": lambda: col("x") > 0,
    "cmp_le_float": lambda: col("x") <= 1.5,
    "arith_chain": lambda: (col("x") * 2 + col("y")) - 3,
    "division_promotes": lambda: col("x") / col("y") > 0.5,
    "floordiv_mod": lambda: (col("x") // 2) % 3 == 1,
    "bool_algebra": lambda: ((col("x") > 0) & (col("y") > 0))
    | ~(col("x") <= col("y")),
    "reflected": lambda: (0 < col("x")) & (10 - col("x") > col("y")),
    "isin": lambda: col("x").isin([1, 2, 3, -4]),
    "isin_negated": lambda: ~col("x").isin([0]) & (col("y") >= 1),
    "nan_cmp": lambda: col("x") == col("x"),  # NaN != NaN on both paths
    "mixed_promote": lambda: (col("x") + 0.5) * col("y") >= 2,
}


@pytest.mark.parametrize("profile", sorted(_profiles()))
@pytest.mark.parametrize("name", sorted(EXPRS))
def test_numpy_jnp_lowering_parity(profile, name):
    cols = _profiles()[profile]
    expr = EXPRS[name]()
    host = np.asarray(expr(cols))

    jitted = jax.jit(lambda c: expr(c))
    dev = np.asarray(jitted({k: jnp.asarray(v) for k, v in cols.items()}))

    assert host.shape == dev.shape
    if host.dtype == bool:
        np.testing.assert_array_equal(host, np.asarray(dev, bool))
    else:
        np.testing.assert_allclose(host, dev, rtol=1e-12, atol=0,
                                   equal_nan=True)


def test_expr_evaluates_on_relation_and_devicerelation():
    """One Expr serves every engine view type: host Relation, DeviceRelation
    (device arrays), and a plain dict."""
    from repro.core import DeviceRelation, Relation

    rel = Relation({"x": np.array([-2, -1, 0, 1, 2], np.int64),
                    "y": np.array([1, 1, 2, 2, 3], np.int64)})
    expr = (col("x") > 0) & col("y").isin([2, 3])
    want = np.array([False, False, False, True, True])
    np.testing.assert_array_equal(np.asarray(expr(rel)), want)
    np.testing.assert_array_equal(
        np.asarray(expr(DeviceRelation.from_host(rel))), want)
    np.testing.assert_array_equal(np.asarray(expr(dict(rel.columns))), want)


# ---------------------------------------------------------------------------
# Cache tokens: stable across rebuilds, distinct across meaning
# ---------------------------------------------------------------------------


def test_cache_token_stable_across_rebuilt_equal_exprs():
    for name, mk in EXPRS.items():
        assert mk().cache_token() == mk().cache_token(), name
        hash(mk().cache_token())  # must be usable as a dict key


def test_cache_token_distinguishes_structure_and_values():
    tokens = {
        "gt0": (col("x") > 0).cache_token(),
        "gt1": (col("x") > 1).cache_token(),
        "ge0": (col("x") >= 0).cache_token(),
        "other_col": (col("y") > 0).cache_token(),
        "flipped": (lit(0) < col("x")).cache_token(),
        "isin": col("x").isin([0, 1]).cache_token(),
        "isin_other": col("x").isin([0, 2]).cache_token(),
    }
    assert len(set(tokens.values())) == len(tokens)


def test_cache_token_type_tags_equal_comparing_literals():
    """1 == 1.0 == True in Python, but each traces to a different program:
    the token must keep them distinct (the dict-key collision hazard)."""
    toks = {(col("x") > v).cache_token() for v in (1, 1.0, True)}
    assert len(toks) == 3
    toks_isin = {col("x").isin([v]).cache_token() for v in (0, 0.0, False)}
    assert len(toks_isin) == 3


def test_reflected_ops_token_matches_explicit_form():
    """``0 < col`` builds through the reflected operator as ``col > 0``."""
    assert (0 < col("x")).cache_token() == (col("x") > 0).cache_token()


# ---------------------------------------------------------------------------
# Planner-facing introspection
# ---------------------------------------------------------------------------


def test_columns_and_rename():
    e = ((col("w") > 0) & (col("b_region") <= 2)) | col("w").isin([5])
    assert e.columns() == {"w", "b_region"}
    r = e.rename_columns({"b_region": "region"})
    assert r.columns() == {"w", "region"}
    # rename does not mutate the original
    assert e.columns() == {"w", "b_region"}


def test_conjuncts_split():
    a, b, c = col("x") > 0, col("y") > 1, col("x").isin([2])
    e = a & b & c
    parts = e.conjuncts()
    assert len(parts) == 3
    assert {p.cache_token() for p in parts} == {
        a.cache_token(), b.cache_token(), c.cache_token()}
    # OR does not split
    assert len((a | b).conjuncts()) == 1


def test_invalid_operands_rejected():
    with pytest.raises(TypeError):
        col("x") > "a string"
    with pytest.raises(TypeError):
        col("x").isin(["a"])


def test_truth_testing_raises_instead_of_dropping_operands():
    """`0 < col < 10` desugars to `(0 < col) and (col < 10)`, and `and`
    truth-tests its left operand — which would silently drop it from the
    predicate.  Expr must refuse boolean coercion (regression)."""
    with pytest.raises(TypeError, match="ambiguous"):
        bool(col("x") > 0)
    with pytest.raises(TypeError, match="ambiguous"):
        0 < col("x") < 10  # noqa: B015 — the chained form IS the test
    with pytest.raises(TypeError, match="ambiguous"):
        (col("x") > 0) and (col("x") < 10)  # noqa: B015


def test_predicate_key_routes_expr_through_cache_token():
    from repro.core.fused import _predicate_key

    e1 = (col("w") > 0) & col("k").isin([1, 2])
    e2 = (col("w") > 0) & col("k").isin([1, 2])
    assert _predicate_key(e1) == _predicate_key(e2) == (
        "expr", e1.cache_token())
    assert _predicate_key(col("w") > 1) != _predicate_key(e1)
