"""Join correctness: linear (in-memory + spilling) vs tensor path.

The paper's invariant (§III.C): "execution-time selection does not change the
semantic result of the operation" — both paths must produce identical result
sets on identical inputs, under any work_mem.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis; pip install -r requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.core import (
    HashTable,
    Relation,
    hash_join_linear,
    join_capacity,
    tensor_join,
    tensor_join_aggregate,
)


def _mk(rng, n_build, n_probe, key_domain):
    build = Relation({
        "k": rng.integers(0, key_domain, n_build).astype(np.int64),
        "v": rng.integers(0, 1 << 30, n_build).astype(np.int64),
    })
    probe = Relation({
        "k": rng.integers(0, key_domain, n_probe).astype(np.int64),
        "w": rng.integers(0, 1 << 30, n_probe).astype(np.int64),
    })
    return build, probe


@pytest.mark.parametrize("work_mem", [1 << 30, 256 * 1024, 32 * 1024])
@pytest.mark.parametrize("n_build,n_probe,domain", [
    (1000, 3000, 5000),      # mostly unique build keys
    (5000, 5000, 50),        # heavy duplicates
    (1, 10, 1),              # degenerate
    (4096, 0, 100),          # empty probe
])
def test_join_paths_agree(work_mem, n_build, n_probe, domain):
    rng = np.random.default_rng(42)
    build, probe = _mk(rng, n_build, n_probe, domain)
    lin, m_lin = hash_join_linear(build, probe, "k", work_mem)
    ten, m_ten = tensor_join(build, probe, "k")
    assert lin.sort_canonical().equals(ten.sort_canonical())
    assert m_ten.spill.temp_bytes == 0  # tensor path has no spill regime
    if work_mem == 1 << 30:
        assert m_lin.spill.temp_bytes == 0


def test_unique_key_join_uses_hash_table():
    rng = np.random.default_rng(0)
    n = 4096
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": np.arange(n, dtype=np.int64)})
    probe = Relation({"k": rng.integers(0, n, 2 * n).astype(np.int64),
                      "w": np.arange(2 * n, dtype=np.int64)})
    out, _ = hash_join_linear(build, probe, "k", 1 << 30)
    # PK-FK: every probe row matches exactly once
    assert len(out) == 2 * n
    assert np.array_equal(np.sort(out["w"]), np.arange(2 * n))
    # payloads correctly paired
    kv = dict(zip(build["k"].tolist(), build["v"].tolist()))
    assert all(kv[k] == v for k, v in zip(out["k"][:100], out["b_v"][:100]))


def test_hash_table_duplicate_detection():
    keys = np.array([1, 2, 3, 2], dtype=np.int64)
    with pytest.raises(HashTable.DuplicateKeys):
        HashTable(keys)


def test_hash_table_probe_miss():
    keys = np.arange(100, dtype=np.int64)
    tab = HashTable(keys)
    res = tab.probe(np.array([5, 500, 99, -1], dtype=np.int64))
    assert res[0] == 5 and res[2] == 99
    assert res[1] == -1 and res[3] == -1


def test_join_capacity_exact():
    rng = np.random.default_rng(1)
    build, probe = _mk(rng, 2000, 3000, 40)
    cap = join_capacity(build["k"], probe["k"])
    out, _ = hash_join_linear(build, probe, "k", 1 << 30)
    assert cap == len(out)


def test_tensor_join_capacity_overflow_detected():
    build = Relation({"k": np.zeros(100, np.int64), "v": np.arange(100, dtype=np.int64)})
    probe = Relation({"k": np.zeros(100, np.int64), "w": np.arange(100, dtype=np.int64)})
    with pytest.raises(ValueError, match="capacity"):
        tensor_join(build, probe, "k", capacity=16)


def test_join_aggregate_matches_materialized():
    rng = np.random.default_rng(7)
    build, probe = _mk(rng, 3000, 4000, 64)
    mat, _ = hash_join_linear(build, probe, "k", 1 << 30)
    agg, m = tensor_join_aggregate(build, probe, "k", "v", "w", key_domain=64)
    assert int(agg["count"]) == len(mat)
    bv = mat["b_v"].astype(np.float64)
    w = mat["w"].astype(np.float64)
    np.testing.assert_allclose(agg["sum_add"], (bv + w).sum(), rtol=1e-6)
    np.testing.assert_allclose(agg["sum_prod"], (bv * w).sum(), rtol=1e-6)
    assert m.spill.temp_bytes == 0  # fused aggregate never materializes the join


@settings(max_examples=25, deadline=None)
@given(
    n_build=st.integers(1, 400),
    n_probe=st.integers(0, 400),
    domain=st.integers(1, 60),
    work_mem=st.sampled_from([8 * 1024, 1 << 30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_join_paths_agree(n_build, n_probe, domain, work_mem, seed):
    rng = np.random.default_rng(seed)
    build, probe = _mk(rng, n_build, n_probe, domain)
    lin, _ = hash_join_linear(build, probe, "k", work_mem)
    ten, _ = tensor_join(build, probe, "k")
    assert lin.sort_canonical().equals(ten.sort_canonical())
