"""Fault tolerance: retries, checkpoint/resume, straggler surfacing, and
elastic mesh re-planning.

On a real multi-pod deployment, failures surface as (a) raised exceptions
from a device/runtime, (b) lost hosts → fewer devices at restart.  This
module provides the control-plane pieces, all testable on CPU:

  * ``ResilientLoop`` — drives train steps; on step failure, restores the last
    checkpoint and replays the data pipeline deterministically; bounded
    retries; per-step wall-time watchdog that *records* stragglers (on TPU
    the mitigation is re-sharding around the slow host at the next restart —
    the watchdog gives the signal).
  * ``plan_mesh`` — elastic re-planning: largest (data × model) grid that the
    surviving device count supports, preferring to shrink the data axis
    (model-parallel groups must stay intact because parameter shards live
    there).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Tuple

from .checkpoint import Checkpointer, latest_step, restore_checkpoint

__all__ = ["plan_mesh", "ResilientLoop", "StepFailure"]


class StepFailure(RuntimeError):
    pass


def plan_mesh(num_devices: int, model_parallel: int = 16,
              pod_size: int = 256) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Elastic mesh plan for the devices that are actually alive.

    Keeps the model axis intact (parameter shards must all exist), shrinks
    data/pod.  Examples: 512 → (2,16,16); 496 → (1,15,16)·240? No —
    (15,16)=240... we take the largest multiple of ``model_parallel``."""
    if num_devices < model_parallel:
        raise ValueError(
            f"cannot keep model axis: {num_devices} < {model_parallel}")
    usable = (num_devices // model_parallel) * model_parallel
    data = usable // model_parallel
    if usable >= 2 * pod_size and usable % pod_size == 0:
        pods = usable // pod_size
        return (pods, pod_size // model_parallel, model_parallel), (
            "pod", "data", "model")
    return (data, model_parallel), ("data", "model")


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    retries: int
    restores: int
    straggler_steps: List[int]
    losses: List[float]


class ResilientLoop:
    """Checkpoint/restart training driver (CPU-testable)."""

    def __init__(self, step_fn: Callable, ckpt: Checkpointer,
                 data_state_fn: Callable[[], dict],
                 data_restore_fn: Callable[[dict], None],
                 max_retries: int = 3,
                 straggler_factor: float = 3.0):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.data_state_fn = data_state_fn
        self.data_restore_fn = data_restore_fn
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor

    def run(self, state: Any, data_iter_factory: Callable, num_steps: int,
            start_step: int = 0, fail_hook: Optional[Callable] = None
            ) -> Tuple[Any, LoopReport]:
        retries = restores = 0
        stragglers: List[int] = []
        losses: List[float] = []
        ema_wall = None
        step = start_step
        it = iter(data_iter_factory())
        while step < num_steps:
            batch = next(it)
            t0 = time.perf_counter()
            try:
                if fail_hook:
                    fail_hook(step)  # test fault injection
                state, loss = self.step_fn(state, batch)
            except Exception:
                retries += 1
                if retries > self.max_retries:
                    raise
                # restore: last durable checkpoint + deterministic data replay
                restored = self.ckpt.restore_or_init(
                    template=state, init_fn=lambda: state)
                state, ck_step = restored
                if isinstance(ck_step, int) and ck_step:
                    step = ck_step
                restores += 1
                self.data_restore_fn({"consumed": step, "seed": 0})
                it = iter(data_iter_factory())
                continue
            wall = time.perf_counter() - t0
            ema_wall = wall if ema_wall is None else 0.9 * ema_wall + 0.1 * wall
            if ema_wall and wall > self.straggler_factor * ema_wall:
                stragglers.append(step)  # mitigation signal (see module doc)
            losses.append(float(loss))
            step += 1
            full = {"state": state, "data": self.data_state_fn()}
            self.ckpt.maybe_save(step, full["state"])
        return state, LoopReport(step - start_step, retries, restores,
                                 stragglers, losses)
