"""Model substrate: attention variants, SSD, MoE with dual dispatch paths,
and the period-patterned transformer assembly."""
from .transformer import (
    cross_entropy_loss,
    decode_step,
    forward,
    init_cache,
    init_model,
    model_input_dtypes,
    prefill,
)

__all__ = [
    "cross_entropy_loss", "decode_step", "forward", "init_cache",
    "init_model", "model_input_dtypes", "prefill",
]
