"""StarCoder2-15B [arXiv:2402.19173; hf]: dense GQA, RoPE, non-gated GELU MLP."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    vocab_size=49_152,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24_576,
    mlp_type="gelu",
    qkv_bias=True,
    rope_theta=100_000.0,
    source="arXiv:2402.19173; hf bigcode/starcoder2-15b",
)

SMOKE = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    mlp_type="gelu",
    qkv_bias=True,
)

register(CONFIG, SMOKE)
