"""Execution-time path selection (paper §III.C).

The selector is *deliberately simple*: it looks only at indicators observable
cheaply at execution time — input scale, join-key cardinality, expected
intermediate size, and the memory budget — and asks one structural question:
**will the linear path's linearized intermediate exceed work_mem?**  If it
comfortably fits, the linear path wins (paper §V.B: at small scale the CPU
hash join is faster).  If it would spill, the regime-shift model predicts the
amplification cost α(N, M) and the tensor path is chosen when it avoids a
worse expected (and far worse tail) latency.

The selection never changes operator semantics — both paths produce identical
result sets (tests assert canonical equality).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .cost_model import CostModel
from .device_relation import DeviceRelation
from .relation import Relation

__all__ = ["Decision", "PathSelector"]


@dataclasses.dataclass
class Decision:
    path: str  # "linear" | "tensor"
    reason: str
    t_linear: float
    t_tensor: float
    predicted_spill_bytes: int


class PathSelector:
    def __init__(self, work_mem: int, cost_model: Optional[CostModel] = None,
                 force: Optional[str] = None):
        self.work_mem = int(work_mem)
        self.model = cost_model or CostModel()
        if force not in (None, "linear", "tensor"):
            raise ValueError(force)
        self.force = force

    # -- join ---------------------------------------------------------------
    def choose_join(self, build: Relation, probe: Relation, key: str) -> Decision:
        if self.force:
            return Decision(self.force, "forced", 0.0, 0.0, 0)
        n_b, n_p = len(build), len(probe)
        # execution-time observables: scale + key cardinality → output estimate.
        # A device-resident input is NOT sampled — pulling 64k keys to the
        # host for planning would be exactly the regime-crossing round trip
        # this layer exists to avoid; scale alone decides (dup ≈ 1).
        if isinstance(build, DeviceRelation):
            dup = 1.0
        else:
            sample = np.asarray(build[key][: min(n_b, 65536)])
            card = max(1, len(np.unique(sample)))
            dup = max(1.0, len(sample) / card)
        est_out = int(n_p * dup)
        est = self.model.estimate_join(
            n_b, n_p, build.row_bytes(), probe.row_bytes(), est_out, self.work_mem)
        if est.path_fits_mem:
            return Decision(
                "linear",
                f"hash table fits work_mem ({self.work_mem} B); linear path has "
                f"no spill regime at this scale",
                est.t_linear, est.t_tensor, 0)
        path = "tensor" if est.t_tensor < est.t_linear else "linear"
        return Decision(
            path,
            f"predicted spill {est.spill_bytes / 1e6:.1f} MB over {est.passes} "
            f"partition pass(es): α(N,M) makes T_linear={est.t_linear:.3f}s vs "
            f"T_tensor={est.t_tensor:.3f}s",
            est.t_linear, est.t_tensor, est.spill_bytes)

    # -- sort ------------------------------------------------------------------
    def choose_sort(self, rel: Relation, keys) -> Decision:
        if self.force:
            return Decision(self.force, "forced", 0.0, 0.0, 0)
        est = self.model.estimate_sort(
            len(rel), rel.row_bytes(), len(keys), self.work_mem)
        if est.path_fits_mem and est.t_linear <= est.t_tensor:
            return Decision(
                "linear",
                "dataset fits work_mem; in-memory lexsort is cheapest",
                est.t_linear, est.t_tensor, 0)
        path = "tensor" if est.t_tensor < est.t_linear else "linear"
        return Decision(
            path,
            f"predicted spill {est.spill_bytes / 1e6:.1f} MB / {est.passes} merge "
            f"pass(es); T_linear={est.t_linear:.3f}s vs T_tensor={est.t_tensor:.3f}s",
            est.t_linear, est.t_tensor, est.spill_bytes)
