"""Fused device-resident pipelines: Join→[Filter]→[Sort]→[Aggregate] as ONE
jitted program.

The seed executor lowered every intermediate to a host-numpy Relation between
operators — its own premature materialization.  This module compiles the
common pipeline fragment into a single XLA program that:

  * carries **gather indices** between the fused operators (late
    materialization): the join emits index arrays, the filter emits a mask,
    the sort permutes the indices — payload columns are gathered on device
    only at the moment a stage actually consumes them, and columns nobody
    consumes never move at all;
  * keeps every shape **static and bucketed**: input columns are padded to
    power-of-two buckets and join capacity is a power-of-two bucket, so
    repeated queries (even with drifting row counts) hit the compile cache
    instead of recompiling — cache keys are
    ``(fragment shape, capacity, input buckets, dtypes, num sort keys, agg)``;
  * performs **≤ 1 device→host transfer per query** on the happy path: the
    single batched fetch of the root result (plus the piggybacked exact match
    count).  If the optimistic capacity bucket overflows — detected from that
    same fetch, never from a separate sync — the driver re-runs at the exact
    bucket, which the cache then holds for every later query of that shape.

Host-side planning (capacity estimation from a key sample) reads only the
numpy inputs and costs no device traffic.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .codec_device import decode_device, dict_bucket
from .metrics import OpMetrics, SpillAccount, Timer
from .relation import Relation
from .table_cache import get_device_layouts, key_stats
from .tensor_engine import (capacity_bucket, radix_hash_probe_dispatch,
                            use_pallas)

__all__ = ["FusedSpec", "match_fragment", "run_fused", "sharded_supported",
           "pipeline_cache_info", "pipeline_cache_clear"]

_I64_MAX = np.iinfo(np.int64).max


# ---------------------------------------------------------------------------
# Fragment description + plan matching
# ---------------------------------------------------------------------------

import types

_VALUE_TYPES = (int, float, complex, bool, str, bytes, type(None),
                types.ModuleType)


def _value_safe(v) -> bool:
    """Is ``v`` safe to compare *by value* in a cache key?  Only immutable
    primitives (and module references, which act as namespaces) qualify —
    an object with the default identity hash can mutate underneath while
    its key stays equal, which would resurrect a stale traced program."""
    if isinstance(v, tuple):
        return all(_value_safe(x) for x in v)
    return isinstance(v, _VALUE_TYPES)


def _freeze(v):
    """Type-tagged value for a cache key.  Python equates ``1 == 1.0 ==
    True`` while the traced program bakes the concrete dtype in, so a
    captured value rebound across those types must be a different cache
    entry, not a dict-key collision resurrecting the stale program."""
    if isinstance(v, tuple):
        return ("tuple",) + tuple(_freeze(x) for x in v)
    return (type(v).__name__, v)


def _predicate_key(fn: Optional[Callable]):
    """Cache identity for a filter predicate.

    IR-built predicates (:class:`repro.core.expr.Expr`) carry their own
    canonical :meth:`~repro.core.expr.Expr.cache_token` — structural value
    identity with no bytecode inspection at all; this is the primary path
    for queries built through :mod:`repro.core.session`.

    Legacy lambdas fall back to bytecode keying: plans typically rebuild
    their predicate lambda per query; keying on ``id(fn)`` would miss the
    cache every time and pin each dead lambda alive inside a compiled
    program.  Identical code at the same source location with equal
    closure/default/global captures is the same predicate — but only when
    every captured value is value-comparable (:func:`_value_safe`), and
    captured values are *type-tagged* (:func:`_freeze`) so rebinding a
    cell across equal-comparing types (``1`` → ``1.0`` → ``True``) is a
    different entry.  Anything else (mutable objects, arrays, nested
    functions) falls back to object identity: fresh lambdas then re-trace
    (correct, just slower), and a *reused* lambda over mutated state keeps
    jax.jit's own closed-over-state semantics.
    """
    if fn is None:
        return None
    from .expr import CombinedPredicate, Expr

    if isinstance(fn, Expr):
        return ("expr", fn.cache_token())
    if isinstance(fn, CombinedPredicate):
        # planner-merged mixed conjunction: compose the per-part keys so a
        # replanned query (fresh wrapper, same parts) stays one cache entry
        return ("and",) + tuple(_predicate_key(p) for p in fn.parts)
    try:
        code = fn.__code__
        cells = tuple(c.cell_contents for c in (fn.__closure__ or ()))
        # referenced globals are baked into the traced program too — a
        # module-level THRESHOLD change must be a different cache entry
        globs = tuple((nm, fn.__globals__.get(nm)) for nm in code.co_names)
        defaults = fn.__defaults__ or ()
        if not (_value_safe(cells) and _value_safe(defaults)
                and all(_value_safe(v) for _, v in globs)):
            return ("id", id(fn))
        key = ("code", code.co_filename, code.co_firstlineno, code.co_code,
               code.co_consts, _freeze(cells),
               tuple((nm, _freeze(v)) for nm, v in globs), _freeze(defaults))
        hash(key)
        return key
    except Exception:
        return ("id", id(fn))


@dataclasses.dataclass(frozen=True)
class FusedSpec:
    """A fusable plan fragment over a Scan join: ``[Project](Aggregate?(
    Sort?(Filter?(Join))))``.  ``project`` narrows a relation root's output
    schema — projected-away columns are never gathered and never cross the
    device→host boundary."""

    join_key: str
    filter_fn: Optional[Callable]  # predicate over a column view, or None
    sort_keys: Tuple[str, ...]     # () = no sort stage
    agg: Optional[Tuple[str, str]]  # (column, fn) for a scalar root, or None
    project: Optional[Tuple[str, ...]] = None  # relation-root column subset

    def cache_signature(self) -> Tuple:
        return (self.join_key, _predicate_key(self.filter_fn),
                self.sort_keys, self.agg, self.project)


def match_fragment(plan):
    """Recognize Aggregate?(Sort?(Filter?(Join(Scan, Scan)))) fragments.

    Returns ``(spec, build_relation, probe_relation)`` or None.  At least one
    of the Filter/Sort/Aggregate stages must be present (a bare join gains
    nothing from fusion over the device-resident per-op path; a filtered
    join does — the predicate folds into the validity mask, and the
    planner's pushed-down filters keep multi-join stages on this path).
    """
    from .executor import Aggregate, Filter, Join, Project, Scan, Sort

    node = plan
    agg = None
    sort_keys: Tuple[str, ...] = ()
    filter_fn = None
    project = None
    if isinstance(node, Project):
        project = tuple(node.columns)
        node = node.child
    if isinstance(node, Aggregate):
        if project is not None:
            return None  # Project(Aggregate) is not a planner shape
        agg = (node.column, node.fn)
        node = node.child
    if isinstance(node, Sort):
        sort_keys = tuple(node.keys)
        node = node.child
    if isinstance(node, Filter):
        filter_fn = node.predicate
        node = node.child
    if not isinstance(node, Join):
        return None
    if not (isinstance(node.build, Scan) and isinstance(node.probe, Scan)):
        return None
    if agg is None and not sort_keys and filter_fn is None and project is None:
        return None
    build, probe = node.build.relation, node.probe.relation
    if len(build) == 0 or len(probe) == 0:
        return None  # degenerate inputs keep the generic path's exact semantics
    return (FusedSpec(node.key, filter_fn, sort_keys, agg, project),
            build, probe)


# ---------------------------------------------------------------------------
# Column view: late materialization inside the traced program
# ---------------------------------------------------------------------------

def _decoders(sigs, dicts, refs):
    """Per-column device decode closures from static layout signatures plus
    the runtime dictionary/reference-point inputs.  ``None`` marks a plain
    (raw-layout) column — no decode work is ever traced for it."""
    out = {}
    for name, (enc, _cdt, ldt) in sigs:
        if enc == "raw":
            out[name] = None
        elif enc == "for":
            out[name] = (lambda a, _l=ldt, _r=refs[name]:
                         decode_device(a, "for", _l, ref=_r))
        else:
            out[name] = (lambda a, _l=ldt, _d=dicts[name]:
                         decode_device(a, "dict", _l, dict_values=_d))
    return out


class _JoinView:
    """Column access over the joined index space; gathers on first touch only.

    Presents the joined schema (probe columns under their own names, build
    columns as ``b_<name>``, probe's key column under the join key).  Filter
    predicates receive this view — numpy-style expressions trace through it.

    Packed columns are stored as narrow codes: the gather moves code-width
    bytes and the decode to logical values runs *after* it, so the expensive
    data movement inside the program happens at packed width and consumers
    of the view still see exact logical values (the decode-at-fetch rule).
    """

    def __init__(self, bcols, pcols, key, build_idx, probe_idx,
                 bdec=None, pdec=None):
        self._bcols = bcols
        self._pcols = pcols
        self._key = key
        self._bidx = build_idx
        self._pidx = probe_idx
        self._bdec = bdec or {}
        self._pdec = pdec or {}
        self._cache: Dict[str, jnp.ndarray] = {}

    def names(self):
        out = list(self._pcols)
        out += [f"b_{n}" for n in self._bcols
                if n != self._key and f"b_{n}" not in out]
        return out

    def __getitem__(self, name: str) -> jnp.ndarray:
        if name not in self._cache:
            # build side resolves first: when a probe column is literally
            # named b_<x> and the build side has x, the engine's join
            # (a dict merge that assigns build columns last) serves the
            # BUILD column under that name — the view must agree
            if (name.startswith("b_") and name[2:] in self._bcols
                    and name[2:] != self._key):
                col = jnp.take(self._bcols[name[2:]], self._bidx)
                dec = self._bdec.get(name[2:])
            elif name in self._pcols:
                col = jnp.take(self._pcols[name], self._pidx)
                dec = self._pdec.get(name)
            else:
                raise KeyError(name)
            self._cache[name] = col if dec is None else dec(col)
        return self._cache[name]


# ---------------------------------------------------------------------------
# Program construction + shape-bucketed compile cache
# ---------------------------------------------------------------------------

class _PipelineCache:
    """Explicit compile cache keyed on the bucketed shape signature.

    jit would deduplicate compilations on its own, but an explicit cache (a)
    avoids re-tracing the program closure per query and (b) exposes hit/miss
    counters that tests use to prove shape bucketing prevents recompile
    churn.

    Thread-safe: concurrent serving sessions share this cache, so lookups,
    counter updates and inserts happen under one lock.  ``builder()`` runs
    inside the lock — it only constructs the jit *wrapper* (cheap; the
    actual XLA compilation happens lazily at first call, which JAX already
    serializes internally), and holding the lock guarantees two racing
    queries of the same shape get the SAME program object, so cache-miss
    accounting stays exact (the warm/cold feedback gate keys off it)."""

    def __init__(self):
        # key -> [program, ready]; ready flips once a call has completed,
        # i.e. XLA compilation is definitely done
        self._programs: Dict[Tuple, list] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple, builder: Callable[[], Callable]
            ) -> Tuple[Callable, bool]:
        """Returns ``(program, fresh)``.  ``fresh`` means the next call may
        pay XLA compilation — either this is the first request for the
        shape, or another thread inserted the wrapper and is still inside
        its compiling first call.  Fresh runs execute OUTSIDE the device
        dispatch queue (a racer blocking on JAX's internal compile lock
        while holding the FIFO would stall the whole fleet) and count as
        cache misses, so the executor's warm-feedback gate keeps their
        compile-inclusive walls out of the runtime profile."""
        with self._lock:
            entry = self._programs.get(key)
            if entry is None:
                self.misses += 1
                entry = self._programs[key] = [builder(), False]
                return entry[0], True
            if not entry[1]:
                self.misses += 1  # still compiling somewhere: cold
                return entry[0], True
            self.hits += 1
            return entry[0], False

    def mark_ready(self, key: Tuple) -> None:
        """A call of this program completed: compilation is over."""
        with self._lock:
            entry = self._programs.get(key)
            if entry is not None:
                entry[1] = True

    def info(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "programs": len(self._programs)}

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()
            self.hits = 0
            self.misses = 0


_CACHE = _PipelineCache()


def pipeline_cache_info() -> Dict[str, int]:
    return _CACHE.info()


def pipeline_cache_clear() -> None:
    _CACHE.clear()


def _join_sorted(bk, pk, n_build, n_probe, capacity):
    """General join core: sorted coordinate alignment (one device sort)."""
    B = bk.shape[0]
    P = pk.shape[0]
    iota_b = jnp.arange(B)
    iota_p = jnp.arange(P)
    # bucket padding rows sort to the tail and can never match
    bk_m = jnp.where(iota_b < n_build, bk, _I64_MAX)
    order = jnp.argsort(bk_m, stable=True)
    sk = jnp.take(bk_m, order)
    left = jnp.searchsorted(sk, pk, side="left")
    right = jnp.searchsorted(sk, pk, side="right")
    counts = right - left
    # padded probe rows contribute nothing; a real probe key equal to the
    # int64 sentinel would false-match padded build rows, so it is
    # excluded (documented key-domain contract)
    counts = jnp.where((iota_p < n_probe) & (pk != _I64_MAX), counts, 0)
    ends = jnp.cumsum(counts)
    starts = ends - counts
    total = ends[-1]
    slot = jnp.arange(capacity, dtype=ends.dtype)
    # expansion by scan, not binary search: scatter each matched probe row's
    # index at its start slot, then forward-fill with a running max
    seed_slots = jnp.full((capacity + 1,), -1, jnp.int64)
    tgt = jnp.where(counts > 0, jnp.minimum(starts, capacity), capacity)
    seeded = seed_slots.at[tgt].max(iota_p)[:capacity]
    probe_idx = jnp.maximum(jax.lax.cummax(seeded), 0)
    build_pos = left[probe_idx] + (slot - starts[probe_idx])
    build_idx = jnp.take(order, jnp.clip(build_pos, 0, B - 1))
    valid = slot < total
    has_dup = jnp.asarray(False)
    return build_idx, probe_idx, valid, total, has_dup


def _join_sorted_run(sk, pk, n_probe, capacity):
    """Join core over a PRE-SORTED build run (the sharded path).

    The partitioned layout (:mod:`repro.core.partition`) stores each build
    partition key-sorted with sentinel padding at the tail, so alignment is
    a searchsorted probe over an already-ordered, cache-resident run —
    **no per-query device sort at all**.  ``build_idx`` therefore indexes
    the stored run directly (the single-device core needs an ``order``
    indirection because it sorts inside the program).  Expansion is the
    same scatter + running-max forward fill as :func:`_join_sorted`.
    """
    B = sk.shape[0]
    P = pk.shape[0]
    iota_p = jnp.arange(P)
    left = jnp.searchsorted(sk, pk, side="left")
    right = jnp.searchsorted(sk, pk, side="right")
    # sentinel-padded probe rows contribute nothing (same key-domain
    # contract as the single-device core)
    counts = jnp.where((iota_p < n_probe) & (pk != _I64_MAX),
                       right - left, 0)
    ends = jnp.cumsum(counts)
    starts = ends - counts
    total = ends[-1]
    slot = jnp.arange(capacity, dtype=ends.dtype)
    seed_slots = jnp.full((capacity + 1,), -1, jnp.int64)
    tgt = jnp.where(counts > 0, jnp.minimum(starts, capacity), capacity)
    seeded = seed_slots.at[tgt].max(iota_p)[:capacity]
    probe_idx = jnp.maximum(jax.lax.cummax(seeded), 0)
    build_pos = left[probe_idx] + (slot - starts[probe_idx])
    build_idx = jnp.clip(build_pos, 0, B - 1)
    valid = slot < total
    return build_idx, probe_idx, valid, total


def _join_dense(bk, pk, n_build, n_probe, capacity, domain: int, kmin,
                use_kernel: bool = False):
    """Dense-domain join core: the key IS a coordinate axis.

    When the build key domain is dense enough to materialize as an axis of
    length ``domain`` (a static power-of-two bucket; ``kmin`` is a traced
    offset) and build keys are unique (PK-FK joins), alignment is direct
    scatter/gather addressing — NO device sort at all.  Uniqueness is
    *verified on device* and the flag rides back with the result fetch; the
    driver re-runs on the sorted core if the optimistic choice was wrong.
    Slot ``domain`` of every scatter target is the spill-over slot for rows
    that must not write (bucket padding / out-of-domain keys).

    ``use_kernel`` (static) routes the table build + probe through the
    Pallas radix-join kernels (:mod:`repro.kernels.segment_join`) via
    :func:`~repro.core.tensor_engine.radix_hash_probe_dispatch` — the
    in-domain codes ``bk0c``/``pk0c`` are exactly the int32 code-domain
    contract those kernels tile over, and the dead slot ``domain`` is
    their padding slot.  Results are bit-for-bit the jnp scatter path's
    (kernel parity is regression-tested in tests/test_kernels.py).
    """
    B = bk.shape[0]
    P = pk.shape[0]
    iota_b = jnp.arange(B)
    iota_p = jnp.arange(P)
    bk0 = bk - kmin
    b_live = iota_b < n_build
    bk0c = jnp.where(b_live & (bk0 >= 0) & (bk0 < domain), bk0, domain)
    pk0 = pk - kmin
    p_live = (iota_p < n_probe) & (pk0 >= 0) & (pk0 < domain)
    pk0c = jnp.where(p_live, pk0, domain)
    if use_kernel:
        cnt_p, brow, has_dup = radix_hash_probe_dispatch(
            bk0c.astype(jnp.int32), pk0c.astype(jnp.int32), domain, True)
        matched = p_live & (cnt_p > 0)
        ends = jnp.cumsum(matched.astype(jnp.int64))
        total = ends[-1]
        slot = jnp.arange(capacity, dtype=jnp.int64)
        pos = jnp.where(matched, jnp.minimum(ends - 1, capacity - 1),
                        capacity)
        probe_idx = jnp.zeros((capacity + 1,),
                              jnp.int64).at[pos].max(iota_p)[:capacity]
        build_idx = jnp.take(jnp.maximum(brow, 0).astype(jnp.int64),
                             probe_idx)
        valid = slot < total
        return build_idx, probe_idx, valid, total, has_dup
    cnt = jnp.zeros((domain + 1,), jnp.int32).at[bk0c].add(1)
    has_dup = cnt[:domain].max() > 1
    inv = jnp.zeros((domain + 1,), jnp.int64).at[bk0c].set(iota_b)
    matched = p_live & (cnt[pk0c] > 0)
    ends = jnp.cumsum(matched.astype(jnp.int64))
    total = ends[-1]
    slot = jnp.arange(capacity, dtype=jnp.int64)
    pos = jnp.where(matched, jnp.minimum(ends - 1, capacity - 1), capacity)
    probe_idx = jnp.zeros((capacity + 1,), jnp.int64).at[pos].max(iota_p)[:capacity]
    build_idx = jnp.take(inv, jnp.take(pk0c, probe_idx))
    valid = slot < total
    return build_idx, probe_idx, valid, total, has_dup


def _build_program(spec: FusedSpec, key: str, capacity: int,
                   dense_domain: Optional[int] = None,
                   key_mode: str = "value", use_kernel: bool = False,
                   bsig: Tuple = (), psig: Tuple = ()):
    """Trace-time closure for one (fragment, capacity, bucket) cache entry.

    ``dense_domain`` (a static power-of-two bucket) selects the sort-free
    coordinate join core; the domain offset ``kmin`` stays a traced scalar so
    drifting key ranges reuse the compiled program.

    ``bsig``/``psig`` are the static per-column layout signatures
    (:meth:`~repro.core.codec_device.DeviceColumnLayout.signature`) of the
    packed inputs — the program closes over the codec *shape*; dictionaries
    and reference points stay runtime inputs so data refreshes never
    recompile.  ``key_mode`` selects the join coordinate domain:

      * ``"value"`` — the key decodes to int64 values in-program (an
        elementwise op; the H2D transfer already happened at packed width)
        and the join cores run exactly as before;
      * ``"dict"``  — the build key is dictionary-encoded and the join runs
        *directly in the code domain*: build codes are the coordinates,
        probe values remap into the build dictionary with one device
        ``searchsorted`` (misses land on the dead slot), and the dense core
        operates over ``dense_domain ==`` the padded dictionary bucket.
        The key axis never widens to int64 coordinates at all.
    """

    def program(bcols: Dict[str, jnp.ndarray], pcols: Dict[str, jnp.ndarray],
                bdicts, pdicts, brefs, prefs, n_build, n_probe, kmin):
        bdec = _decoders(bsig, bdicts, brefs)
        pdec = _decoders(psig, pdicts, prefs)
        if key_mode == "dict":
            # code-domain join: build codes ARE the coordinates; the probe
            # side remaps its logical key values into the build dictionary
            # (padded with repeats of the last value — searchsorted-left
            # still returns the true first occurrence; see pad_dictionary)
            bk = bcols[key].astype(jnp.int64)
            pk_raw = pcols[key]
            pk_vals = (pk_raw if pdec.get(key) is None
                       else pdec[key](pk_raw)).astype(jnp.int64)
            bdict = bdicts[key].astype(jnp.int64)
            dbkt = bdict.shape[0]
            pos = jnp.searchsorted(bdict, pk_vals, side="left")
            posc = jnp.clip(pos, 0, dbkt - 1)
            hit = jnp.take(bdict, posc) == pk_vals
            pk = jnp.where(hit, posc, dense_domain).astype(jnp.int64)
        else:
            # join coordinates are int64 (same coercion as tensor_join); the
            # view/output below serves the ORIGINAL key column — dtype and
            # values of result columns never depend on fusion
            bk_raw, pk_raw = bcols[key], pcols[key]
            bk = (bk_raw if bdec.get(key) is None
                  else bdec[key](bk_raw)).astype(jnp.int64)
            pk = (pk_raw if pdec.get(key) is None
                  else pdec[key](pk_raw)).astype(jnp.int64)
        if dense_domain is not None:
            build_idx, probe_idx, valid, total, has_dup = _join_dense(
                bk, pk, n_build, n_probe, capacity, dense_domain, kmin,
                use_kernel=use_kernel)
        else:
            build_idx, probe_idx, valid, total, has_dup = _join_sorted(
                bk, pk, n_build, n_probe, capacity)

        view = _JoinView(bcols, pcols, key, build_idx, probe_idx, bdec, pdec)
        if spec.filter_fn is not None:
            mask = jnp.asarray(spec.filter_fn(view), bool)
            valid = valid & mask

        perm = None
        if spec.sort_keys:
            # ONE multi-operand lexicographic device sort: key axes stay
            # separate operands (no linearization into a composite scalar)
            # and the permutation rides as the trailing payload.  Invalid
            # rows sink by pinning their most-significant key to the dtype
            # maximum — their relative position among real max-key rows is
            # irrelevant because only valid rows survive materialization.
            keys0 = [view[k] for k in spec.sort_keys]
            msk = keys0[0]
            if jnp.issubdtype(msk.dtype, jnp.integer):
                fill = jnp.iinfo(msk.dtype).max
            else:
                fill = jnp.inf
            operands = [jnp.where(valid, msk, fill)] + keys0[1:]
            operands.append(jnp.arange(capacity, dtype=jnp.int32))
            sorted_ops = jax.lax.sort(tuple(operands), dimension=0,
                                      is_stable=True,
                                      num_keys=len(operands) - 1)
            perm = sorted_ops[-1]

        if spec.agg is not None:
            col_name, fn = spec.agg
            col = view[col_name]
            v = valid if perm is None else jnp.take(valid, perm)
            c = col if perm is None else jnp.take(col, perm)
            # integer columns reduce in int64 (exact, matches the host path
            # bit-for-bit — f64 would lose integer sums past 2^53)
            is_int = jnp.issubdtype(c.dtype, jnp.integer)
            if fn == "sum":
                zero = jnp.asarray(0, c.dtype)
                scalar = jnp.where(v, c, zero).sum()
            elif fn == "count":
                scalar = v.sum().astype(jnp.int64)
            elif fn == "min":
                fill = jnp.iinfo(c.dtype).max if is_int else jnp.inf
                scalar = jnp.where(v, c, fill).min()
            elif fn == "max":
                fill = jnp.iinfo(c.dtype).min if is_int else -jnp.inf
                scalar = jnp.where(v, c, fill).max()
            else:
                raise ValueError(fn)
            # agg_n rides the fetch so the driver can reject min/max over an
            # empty result (the fill value is not a legitimate answer) the
            # way the host path's numpy reduction does
            return {"total": total, "has_dup": has_dup, "scalar": scalar,
                    "agg_n": v.sum()}

        # relation root (sort is the last stage): gather the output schema
        # through the sorted indices — the only payload gathers in the
        # whole pipeline, and they happen once, on device.  A projected
        # root gathers (and later fetches) only its declared subset.
        out_names = view.names() if spec.project is None else spec.project
        out_cols = {name: (view[name] if perm is None
                           else jnp.take(view[name], perm))
                    for name in out_names}
        out_valid = valid if perm is None else jnp.take(valid, perm)
        return {"total": total, "has_dup": has_dup, "cols": out_cols,
                "valid": out_valid}

    return jax.jit(program)


# ---------------------------------------------------------------------------
# Sharded program: partition-parallel fragment over a device mesh
# ---------------------------------------------------------------------------

def sharded_supported(spec: FusedSpec, build: Relation,
                      probe: Relation) -> bool:
    """Host-side eligibility of a fragment for partition-parallel execution.

    The sharded path merges per-partition results with device-side
    combines (psum/pmin/pmax over the mesh axis), so only scalar
    AGGREGATE roots qualify — a relation root would need a global merge
    that re-serializes the partitions.  Bit-for-bit parity with the
    single-device program is part of the contract, which admits exactly
    the order-independent reductions: ``count`` always; ``min``/``max``
    always (exact for floats too); ``sum`` only over integer columns —
    integer addition is associative even under wraparound, while a float
    psum of per-partition partials reassociates the single program's
    reduction order.  Join keys must be integers (the partition hash and
    the sentinel padding contract are int64).  A fragment's sort stage is
    irrelevant under these aggregates and is skipped per shard.
    """
    if spec.agg is None:
        return False
    key = spec.join_key
    for rel in (build, probe):
        if not isinstance(rel, Relation) or key not in rel.names:
            return False
        if not np.issubdtype(rel[key].dtype, np.integer):
            return False
    col, fn = spec.agg
    if fn == "count":
        return True
    # the _JoinView naming contract: build wins b_<x> collisions
    if col.startswith("b_") and col[2:] in build.names and col[2:] != key:
        dtype = build[col[2:]].dtype
    elif col in probe.names:
        dtype = probe[col].dtype
    else:
        return False
    if fn in ("min", "max"):
        return True
    return fn == "sum" and bool(np.issubdtype(dtype, np.integer))


def _build_sharded_program(spec: FusedSpec, key: str, num_parts: int,
                           capacity: int, bsig: Tuple = (),
                           psig: Tuple = ()):
    """Trace-time closure for one sharded (fragment, partitions, capacity)
    cache entry: the per-shard fragment body under ``shard_map`` over the
    relational mesh, with device-side combines so the host still fetches
    ONE replicated result dict per query.

    ``max_part_total`` (the largest single partition's match count) rides
    the fetch next to the psum'd total so the driver can verify its
    optimistic per-partition capacity without a second sync.

    Payload columns arrive as packed codes (``bsig``/``psig`` carry the
    static layout signatures); dictionaries and reference points are
    REPLICATED runtime inputs — every shard decodes at gather against the
    full dictionary, and a data refresh never recompiles.  The join key
    stays logical int64 (the sentinel-padding contract).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PSpec

    from ..distributed.sharding import PART_AXIS, relational_mesh

    mesh = relational_mesh(num_parts)
    col_name, fn = spec.agg

    def shard_body(bcols, pcols, bdicts, pdicts, brefs, prefs,
                   n_build, n_probe):
        # each shard sees a (1, bucket) block of its partition: squeeze
        bcols = {k: v[0] for k, v in bcols.items()}
        pcols = {k: v[0] for k, v in pcols.items()}
        bdec = _decoders(bsig, bdicts, brefs)
        pdec = _decoders(psig, pdicts, prefs)
        del n_build  # build padding is sentinel-keyed; no live-row mask
        npr = n_probe[0]
        sk = bcols[key].astype(jnp.int64)
        pk = pcols[key].astype(jnp.int64)
        build_idx, probe_idx, valid, total = _join_sorted_run(
            sk, pk, npr, capacity)
        view = _JoinView(bcols, pcols, key, build_idx, probe_idx,
                         bdec, pdec)
        if spec.filter_fn is not None:
            mask = jnp.asarray(spec.filter_fn(view), bool)
            valid = valid & mask
        # sort stage intentionally skipped: the supported aggregates are
        # order-independent (see sharded_supported)
        if fn == "count":
            part = valid.sum().astype(jnp.int64)
            scalar = jax.lax.psum(part, PART_AXIS)
        else:
            c = view[col_name]
            is_int = jnp.issubdtype(c.dtype, jnp.integer)
            if fn == "sum":
                zero = jnp.asarray(0, c.dtype)
                part = jnp.where(valid, c, zero).sum()
                scalar = jax.lax.psum(part, PART_AXIS)
            elif fn == "min":
                fill = jnp.iinfo(c.dtype).max if is_int else jnp.inf
                part = jnp.where(valid, c, fill).min()
                scalar = jax.lax.pmin(part, PART_AXIS)
            elif fn == "max":
                fill = jnp.iinfo(c.dtype).min if is_int else -jnp.inf
                part = jnp.where(valid, c, fill).max()
                scalar = jax.lax.pmax(part, PART_AXIS)
            else:
                raise ValueError(fn)
        return {"total": jax.lax.psum(total, PART_AXIS),
                "max_part_total": jax.lax.pmax(total, PART_AXIS),
                "scalar": scalar,
                "agg_n": jax.lax.psum(valid.sum(), PART_AXIS)}

    mapped = shard_map(shard_body, mesh=mesh,
                       in_specs=(PSpec(PART_AXIS), PSpec(PART_AXIS),
                                 PSpec(), PSpec(), PSpec(), PSpec(),
                                 PSpec(PART_AXIS), PSpec(PART_AXIS)),
                       out_specs=PSpec())
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

# The device is a serially-shared resource: concurrent serving sessions
# funnel fused-program launches through the broker's DeviceQueue (a typed
# DeviceLease per dispatch), so a query's device phase runs at full speed
# instead of time-slicing against seven neighbors (the scheduler roulette
# that turns a homogeneous workload into a 3x p99/p50 spread).  Latency
# becomes queue wait + execution — the wait is accounted in
# OpMetrics.queue_wait_s and excluded from the runtime profile's
# execution-cost observations.  Queued dispatches of the SAME compiled
# shape (lease batch_key = the pipeline cache key) coalesce into one
# micro-batched admission group instead of running strictly one-at-a-time;
# the programs are identical compiled artifacts over independent inputs, so
# coalescing changes scheduling only, never results.
# ``REPRO_DEVICE_SERIALIZE=0`` makes the broker grant device leases without
# serializing (e.g. multi-device hosts where XLA can genuinely overlap
# programs).


def _host_plan(build: Relation, probe: Relation, key: str):
    """Host-side planning from the numpy inputs — free of device traffic.

    Returns ``(capacity, dense_domain, kmin)``: an optimistic capacity bucket
    from the cached key-cardinality sketch (:func:`repro.core.table_cache.
    key_stats` — repeated queries do not re-sample), and — when the build key
    domain is dense enough to materialize as a coordinate axis and the sample
    predicts unique keys — the power-of-two domain bucket for the sort-free
    dense join core.  Both predictions are *verified on device* (overflow /
    has_dup piggyback on the result fetch), so a wrong guess costs one retry,
    never a wrong answer.
    """
    stats = key_stats(build, key)
    capacity = capacity_bucket(int(len(probe) * stats.dup))
    dense_domain = None
    kmin = 0
    if stats.dup == 1.0 and stats.n:
        kmin = int(stats.kmin)
        width = int(stats.kmax) - kmin + 1
        if width <= 4 * capacity_bucket(stats.n):
            dense_domain = capacity_bucket(width)
    return capacity, dense_domain, kmin


def run_fused(spec: FusedSpec, build: Relation, probe: Relation,
              decision_reason: str = "", broker=None,
              shards: Optional[int] = None,
              guard=None) -> Tuple[object, OpMetrics]:
    """Execute a fused fragment; returns (Relation | float, OpMetrics).

    Happy path: one compiled program launch + one batched device→host fetch.
    Capacity overflow (optimistic bucket too small) re-runs once at the exact
    bucket; both programs stay cached for subsequent queries.

    Device dispatch acquires a :class:`~repro.core.resource_broker.
    DeviceLease` from ``broker`` (the process-wide default broker when none
    is passed — one shared queue per physical device); queued dispatches of
    the same compiled shape coalesce into one micro-batched admission group.

    ``shards=N`` (N >= 2) requests partition-parallel execution over the
    first N mesh devices: hash/radix co-partition both sides by the join
    key, run the fragment per partition under ``shard_map``, and combine
    per-partition aggregates on device — still ≤ 1 device→host sync.  The
    request silently degrades to the single-device path when the fragment
    is not :func:`sharded_supported` or fewer devices exist (metrics then
    report ``devices=1``); dispatch holds a gang lease over one broker
    lane per device.

    ``guard`` is an optional :class:`~repro.core.guards.ExecutionGuard`:
    a capacity overflow — the device reporting the ACTUAL join fan-out —
    is fed to ``guard.observe_fragment`` before the retry, which may raise
    :class:`~repro.core.guards.SwitchPoint` to abandon the retry loop when
    the re-priced linear fragment beats a second dispatch at the exact
    bucket (the executor's generic walk then re-plans with ground truth).
    """
    if broker is None:
        from .resource_broker import default_broker
        broker = default_broker()
    if shards is not None and int(shards) > 1:
        from ..distributed.sharding import available_partitions

        num_parts = min(int(shards), available_partitions())
        if num_parts > 1 and sharded_supported(spec, build, probe):
            return _run_fused_sharded(spec, build, probe, num_parts,
                                      decision_reason, broker)
    n_build, n_probe = len(build), len(probe)
    b_bucket = capacity_bucket(n_build)
    p_bucket = capacity_bucket(n_probe)
    syncs = 0
    queue_wait = 0.0
    any_fresh = False
    batched = False
    with Timer() as t:
        # host planning is part of the query's wall time (the per-op
        # baseline pays for its planning inside its timers too)
        capacity, dense_domain, kmin = _host_plan(build, probe, spec.join_key)
        layouts_b, up_b, log_b = get_device_layouts(build, b_bucket)
        layouts_p, up_p, log_p = get_device_layouts(probe, p_bucket)
        bcols = {k: dc.codes for k, dc in layouts_b.items()}
        pcols = {k: dc.codes for k, dc in layouts_p.items()}
        bdicts = {k: dc.dict_values for k, dc in layouts_b.items()
                  if dc.dict_values is not None}
        pdicts = {k: dc.dict_values for k, dc in layouts_p.items()
                  if dc.dict_values is not None}
        brefs = {k: dc.layout.ref for k, dc in layouts_b.items()
                 if dc.encoding == "for"}
        prefs = {k: dc.layout.ref for k, dc in layouts_p.items()
                 if dc.encoding == "for"}
        bsig = tuple(sorted((k, dc.layout.signature())
                            for k, dc in layouts_b.items()))
        psig = tuple(sorted((k, dc.layout.signature())
                            for k, dc in layouts_p.items()))
        # Dictionary-encoded build key + sampled-unique keys: join in the
        # code domain — the dense core over the padded dictionary bucket,
        # even when the VALUE domain is far too wide/sparse for it.  A
        # wrong uniqueness guess is caught on device (has_dup) and retried
        # on the sorted value core, same as the value-dense path.
        key_mode = "value"
        bkey = layouts_b[spec.join_key]
        if bkey.encoding == "dict":
            stats = key_stats(build, spec.join_key)
            if stats.dup == 1.0 and stats.n:
                key_mode = "dict"
                dense_domain = dict_bucket(bkey.layout.card)
                kmin = 0
        while True:
            use_kernel = (use_pallas(dense_domain)
                          if dense_domain is not None else False)
            cache_key = (spec.cache_signature(), capacity, b_bucket,
                         p_bucket, dense_domain, key_mode, use_kernel,
                         bsig, psig)
            prog, fresh = _CACHE.get(
                cache_key,
                lambda: _build_program(spec, spec.join_key, capacity,
                                       dense_domain, key_mode, use_kernel,
                                       bsig, psig))
            # a FRESH program's first call pays multi-second XLA
            # compilation; running it outside the queue keeps one novel
            # shape from stalling every other query's device phase (its
            # own unserialized execution is a one-off, and compiling runs
            # never feed the runtime profile anyway)
            any_fresh = any_fresh or fresh
            lease = None
            if not fresh:
                lease = broker.device_lease(batch_key=("fused", cache_key))
                queue_wait += lease.wait_s
            try:
                out = prog(bcols, pcols, bdicts, pdicts, brefs, prefs,
                           n_build, n_probe, kmin)
                fetched = jax.device_get(out)  # THE host sync of the query
            finally:
                if lease is not None:
                    lease.release()
                    # read AFTER the run: `batched` is live — a solo lease
                    # becomes batched when a same-shape arrival joins its
                    # in-flight round
                    batched = batched or lease.batched
            if fresh:
                _CACHE.mark_ready(cache_key)
            syncs += 1
            total = int(fetched["total"])
            if dense_domain is not None and bool(fetched["has_dup"]):
                # optimistic unique-key guess was wrong: fall back to the
                # sorted core over decoded int64 values (code-domain joins
                # included — the sorted core's sentinel contract is int64)
                dense_domain = None
                key_mode = "value"
                kmin = 0
                continue
            if total <= capacity:
                break
            if guard is not None:
                # the overflow IS the observed fan-out: let the execution-
                # time guard re-check the fragment decision before paying
                # the retry dispatch (raises SwitchPoint to abandon)
                guard.observe_fragment(total, capacity)
            capacity = capacity_bucket(total)  # rare: bucket overflowed
        if spec.agg is not None:
            if spec.agg[1] in ("min", "max") and int(fetched["agg_n"]) == 0:
                raise ValueError(
                    f"{spec.agg[1]} over an empty result has no identity")
            result = float(fetched["scalar"])
            rows_out = 1
        else:
            keep = np.nonzero(np.asarray(fetched["valid"]))[0]
            result = Relation({k: np.asarray(v)[keep]
                               for k, v in fetched["cols"].items()})
            rows_out = len(result)
    metrics = OpMetrics(
        op="fused_pipeline",
        path="tensor",
        rows_in=n_build + n_probe,
        rows_out=rows_out,
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=(b_bucket + p_bucket) * 8 * 3
        + capacity * 8 * (3 + len(spec.sort_keys)),
        decision_reason=decision_reason,
        host_syncs=syncs,
        h2d_bytes=up_b + up_p,
        h2d_bytes_logical=log_b + log_p,
        queue_wait_s=queue_wait,
        compiled=any_fresh,
        batched=batched,
    )
    return result, metrics


# Verified per-partition capacities by (fragment, partitions, key-column
# tokens): content-addressed, so a mutated table simply misses and re-plans.
# Bounded as a backstop; overflow costs at most one extra retry per entry.
_CAP_HINTS: Dict[tuple, int] = {}
_CAP_HINT_LOCK = threading.Lock()
_CAP_HINTS_CAP = 512


def _run_fused_sharded(spec: FusedSpec, build: Relation, probe: Relation,
                       num_parts: int, decision_reason: str,
                       broker) -> Tuple[float, OpMetrics]:
    """Partition-parallel driver: cached partitioned layouts in, ONE gang
    dispatch over ``num_parts`` broker lanes, ONE replicated fetch out.

    The per-partition capacity is optimistic — the critical partition's
    probe fill times the sampled duplication factor, with skew slack — and
    verified on device: ``max_part_total`` rides the single result fetch,
    a wrong guess costs one retry at the exact bucket, never a wrong
    answer (the same discipline as the single-device driver's overflow
    and dense retries).
    """
    from .partition import get_partitioned_columns, partition_bucket
    from .relation import column_token

    n_build, n_probe = len(build), len(probe)
    syncs = 0
    queue_wait = 0.0
    any_fresh = False
    batched = False
    broker.ensure_lanes(num_parts)
    with Timer() as t:
        stats = key_stats(build, spec.join_key)
        (bcols, counts_b_dev, counts_b, bucket_b, up_b, log_b, b_lay,
         bdicts) = get_partitioned_columns(build, spec.join_key, num_parts,
                                           sort_within=True)
        (pcols, counts_p_dev, counts_p, bucket_p, up_p, log_p, p_lay,
         pdicts) = get_partitioned_columns(probe, spec.join_key, num_parts,
                                           sort_within=False)
        brefs = {k: lay.ref for k, lay in b_lay.items()
                 if lay.encoding == "for"}
        prefs = {k: lay.ref for k, lay in p_lay.items()
                 if lay.encoding == "for"}
        bsig = tuple(sorted((k, lay.signature()) for k, lay in b_lay.items()))
        psig = tuple(sorted((k, lay.signature()) for k, lay in p_lay.items()))
        est_part_out = int(max(1, int(counts_p.max())) * stats.dup)
        capacity = partition_bucket(int(est_part_out * 1.25))
        # A verified-capacity hint from an earlier run of this fragment over
        # the same data: the optimistic estimate is recomputed per call, so
        # without the hint a query whose critical partition overflows it
        # would pay the overflow retry (a second dispatch + fetch) on EVERY
        # warm serving query, not just the first.
        hint_key = (spec.cache_signature(), num_parts,
                    column_token(build[spec.join_key]),
                    column_token(probe[spec.join_key]))
        with _CAP_HINT_LOCK:
            capacity = max(capacity, _CAP_HINTS.get(hint_key, 0))
        while True:
            cache_key = ("sharded", spec.cache_signature(), num_parts,
                         capacity, bucket_b, bucket_p, bsig, psig)
            prog, fresh = _CACHE.get(
                cache_key,
                lambda: _build_sharded_program(spec, spec.join_key,
                                               num_parts, capacity,
                                               bsig, psig))
            any_fresh = any_fresh or fresh
            # ALWAYS under the gang lease — including the compile dispatch.
            # A sharded launch runs collectives over every lane's device;
            # any unleased dispatch (the old fresh-path bypass) can overlap
            # another thread's leased launch and deadlock the host-platform
            # collective rendezvous.
            lease = broker.device_lease(lanes=num_parts)
            queue_wait += lease.wait_s
            try:
                out = prog(bcols, pcols, bdicts, pdicts, brefs, prefs,
                           counts_b_dev, counts_p_dev)
                fetched = jax.device_get(out)  # THE host sync of the query
            finally:
                lease.release()
                batched = batched or lease.batched
            if fresh:
                _CACHE.mark_ready(cache_key)
            syncs += 1
            max_part = int(fetched["max_part_total"])
            if max_part <= capacity:
                # remember the verified minimal bucket (max() keeps it from
                # ever shrinking a future optimistic estimate)
                with _CAP_HINT_LOCK:
                    if len(_CAP_HINTS) >= _CAP_HINTS_CAP:
                        _CAP_HINTS.clear()
                    _CAP_HINTS[hint_key] = max(
                        _CAP_HINTS.get(hint_key, 0),
                        partition_bucket(max_part))
                break
            capacity = partition_bucket(max_part)  # rare: skewed overflow
        if spec.agg[1] in ("min", "max") and int(fetched["agg_n"]) == 0:
            raise ValueError(
                f"{spec.agg[1]} over an empty result has no identity")
        result = float(fetched["scalar"])
    metrics = OpMetrics(
        op="fused_pipeline",
        path="tensor",
        rows_in=n_build + n_probe,
        rows_out=1,
        wall_s=t.elapsed,
        spill=SpillAccount(),
        peak_working_set_bytes=num_parts * (bucket_b + bucket_p) * 8 * 3
        + num_parts * capacity * 8 * 3,
        decision_reason=decision_reason,
        host_syncs=syncs,
        h2d_bytes=up_b + up_p,
        h2d_bytes_logical=log_b + log_p,
        queue_wait_s=queue_wait,
        compiled=any_fresh,
        batched=batched,
        devices=num_parts,
    )
    return result, metrics
