"""Queue-aware resource broker: one layer that owns "what will this request
actually wait for, right now".

Before this module the serving layer had three independent resource
mechanisms, none of which could see the queues the others created:

  * the :class:`~repro.core.memory_governor.MemoryGovernor` priced memory
    (full grant / floor degradation / blocking admission) but its
    ``would_grant`` peek was blind to *admission wait* — when not even the
    floor was free it reported the floor the waiter would eventually get,
    and the wait itself was invisible to path pricing;
  * a module-global FIFO ticket lock in ``core/fused.py`` serialized device
    programs invisibly — queue depth existed, but nothing could observe or
    price it;
  * the :class:`~repro.core.path_selector.PathSelector` priced *execution*
    cost only, so under load ``auto`` happily chose a small linear operator
    that then parked in admission while the tensor path would have run
    immediately (ROADMAP open items 1–3).

The :class:`ResourceBroker` unifies them.  Every execution path acquires
resources through typed leases — :class:`MemoryLease` for linear operators
(wrapping the governor's grant), :class:`DeviceLease` for fused *and*
per-operator tensor dispatch — and the broker tracks, per resource, live
queue depth and EWMA wait/hold times.  One :meth:`ResourceBroker.price`
entry point turns a :class:`ResourceRequest` into a :class:`PressureQuote`
(expected grant + expected admission/queue wait) that the selector folds
into path costs, so the decision layer finally prices *run-time conditions*
(Graefe's robustness argument), not just compile-time estimates.

Device micro-batching: the :class:`DeviceQueue` admits leases in strict
arrival order, but queued leases that share a ``batch_key`` (the fused
pipeline passes its compiled-shape cache key; the per-operator tensor path
uses a shared ``"per-op"`` bucket) are admitted **together** as one
coalesced dispatch group instead of running strictly one-at-a-time — the
programs are identical compiled artifacts, so overlapping them changes
scheduling only, never results (asserted bit-for-bit in tests and fig12).

**Price-and-hold reservations** close the quote's decide-then-act gap: a
:class:`PressureQuote` is non-binding, so between "the quote said the full
grant is free" and "the operator acquires", a concurrent grant can take the
bytes — ``auto`` then runs its *linear* decision on a *degraded* grant it
never priced (the decide-then-lose incident fig13 counts).
:meth:`ResourceBroker.reserve` pairs the quote with a short-TTL
:class:`~repro.core.memory_governor.MemoryHold`: the quoted bytes are
committed at decision time, :meth:`memory_lease` converts the hold without
waiting, and a decision that goes the other way cancels it (the TTL reaps
anything leaked).  ``reservations=False`` is the quote-only ablation.

**Preemption**: floor-degraded linear operators register a
:class:`PreemptToken` while they run; :meth:`ResourceBroker.
preempt_degraded` cancels them mid-spill (they poll the token at partition
/ run boundaries) so the executor can requeue the operator on the tensor
path — graceful degradation instead of a multi-second spill wall blocking
a premium tenant's admission.

``REPRO_DEVICE_SERIALIZE=0`` keeps its escape-hatch meaning: the broker
grants device leases without serializing (multi-device hosts where XLA can
genuinely overlap arbitrary programs).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from .faults import FaultInjector, PreemptedError
from .memory_governor import MemoryGovernor, MemoryGrant, MemoryHold

__all__ = ["ResourceBroker", "ResourceRequest", "PressureQuote",
           "Reservation", "PreemptToken", "MemoryLease", "DeviceLease",
           "DeviceGangLease", "DeviceQueue", "BrokerStats",
           "default_broker"]

# EWMA smoothing for wait/hold/service observations: heavy enough that one
# stall cannot whipsaw the pricing, light enough to track a shifting load
# within ~a dozen observations.
_EWMA_ALPHA = 0.3


# ---------------------------------------------------------------------------
# Request / quote types
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResourceRequest:
    """What an execution path is about to acquire.

    ``resource`` is ``"memory"`` (a linear operator's linearized-intermediate
    footprint in ``need_bytes``) or ``"device"`` (one compiled-program
    dispatch; ``batch_key`` may name the compiled-shape bucket when the
    caller already knows it — coalescible queued work is then not counted
    as wait).
    """

    resource: str
    need_bytes: int = 0
    batch_key: object = None
    # Device requests only: mesh lanes a sharded dispatch would gang over
    # (1 = the classic single-lane dispatch).  Pricing then quotes every
    # requested lane so admission sees per-lane contention.
    lanes: int = 1

    def __post_init__(self):
        if self.resource not in ("memory", "device"):
            raise ValueError(f"unknown resource {self.resource!r}")
        if self.lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {self.lanes}")


@dataclasses.dataclass
class PressureQuote:
    """The broker's answer to "what would this request get, right now?".

    ``grant_bytes`` is the expected grant (memory requests only — the same
    full-or-policy sizing :meth:`MemoryGovernor.acquire` would apply);
    ``expected_wait_s`` is the expected admission/queue wait *before* the
    resource is held — the term the old ``would_grant`` peek could not see;
    ``queue_depth`` the live number of holders+waiters ahead; ``would_block``
    whether acquisition would park in admission right now.  A broker with
    ``queue_pricing=False`` (the fig12 "queue-blind" baseline) always quotes
    ``expected_wait_s=0`` — grant sizing stays pressure-aware, wait pricing
    is what is being ablated.
    """

    resource: str
    grant_bytes: int = 0
    expected_wait_s: float = 0.0
    queue_depth: int = 0
    would_block: bool = False
    # Device quotes: per-lane expected waits for the request's ``lanes``
    # (lane 0 first; lanes the broker has not yet materialized quote 0.0).
    # ``expected_wait_s`` is then the gang's critical path — the max over
    # these — which for the classic single-lane request is exactly the
    # lane-0 wait.
    lane_waits: Tuple[float, ...] = ()
    # Memory quotes under a tiered governor: the per-tier spill quotas the
    # grant would carry ((t0, t1, t2) bytes; None = unbounded) and the
    # tiers' modeled per-byte service times ((t0, t1, t2) seconds/byte;
    # None = use the cost model's calibrated io_byte_cost).  These are the
    # bandwidth/latency terms the selector folds into tiered-linear spill
    # pricing — an untiered governor quotes both as None.
    tier_quotas: Optional[Tuple[Optional[int], ...]] = None
    tier_byte_s: Optional[Tuple[Optional[float], ...]] = None


class Reservation:
    """A priced decision input that cannot be lost: quote + short-TTL hold.

    ``quote`` is what the selector prices against.  When the broker placed a
    :class:`~repro.core.memory_governor.MemoryHold` behind it (``held`` is
    true), the quoted ``grant_bytes`` are *committed* — converting via
    :meth:`ResourceBroker.memory_lease` gets exactly that size with zero
    admission wait.  A quote-only reservation (``reservations=False``
    ablation, device resources, or a would-block probe where there is
    nothing truthful to hold) carries no hold and keeps the historical race.
    :meth:`cancel` is idempotent and safe after conversion; the hold's TTL
    backstops any path that forgets.
    """

    __slots__ = ("quote", "_hold", "_broker")

    def __init__(self, quote: PressureQuote, hold: Optional[MemoryHold],
                 broker: "ResourceBroker"):
        self.quote = quote
        self._hold = hold
        self._broker = broker

    @property
    def held(self) -> bool:
        return self._hold is not None and self._hold.active

    def cancel(self) -> None:
        if self._hold is not None:
            self._hold.cancel()

    def __enter__(self) -> "Reservation":
        return self

    def __exit__(self, *exc) -> None:
        self.cancel()


class PreemptToken:
    """Cooperative cancellation handle for a floor-degraded linear operator.

    The operator polls :meth:`check` at partition/run boundaries inside its
    spill loops; :meth:`cancel` (called by :meth:`ResourceBroker.
    preempt_degraded`) makes the next poll raise
    :class:`~repro.core.faults.PreemptedError`, which the executor catches
    to requeue the operator on the tensor path.
    """

    __slots__ = ("_flag",)

    def __init__(self):
        self._flag = threading.Event()

    def cancel(self) -> None:
        self._flag.set()

    @property
    def cancelled(self) -> bool:
        return self._flag.is_set()

    def check(self) -> None:
        if self._flag.is_set():
            raise PreemptedError(
                "floor-degraded linear operator preempted mid-spill")


# ---------------------------------------------------------------------------
# Typed leases
# ---------------------------------------------------------------------------

class MemoryLease:
    """A broker-issued hold on the governor's budget.

    Wraps the governor's :class:`~repro.core.memory_governor.MemoryGrant`
    (same sizing, same never-over-budget invariant) and reports its hold
    duration back to the broker on release, which is where the EWMA hold
    time that prices future admission waits comes from.  Release exactly
    once — a second :meth:`release` raises (the grant's double-release
    guard); the context-manager exit is idempotent.
    """

    __slots__ = ("_broker", "_grant", "_t_admit")

    def __init__(self, broker: "ResourceBroker", grant: MemoryGrant):
        self._broker = broker
        self._grant = grant
        self._t_admit = time.perf_counter()

    @property
    def size(self) -> int:
        return self._grant.size

    @property
    def requested(self) -> int:
        return self._grant.requested

    @property
    def wait_s(self) -> float:
        return self._grant.wait_s

    @property
    def degraded(self) -> bool:
        return self._grant.degraded

    @property
    def tier_quotas(self):
        """Per-tier spill quotas when the underlying grant is a
        :class:`~repro.core.memory_governor.TieredGrant`, else None."""
        return getattr(self._grant, "quotas", None)

    @property
    def released(self) -> bool:
        return self._grant.released

    def release(self) -> None:
        self._grant.release()  # raises on double release
        self._broker._record_mem_hold(time.perf_counter() - self._t_admit)

    def __enter__(self) -> "MemoryLease":
        return self

    def __exit__(self, *exc) -> None:
        if not self._grant.released:
            self.release()


class _Ticket:
    __slots__ = ("batch_key", "admitted", "batched", "t_admit")

    def __init__(self, batch_key):
        self.batch_key = batch_key
        self.admitted = False
        self.batched = False
        self.t_admit = 0.0


class DeviceLease:
    """An admitted device dispatch slot.

    ``wait_s`` is the time spent queued before admission (load, not
    execution cost — callers stamp it into ``OpMetrics.queue_wait_s`` so it
    stays out of runtime-profile feedback); ``batched`` marks a lease that
    ran as part of a coalesced same-``batch_key`` group (live: a solo lease
    becomes batched the moment a same-shape arrival joins its round).
    """

    __slots__ = ("_queue", "_ticket", "wait_s", "_released")

    def __init__(self, queue: "DeviceQueue", ticket: Optional[_Ticket],
                 wait_s: float):
        self._queue = queue
        self._ticket = ticket
        self.wait_s = wait_s
        self._released = False

    @property
    def batched(self) -> bool:
        return self._ticket is not None and self._ticket.batched

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        if self._released:
            raise RuntimeError("device lease released twice")
        self._released = True
        self._queue._release(self._ticket)

    def __enter__(self) -> "DeviceLease":
        return self

    def __exit__(self, *exc) -> None:
        if not self._released:
            self.release()


class DeviceGangLease:
    """An admitted all-lane dispatch for a sharded fragment.

    One :class:`DeviceLease` per mesh lane, acquired in FIXED lane order
    (0..N-1) — every gang and every single-lane dispatch (always lane 0)
    acquires along the same total order, so lane acquisition can never
    deadlock — and released together.  ``wait_s`` is the acquisition's
    total blocked time across lanes (on a serial host the gang's waits
    accumulate; ``lane_waits`` keeps the per-lane attribution).
    """

    __slots__ = ("_leases", "wait_s", "lane_waits", "_released")

    def __init__(self, leases: List[DeviceLease]):
        self._leases = leases
        self.lane_waits = tuple(l.wait_s for l in leases)
        self.wait_s = sum(self.lane_waits)
        self._released = False

    @property
    def lanes(self) -> int:
        return len(self._leases)

    @property
    def batched(self) -> bool:
        return any(l.batched for l in self._leases)

    @property
    def released(self) -> bool:
        return self._released

    def release(self) -> None:
        if self._released:
            raise RuntimeError("device gang lease released twice")
        self._released = True
        for lease in reversed(self._leases):
            lease.release()

    def __enter__(self) -> "DeviceGangLease":
        return self

    def __exit__(self, *exc) -> None:
        if not self._released:
            self.release()


# ---------------------------------------------------------------------------
# Device dispatch queue (replaces fused._FifoLock)
# ---------------------------------------------------------------------------

class DeviceQueue:
    """Strict-arrival-order device admission with same-shape coalescing.

    The device is a serially-shared resource: concurrent serving sessions
    funnel compiled-program launches through this queue so a query's device
    phase runs at full speed instead of time-slicing against seven
    neighbors.  Like the ticket lock it replaces, admission order is the
    arrival order — a plain ``threading.Lock`` lets the releasing thread
    barge back in and manufactures exactly the p99 tail the queue exists to
    remove.  Unlike the lock, the queue is *observable* (depth, EWMA wait,
    EWMA service time feed :meth:`ResourceBroker.price`) and **coalesces**:
    when the device frees up, the head ticket is admitted together with
    every queued ticket sharing its ``batch_key`` — one micro-batched
    dispatch group instead of N serial rounds of the same compiled program.
    A same-key arrival while a keyed group is RUNNING joins the in-flight
    round immediately (the members are independent identical compiled
    artifacts, not a barrier) — but only while no other-key ticket is
    waiting, so cross-shape arrival order is never starved.  A ``batch_key``
    of ``None`` is always exclusive.

    ``max_group`` bounds a coalesced group's size (admission-time AND
    in-flight joins) — the classic serving-system batch-size cap: an
    unbounded group time-slices all its members against each other, which
    on an oversubscribed device turns a homogeneous stream's tail into a
    co-runner-count lottery.  ``None`` = unbounded.
    """

    def __init__(self, max_group: Optional[int] = None):
        if max_group is not None and max_group < 1:
            raise ValueError(f"max_group must be >= 1, got {max_group}")
        self.max_group = max_group
        self._cond = threading.Condition()
        self._waiting: List[_Ticket] = []
        self._active: List[_Ticket] = []
        self._active_key = None  # batch key of the running group, if keyed
        # cumulative counters (snapshot via stats())
        self._dispatches = 0
        self._groups = 0
        self._coalesced = 0
        self._bypassed = 0
        self._wait_s_total = 0.0
        self._peak_depth = 0
        self._ewma_wait_s = 0.0
        self._ewma_service_s = 0.0

    @staticmethod
    def serialize() -> bool:
        """``REPRO_DEVICE_SERIALIZE=0`` → leases are granted immediately,
        without serializing (or pricing) device dispatch."""
        return os.environ.get("REPRO_DEVICE_SERIALIZE", "1") != "0"

    # -- lease lifecycle -----------------------------------------------------
    def acquire(self, batch_key=None) -> DeviceLease:
        if not self.serialize():
            with self._cond:
                self._dispatches += 1
                self._bypassed += 1
            return DeviceLease(self, None, 0.0)
        t0 = time.perf_counter()
        ticket = _Ticket(batch_key)
        with self._cond:
            if (batch_key is not None and self._active
                    and self._active_key == batch_key and not self._waiting
                    and (self.max_group is None
                         or len(self._active) < self.max_group)):
                # join the in-flight same-shape round: no missed-round
                # penalty for lockstep serving traffic, and nobody is
                # waiting whose arrival order this could violate
                ticket.admitted = True
                ticket.batched = True
                # a previously-solo round becomes batched when joined:
                # count every member that newly shares a group, not just
                # the joiner, so `coalesced` means "leases that ran in a
                # batched group"
                for t in self._active:
                    if not t.batched:
                        t.batched = True
                        self._coalesced += 1
                self._active.append(ticket)
                self._peak_depth = max(self._peak_depth, len(self._active))
                ticket.t_admit = time.perf_counter()
                self._dispatches += 1
                self._coalesced += 1
                self._ewma_wait_s = _ewma(self._ewma_wait_s, 0.0)
                return DeviceLease(self, ticket, 0.0)
            self._waiting.append(ticket)
            self._peak_depth = max(self._peak_depth,
                                   len(self._waiting) + len(self._active))
            self._admit_locked()
            while not ticket.admitted:
                self._cond.wait()
            wait = time.perf_counter() - t0
            ticket.t_admit = time.perf_counter()
            self._dispatches += 1
            self._wait_s_total += wait
            self._ewma_wait_s = _ewma(self._ewma_wait_s, wait)
        return DeviceLease(self, ticket, wait)

    def _admit_locked(self) -> None:
        """Admit the next dispatch group (lock held): the head of the queue
        plus every queued ticket sharing its batch_key."""
        if self._active or not self._waiting:
            return
        head = self._waiting[0]
        group = [head]
        if head.batch_key is not None:
            for t in self._waiting[1:]:
                if (self.max_group is not None
                        and len(group) >= self.max_group):
                    break
                if t.batch_key == head.batch_key:
                    group.append(t)
        batched = len(group) > 1
        for t in group:
            self._waiting.remove(t)
            t.admitted = True
            t.batched = batched
        self._active = group
        self._active_key = head.batch_key
        self._groups += 1
        if batched:
            self._coalesced += len(group)
        self._cond.notify_all()

    def _release(self, ticket: Optional[_Ticket]) -> None:
        if ticket is None:  # bypass lease (REPRO_DEVICE_SERIALIZE=0)
            return
        with self._cond:
            self._active.remove(ticket)
            self._ewma_service_s = _ewma(
                self._ewma_service_s, time.perf_counter() - ticket.t_admit)
            if not self._active:
                self._active_key = None
                self._admit_locked()

    # -- pricing -------------------------------------------------------------
    def expected_wait(self, batch_key=None):
        """``(expected_wait_s, queue_depth)`` for a new request.

        Expected wait = EWMA service time × the number of *serial dispatch
        rounds* ahead: the running group (if any) plus one round per distinct
        batch_key among the waiters (same-key waiters coalesce into one
        round; exclusive ``None`` tickets are a round each).  A request that
        names a ``batch_key`` already queued would join that round and does
        not count it.  A request with NO key yet (the selector prices before
        the compiled shape is known) optimistically assumes it will coalesce
        with one keyed queued round when any exists — serving workloads
        repeat shapes, and counting a round the request would join as wait
        double-charges the tensor path and flips ``auto`` toward a linear
        choice that then parks in admission (the exact pathology this
        pricing exists to remove).
        """
        with self._cond:
            depth = len(self._waiting) + len(self._active)
            if not self.serialize():
                return 0.0, depth
            if (self._active and self._active_key is not None
                    and not self._waiting
                    and (batch_key is None or batch_key == self._active_key)
                    and (self.max_group is None
                         or len(self._active) < self.max_group)):
                return 0.0, depth  # would join the in-flight round
            rounds = 1 if self._active else 0
            keyed = set()
            for t in self._waiting:
                if t.batch_key is None:
                    rounds += 1
                elif t.batch_key not in keyed:
                    keyed.add(t.batch_key)
                    rounds += 1
            if keyed and (batch_key in keyed or batch_key is None):
                rounds -= 1  # we would (likely) coalesce into that round
            return rounds * self._ewma_service_s, depth

    def stats(self) -> dict:
        with self._cond:
            return {
                "depth": len(self._waiting) + len(self._active),
                "dispatches": self._dispatches,
                "groups": self._groups,
                "coalesced": self._coalesced,
                "bypassed": self._bypassed,
                "wait_s_total": self._wait_s_total,
                "peak_depth": self._peak_depth,
                "ewma_wait_s": self._ewma_wait_s,
                "ewma_service_s": self._ewma_service_s,
            }


def _ewma(old: float, sample: float) -> float:
    return sample if old == 0.0 else old + _EWMA_ALPHA * (sample - old)


# ---------------------------------------------------------------------------
# Broker
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BrokerStats:
    """Snapshot of the broker's queue accounting (see :meth:`ResourceBroker.
    stats`).  Counters are cumulative; EWMA/peak fields are gauges —
    :meth:`since` subtracts a baseline snapshot's counters for per-run
    reporting (the same discipline :class:`~repro.core.server.ServeReport`
    applies to governor stats)."""

    device_dispatches: int = 0
    device_groups: int = 0          # serial admission rounds
    device_coalesced: int = 0       # leases that shared a batched group
    device_bypassed: int = 0        # REPRO_DEVICE_SERIALIZE=0 grants
    device_wait_s_total: float = 0.0
    device_peak_depth: int = 0
    device_ewma_wait_s: float = 0.0
    device_ewma_service_s: float = 0.0
    mem_leases: int = 0
    mem_wait_s_total: float = 0.0
    mem_ewma_wait_s: float = 0.0
    mem_ewma_hold_s: float = 0.0
    quotes: int = 0
    quotes_blocking: int = 0        # memory quotes that would have parked
    reservations: int = 0           # price-and-hold reservations placed
    decide_then_lose: int = 0       # priced-unblocked decisions that then
                                    # waited or got a smaller grant
    preempt_registered: int = 0     # degraded linear ops that ran preemptible
    preemptions: int = 0            # tokens actually cancelled
    switches: int = 0               # guard-initiated mid-query path switches
    # Per-lane DeviceQueue snapshots (lane 0 first — the same queue the
    # device_* aggregate fields above describe; lanes beyond 0 exist only
    # on brokers serving sharded dispatch).  Each entry is the lane's
    # ``DeviceQueue.stats()`` dict: depth, peak_depth, dispatches, groups,
    # coalesced, bypassed, wait_s_total, ewma_wait_s, ewma_service_s.
    lanes: Tuple[Dict[str, float], ...] = ()

    _LANE_COUNTERS = ("dispatches", "groups", "coalesced", "bypassed",
                      "wait_s_total")

    def since(self, base: "BrokerStats") -> "BrokerStats":
        out = dataclasses.replace(self)
        for f in ("device_dispatches", "device_groups", "device_coalesced",
                  "device_bypassed", "device_wait_s_total", "mem_leases",
                  "mem_wait_s_total", "quotes", "quotes_blocking",
                  "reservations", "decide_then_lose", "preempt_registered",
                  "preemptions", "switches"):
            setattr(out, f, getattr(self, f) - getattr(base, f))
        lanes = []
        for i, lane in enumerate(self.lanes):
            lane = dict(lane)
            if i < len(base.lanes):
                for k in self._LANE_COUNTERS:
                    lane[k] = lane[k] - base.lanes[i].get(k, 0)
            lanes.append(lane)
        out.lanes = tuple(lanes)
        return out


class ResourceBroker:
    """Issues typed leases over the serving-scope resources and prices them.

    ``governor=None`` builds a device-only broker (ungoverned sessions);
    ``device_queue=None`` gives the broker its own private queue (the
    per-server configuration) — pass a shared :class:`DeviceQueue` when
    several brokers in one process must serialize against the same physical
    device (the module-level :func:`default_broker` serves exactly that
    role for broker-less executors).  ``queue_pricing=False`` disables the
    wait terms in :meth:`price` — the "queue-blind" ablation fig12 measures
    against — while leases and grant sizing behave identically.
    """

    def __init__(self, governor: Optional[MemoryGovernor] = None,
                 device_queue: Optional[DeviceQueue] = None,
                 queue_pricing: bool = True, reservations: bool = True,
                 reservation_ttl_s: float = 0.25,
                 faults: Optional[FaultInjector] = None):
        self.governor = governor
        self.device = device_queue if device_queue is not None else DeviceQueue()
        # Dispatch lanes for sharded fragments: lane 0 IS self.device (the
        # classic single-device queue — all existing accounting keeps
        # describing it); further lanes are materialized on demand by
        # ensure_lanes() and share lane 0's max_group.
        self._lanes: List[DeviceQueue] = [self.device]
        self.queue_pricing = bool(queue_pricing)
        # price-and-hold on/off: False is the quote-only ablation fig13
        # measures decide-then-lose incidents against
        self.reservations = bool(reservations)
        self.reservation_ttl_s = float(reservation_ttl_s)
        self.faults = faults
        self._lock = threading.Lock()
        self._mem_leases = 0
        self._mem_wait_s_total = 0.0
        self._mem_ewma_wait_s = 0.0
        self._mem_ewma_hold_s = 0.0
        self._quotes = 0
        self._quotes_blocking = 0
        self._reservations = 0
        self._decide_then_lose = 0
        self._preemptible: List[PreemptToken] = []
        self._preempt_registered = 0
        self._preemptions = 0
        self._switches = 0

    # -- leases --------------------------------------------------------------
    def memory_lease(self, need_bytes: int, timeout: Optional[float] = None,
                     reservation: Optional[Reservation] = None) -> MemoryLease:
        """Acquire a memory lease (blocks under admission control exactly as
        :meth:`MemoryGovernor.acquire`); the observed admission wait feeds
        the EWMA that prices future memory quotes.

        ``reservation`` redeems a :meth:`reserve` decision: an active hold
        converts without waiting; a quote-only reservation acquires normally
        and — when its quote promised an unblocked grant the acquisition did
        not honor (smaller size, or it waited) — records a decide-then-lose
        incident, the race the reservation mechanism exists to close."""
        if self.governor is None:
            raise RuntimeError("broker has no memory governor; memory leases "
                               "require a governed session")
        if self.faults is not None:
            self.faults.on_memory_grant()
        hold = reservation._hold if reservation is not None else None
        grant = self.governor.acquire(need_bytes, timeout=timeout, hold=hold)
        with self._lock:
            self._mem_leases += 1
            self._mem_wait_s_total += grant.wait_s
            if grant.wait_s > 0:
                self._mem_ewma_wait_s = _ewma(self._mem_ewma_wait_s,
                                              grant.wait_s)
            if (reservation is not None
                    and reservation.quote.resource == "memory"
                    and not reservation.quote.would_block
                    and (grant.size < reservation.quote.grant_bytes
                         or grant.wait_s > 0)):
                self._decide_then_lose += 1
        return MemoryLease(self, grant)

    @property
    def lanes(self) -> Tuple[DeviceQueue, ...]:
        with self._lock:
            return tuple(self._lanes)

    def ensure_lanes(self, n: int) -> None:
        """Materialize dispatch lanes up to ``n`` (idempotent, never
        shrinks).  New lanes inherit lane 0's ``max_group`` so sharded and
        single-lane dispatch coalesce under the same batching policy."""
        n = int(n)
        with self._lock:
            while len(self._lanes) < n:
                self._lanes.append(DeviceQueue(max_group=self.device.max_group))

    def device_lease(self, batch_key=None, lanes: int = 1):
        """Acquire a device dispatch slot (blocks per the queue discipline;
        coalesces with queued same-``batch_key`` leases).

        ``lanes=N`` (N >= 2) acquires a :class:`DeviceGangLease` over lanes
        0..N-1 in fixed lane order — the all-device admission a sharded
        fragment's ``shard_map`` launch needs.  Lane order is a total
        order shared with single-lane dispatch (always lane 0), so gangs
        can never deadlock against each other or against classic leases.
        """
        if self.faults is not None:
            self.faults.on_device_dispatch()
        if lanes <= 1:
            return self.device.acquire(batch_key)
        self.ensure_lanes(lanes)
        with self._lock:
            queues = list(self._lanes[:lanes])
        # Gangs never coalesce: a sharded launch runs cross-device
        # collectives, and two gangs admitted as one batch_key group would
        # interleave collective launches — on the host platform that is a
        # rendezvous deadlock, not a slowdown.  Strict per-lane exclusion in
        # fixed lane order serializes gangs against each other and against
        # single-lane (lane 0) dispatch.
        held: List[DeviceLease] = []
        try:
            for q in queues:
                held.append(q.acquire(None))
        except BaseException:
            for lease in reversed(held):
                lease.release()
            raise
        return DeviceGangLease(held)

    # -- reservations --------------------------------------------------------
    def reserve(self, request: ResourceRequest) -> Reservation:
        """Price a request and — for memory, when reservations are enabled
        and the grant would not block — commit the quoted bytes behind a
        short-TTL hold.  The returned :class:`Reservation` either converts
        (pass it to :meth:`memory_lease`) or must be cancelled; the TTL
        reaps anything a crashed decision leaks.  Device requests and the
        quote-only ablation return an unheld reservation (plain quote
        semantics)."""
        if (request.resource == "memory" and self.reservations
                and self.governor is not None):
            hold = self.governor.hold(request.need_bytes,
                                      ttl_s=self.reservation_ttl_s)
            if hold is not None:
                with self._lock:
                    self._quotes += 1
                    self._reservations += 1
                quote = PressureQuote("memory", hold.size, 0.0,
                                      0, False)
                return Reservation(quote, hold, self)
        return Reservation(self.price(request), None, self)

    # -- preemption ----------------------------------------------------------
    def register_preemptible(self, token: PreemptToken) -> None:
        """A floor-degraded linear operator announces it can be cancelled
        mid-spill (it polls the token at partition/run boundaries)."""
        with self._lock:
            self._preemptible.append(token)
            self._preempt_registered += 1

    def unregister_preemptible(self, token: PreemptToken) -> None:
        with self._lock:
            try:
                self._preemptible.remove(token)
            except ValueError:
                pass  # already preempted away

    def preempt_degraded(self, max_n: Optional[int] = None) -> int:
        """Cancel up to ``max_n`` registered floor-degraded linear operators
        (all of them when ``None``): each abandons its spill at the next
        poll and its query re-runs the operator on the tensor path.  Returns
        the number preempted.  Called by the serving layer when a
        higher-priority tenant's admission would otherwise block behind a
        spill wall."""
        with self._lock:
            victims = (self._preemptible[:] if max_n is None
                       else self._preemptible[:max_n])
            for t in victims:
                self._preemptible.remove(t)
            self._preemptions += len(victims)
        for t in victims:
            t.cancel()
        return len(victims)

    def note_switch(self) -> None:
        """Count a guard-initiated mid-query path switch (executor calls
        this when a SwitchPoint is taken).  Observability only — switching
        consumes no broker resource; the takeover path acquires its own
        leases through the normal sites."""
        with self._lock:
            self._switches += 1

    def _record_mem_hold(self, hold_s: float) -> None:
        with self._lock:
            self._mem_ewma_hold_s = _ewma(self._mem_ewma_hold_s, hold_s)

    # -- pricing -------------------------------------------------------------
    def price(self, request: ResourceRequest) -> PressureQuote:
        """Non-binding quote: expected grant + expected admission/queue wait
        for ``request`` *right now*.  Cheap (lock-held reads only), never
        blocks, never reserves anything."""
        if request.resource == "device":
            with self._lock:
                self._quotes += 1
                queues = list(self._lanes[:max(1, request.lanes)])
            lane_waits = []
            depth = 0
            for q in queues:
                w, d = q.expected_wait(request.batch_key)
                lane_waits.append(w)
                depth = max(depth, d)
            # lanes not yet materialized are idle: they quote 0 wait
            lane_waits += [0.0] * (max(1, request.lanes) - len(lane_waits))
            if not self.queue_pricing:
                lane_waits = [0.0] * len(lane_waits)
            # the gang's critical path; for lanes=1 exactly the lane-0 wait
            wait = max(lane_waits)
            return PressureQuote("device", 0, wait, depth, depth > 0,
                                 lane_waits=tuple(lane_waits))
        gov = self.governor
        if gov is None:
            return PressureQuote("memory", max(1, int(request.need_bytes)),
                                 0.0, 0, False)
        size, would_block, waiters = gov.admission_probe(request.need_bytes)
        wait = 0.0
        if (self.queue_pricing and gov.full_grant_wait_s > 0
                and size < max(1, int(request.need_bytes))):
            # a degraded-sized grant first waits (up to full_grant_wait_s)
            # for its full size in acquire()'s phase 1 — expected value of
            # a uniformly-arriving release is half the window
            wait = 0.5 * gov.full_grant_wait_s
        with self._lock:
            self._quotes += 1
            if would_block or waiters > 0:
                # Waiters with no would_block means the pool momentarily has
                # free bytes AND standing parked demand: those bytes are
                # ephemeral — a woken waiter grabs them before a request
                # that only decided now gets to acquire — so admission is
                # priced as contended either way.
                self._quotes_blocking += 1
                if self.queue_pricing:
                    # Expected admission wait: the larger of the observed
                    # admission-wait EWMA and the residual of the current
                    # hold (≈ half an EWMA hold) plus one full hold per
                    # waiter already parked ahead.  Hold times come from
                    # lease releases, so the signal exists even when wait
                    # pricing has been steering every request AWAY from
                    # blocking (no fresh wait observations to learn from).
                    wait = max(wait, self._mem_ewma_wait_s,
                               self._mem_ewma_hold_s * (0.5 + waiters))
        tier_quotas = tier_byte_s = None
        tiers = getattr(gov, "tiers", None)
        if tiers is not None:
            # fold the hierarchy's bandwidth/latency terms into the quote:
            # the quotas THIS grant size would carry plus each tier's
            # modeled per-byte service time (T1's includes its configured
            # latency + bandwidth cap)
            q = gov.policy.tier_quotas(size, max(1, int(request.need_bytes)),
                                       tiers)
            tier_quotas = (q.get("t0"), q.get("t1"), q.get("t2"))
            tier_byte_s = tiers.byte_costs()
        return PressureQuote("memory", size, wait, waiters,
                             would_block or waiters > 0,
                             tier_quotas=tier_quotas, tier_byte_s=tier_byte_s)

    # -- observability -------------------------------------------------------
    def stats(self) -> BrokerStats:
        dev = self.device.stats()
        with self._lock:
            lane_queues = list(self._lanes)
        lanes = tuple(q.stats() for q in lane_queues)
        with self._lock:
            return BrokerStats(
                lanes=lanes,
                device_dispatches=dev["dispatches"],
                device_groups=dev["groups"],
                device_coalesced=dev["coalesced"],
                device_bypassed=dev["bypassed"],
                device_wait_s_total=dev["wait_s_total"],
                device_peak_depth=dev["peak_depth"],
                device_ewma_wait_s=dev["ewma_wait_s"],
                device_ewma_service_s=dev["ewma_service_s"],
                mem_leases=self._mem_leases,
                mem_wait_s_total=self._mem_wait_s_total,
                mem_ewma_wait_s=self._mem_ewma_wait_s,
                mem_ewma_hold_s=self._mem_ewma_hold_s,
                quotes=self._quotes,
                quotes_blocking=self._quotes_blocking,
                reservations=self._reservations,
                decide_then_lose=self._decide_then_lose,
                preempt_registered=self._preempt_registered,
                preemptions=self._preemptions,
                switches=self._switches,
            )


# Process-wide broker for executors constructed without one: its device
# queue is THE device queue for every broker-less session in the process,
# preserving the pre-broker invariant that one physical device serializes
# all fused dispatch.  Sessions that own a governor get their own broker
# (and, by default, their own queue) — the per-server configuration.
_DEFAULT_BROKER = ResourceBroker()


def default_broker() -> ResourceBroker:
    return _DEFAULT_BROKER
