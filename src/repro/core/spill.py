"""Temp-file spill manager (PostgreSQL-style work_mem discipline).

Spills are *real* file I/O: the linear execution path writes partition /
sort-run files to a temp directory and reads them back, and every byte is
accounted in a :class:`SpillAccount`.  This is what lets the benchmarks
reproduce the paper's Temp_MB / block counts and the latency impact of the
spill regime, rather than simulating them.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Dict, Optional

import numpy as np

from .metrics import SpillAccount
from .relation import Relation

__all__ = ["SpillManager"]


class SpillManager:
    """Owns a temp directory; writes/reads columnar spill files with accounting."""

    def __init__(self, root: Optional[str] = None):
        self.dir = tempfile.mkdtemp(prefix="repro_spill_", dir=root)
        self._counter = 0

    # -- lifecycle -----------------------------------------------------------
    def cleanup(self) -> None:
        shutil.rmtree(self.dir, ignore_errors=True)

    def __enter__(self) -> "SpillManager":
        return self

    def __exit__(self, *exc) -> None:
        self.cleanup()

    def _next_path(self, tag: str) -> str:
        self._counter += 1
        return os.path.join(self.dir, f"{tag}_{self._counter:06d}")

    # -- columnar spill files --------------------------------------------------
    def write_relation(self, rel: Relation, tag: str, account: SpillAccount) -> str:
        """Write a relation as one .npy file per column; returns the base path.

        A write failure (disk full, permission change mid-run) removes the
        partial spill directory before re-raising: a half-written run left
        behind would later be read back as a *truncated relation* by
        ``read_relation``/``RunReader`` — silently wrong results instead of
        the loud error the failure deserves — and would leak temp space for
        the life of the manager."""
        base = self._next_path(tag)
        os.makedirs(base, exist_ok=True)
        try:
            for name, col in rel.columns.items():
                np.save(os.path.join(base, name + ".npy"), col,
                        allow_pickle=False)
                account.write(col.nbytes)
        except BaseException:
            shutil.rmtree(base, ignore_errors=True)
            raise
        account.files_created += len(rel.columns)
        return base

    def read_relation(self, base: str, account: SpillAccount) -> Relation:
        cols: Dict[str, np.ndarray] = {}
        for fname in sorted(os.listdir(base)):
            if not fname.endswith(".npy"):
                continue
            arr = np.load(os.path.join(base, fname), allow_pickle=False)
            cols[fname[:-4]] = arr
            account.read(arr.nbytes)
        return Relation(cols)

    def open_run_reader(self, base: str, account: SpillAccount) -> "RunReader":
        return RunReader(base, account)

    def delete(self, base: str) -> None:
        shutil.rmtree(base, ignore_errors=True)


class RunReader:
    """Chunked reader over a spilled relation (memory-mapped, counts bytes read)."""

    def __init__(self, base: str, account: SpillAccount):
        self.account = account
        self.cols: Dict[str, np.ndarray] = {}
        for fname in sorted(os.listdir(base)):
            if fname.endswith(".npy"):
                self.cols[fname[:-4]] = np.load(
                    os.path.join(base, fname), mmap_mode="r", allow_pickle=False
                )
        if not self.cols:
            # a spill dir with no column files (zero-column relation, wrong
            # path, or a cleaned-up partial write) must fail loudly here —
            # `next(iter(...))` would raise bare StopIteration, which a
            # generator-based caller would swallow as silent end-of-stream
            raise ValueError(
                f"spill run at {base!r} contains no column files; cannot "
                f"determine row count")
        self.n = len(next(iter(self.cols.values())))
        self.pos = 0

    @property
    def exhausted(self) -> bool:
        return self.pos >= self.n

    def read_rows(self, nrows: int) -> Relation:
        end = min(self.n, self.pos + nrows)
        out = {}
        for name, col in self.cols.items():
            chunk = np.asarray(col[self.pos : end])  # materialize the slice
            out[name] = chunk
            self.account.read(chunk.nbytes)
        self.pos = end
        return Relation(out)
