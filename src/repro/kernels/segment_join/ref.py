"""Pure-jnp oracles for the segment-sum, radix and probe kernels."""
import jax
import jax.numpy as jnp

__all__ = ["segment_sum_ref", "radix_partition_ref", "radix_hash_probe_ref"]


def segment_sum_ref(seg_ids, values, num_segments: int):
    return jax.ops.segment_sum(values, seg_ids, num_segments=num_segments)


def radix_partition_ref(bucket_ids, num_buckets: int):
    """Stable partition-major positions + histogram (argsort oracle)."""
    n = bucket_ids.shape[0]
    b = bucket_ids.astype(jnp.int32)
    order = jnp.argsort(b, stable=True)
    dest = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), b,
                                 num_segments=num_buckets)
    return dest, counts


def radix_hash_probe_ref(bk, pk, domain: int):
    """Scatter-table oracle with the same tie rule as the kernel: the
    per-slot build row is the LARGEST row id landing on that slot.
    Matches the kernel wrapper's empty-side contract (``has_dup`` is
    False when either side is empty — no probe can observe a collision)."""
    nb, np_ = bk.shape[0], pk.shape[0]
    if nb == 0 or np_ == 0:
        cnt_p = jnp.zeros((np_,), jnp.int32)
        return cnt_p, cnt_p - 1, jnp.asarray(False)
    bk = bk.astype(jnp.int32)
    pk = pk.astype(jnp.int32)
    cnt = jnp.zeros((domain + 1,), jnp.int32).at[bk].add(1)
    inv = jnp.zeros((domain + 1,), jnp.int32).at[bk].max(
        jnp.arange(1, nb + 1, dtype=jnp.int32))
    cnt_p = jnp.take(cnt, pk)
    build_row = jnp.take(inv, pk) - 1
    has_dup = jnp.max(cnt[:domain]) > 1 if domain else jnp.asarray(False)
    return cnt_p, build_row, has_dup
