"""Global memory governor: one budget, many concurrent queries.

The paper's tail-latency claim is about memory *under contention*: a single
query with a private ``work_mem`` never reproduces the phase transition,
because nothing ever takes its memory away.  Real servers (PostgreSQL with
hundreds of backends, REMOP's memory-aware operator scheduling) hand every
concurrent operator a slice of one finite pool — and the slice an operator
actually receives, not the configured ``work_mem``, decides whether it stays
in the fast in-memory regime or collapses into the spill regime.

:class:`MemoryGovernor` owns that pool.  Linear-path operators acquire a
:class:`MemoryGrant` before building their linearized intermediate (hash
table / sort runs) and release it when the operator completes:

  * a request is served **in full** when the budget allows — the operator
    runs exactly as it would have with a private ``work_mem``;
  * under pressure the grant is **degraded** down to ``min_grant`` — the
    operator still runs, but with less memory than it wanted, which is what
    pushes it over the spill boundary (the contention-induced tail fig11
    measures);
  * when not even ``min_grant`` is available the request **blocks**
    (admission control) until a running query releases memory — queueing
    delay instead of an out-of-memory failure.

The governor's hard invariant — asserted continuously and exposed for tests
via :attr:`GovernorStats.over_budget_events` / :attr:`GovernorStats.
peak_in_use` — is that the sum of outstanding grants never exceeds the
budget.  Tensor-path operators never acquire grants: device-resident
execution is precisely the path that does not build a host linearized
intermediate, which is why it sidesteps the contention this module models.

:meth:`would_grant` is the *pressure signal* for the decision layer: the
:class:`~repro.core.path_selector.PathSelector` prices the linear path at
the work_mem a request would receive *right now*, so ``auto`` shifts toward
the fused path exactly as memory tightens.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

__all__ = ["MemoryGovernor", "MemoryGrant", "GovernorStats"]

MB = 1 << 20


@dataclasses.dataclass
class GovernorStats:
    """Cumulative counters; snapshot via :meth:`MemoryGovernor.stats`."""

    grants: int = 0            # grants issued
    degraded: int = 0          # grants smaller than their request
    waits: int = 0             # requests that blocked in admission control
    wait_s_total: float = 0.0  # total seconds spent blocked
    peak_in_use: int = 0       # high-water mark of outstanding granted bytes
    over_budget_events: int = 0  # invariant violations (must stay 0)


@dataclasses.dataclass
class MemoryGrant:
    """An outstanding slice of the governor's budget.

    ``size`` is the work_mem the holding operator must live within; ``size <
    requested`` marks a degraded grant.  Use as a context manager (releases
    on exit) or call :meth:`release` exactly once.
    """

    governor: "MemoryGovernor"
    size: int
    requested: int
    wait_s: float = 0.0
    _released: bool = False

    @property
    def degraded(self) -> bool:
        return self.size < self.requested

    def release(self) -> None:
        if not self._released:
            self._released = True
            self.governor._release(self.size)

    def __enter__(self) -> "MemoryGrant":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class MemoryGovernor:
    """Thread-safe admission controller over one total memory budget."""

    def __init__(self, total_bytes: int, min_grant: int = 1 * MB,
                 full_grant_wait_s: float = 0.0):
        if total_bytes <= 0:
            raise ValueError(f"total_bytes must be positive, got {total_bytes}")
        min_grant = max(1, int(min_grant))
        if min_grant > total_bytes:
            raise ValueError(
                f"min_grant ({min_grant} B) exceeds the total budget "
                f"({total_bytes} B); no request could ever be admitted")
        self.total_bytes = int(total_bytes)
        self.min_grant = min_grant
        # how long a request is willing to wait for its FULL size before
        # accepting a degraded grant (0 = degrade immediately; degrading
        # early trades per-query latency for throughput, like PG choosing a
        # smaller hash table over queueing the whole backend)
        self.full_grant_wait_s = float(full_grant_wait_s)
        self._in_use = 0
        self._cond = threading.Condition()
        self._stats = GovernorStats()

    # -- observability -------------------------------------------------------
    @property
    def in_use(self) -> int:
        return self._in_use

    @property
    def available(self) -> int:
        return self.total_bytes - self._in_use

    @property
    def pressure(self) -> float:
        """Fraction of the budget currently granted (0.0 = idle, 1.0 = full)."""
        return self._in_use / self.total_bytes

    def stats(self) -> GovernorStats:
        with self._cond:
            return dataclasses.replace(self._stats)

    def would_grant(self, requested: int) -> int:
        """Non-binding peek: the grant size a request of ``requested`` bytes
        would receive right now.  This is the decision layer's pressure
        signal — cheap, lock-held only for the read, and never blocks.
        Mirrors :meth:`acquire`'s full-or-floor SIZING exactly (a signal
        reporting the in-between leftover would price the linear path
        against memory the grant will never contain); it does NOT model
        admission blocking — when not even the floor is free it still
        returns the floor the waiter will eventually get, and the wait
        itself is unpriced (see ROADMAP: queue-aware admission)."""
        requested = max(1, int(requested))
        with self._cond:
            avail = self.total_bytes - self._in_use
        floor = min(requested, self.min_grant)
        return requested if avail >= requested else floor

    # -- grant lifecycle -----------------------------------------------------
    def acquire(self, requested: int, timeout: Optional[float] = None
                ) -> MemoryGrant:
        """Block until at least ``min(requested, min_grant)`` bytes are free,
        then grant ``min(requested, available)``.

        With ``full_grant_wait_s > 0`` the request first waits up to that
        long for its *full* size before settling for a degraded grant.
        ``timeout`` bounds the total admission wait; expiry raises
        :class:`TimeoutError` (the caller's query fails rather than wedging
        a worker forever — surfaced, never silent).
        """
        requested = max(1, int(requested))
        floor = min(requested, self.min_grant)
        t0 = time.perf_counter()
        deadline = None if timeout is None else t0 + timeout
        with self._cond:
            waited = False
            # phase 1: opportunistic wait for the full request
            if self.full_grant_wait_s > 0:
                full_deadline = t0 + self.full_grant_wait_s
                if deadline is not None:
                    full_deadline = min(full_deadline, deadline)
                while (self.total_bytes - self._in_use < requested
                       and time.perf_counter() < full_deadline):
                    waited = True
                    self._cond.wait(full_deadline - time.perf_counter())
            # phase 2: admission control — never grant below the floor
            while self.total_bytes - self._in_use < floor:
                waited = True
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    self._stats.waits += 1
                    self._stats.wait_s_total += time.perf_counter() - t0
                    raise TimeoutError(
                        f"admission control: {requested} B requested, "
                        f"{self.total_bytes - self._in_use} B available "
                        f"after {timeout:.3f}s")
                self._cond.wait(remaining)
            # full grant if it fits, else the floor — NOT "whatever is
            # left".  A partially-filled grant spills anyway (its deficit
            # is what it is) while stranding the remaining pool, so the
            # queries that COULD have fit (the fast tier) start degrading
            # too and the whole distribution collapses.  Floor-degrading
            # keeps the pool liquid: operators that fit stay fast,
            # operators that don't pay their own spill and nobody else's.
            avail = self.total_bytes - self._in_use
            size = requested if avail >= requested else floor
            self._in_use += size
            if self._in_use > self.total_bytes:  # pragma: no cover
                self._stats.over_budget_events += 1
            self._stats.grants += 1
            if size < requested:
                self._stats.degraded += 1
            if waited:
                self._stats.waits += 1
                self._stats.wait_s_total += time.perf_counter() - t0
            self._stats.peak_in_use = max(self._stats.peak_in_use,
                                          self._in_use)
            wait_s = time.perf_counter() - t0 if waited else 0.0
        return MemoryGrant(self, size, requested, wait_s)

    def _release(self, size: int) -> None:
        with self._cond:
            self._in_use -= size
            if self._in_use < 0:  # pragma: no cover - double release guard
                self._stats.over_budget_events += 1
                self._in_use = 0
            self._cond.notify_all()
