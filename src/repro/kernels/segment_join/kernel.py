"""Pallas TPU kernels: segment-sum, radix partition, hash-table probe.

Three VMEM-tiled kernels back the tensor engine's device joins and
aggregates, all built on the same MXU-friendly idiom — data-dependent
scatter/gather expressed as one-hot masked matmuls, which lowers
identically on TPU hardware and in interpret mode (the CPU fallback):

  * :func:`segment_sum_pallas` — per-tile one-hot matmul into a
    VMEM-resident ``[num_segments]`` accumulator (revisited across all
    tiles); the fused join-aggregate core streams rows exactly once.
  * :func:`radix_rank_pallas` — stable radix partitioning: one
    sequential pass computes each row's rank within its bucket plus the
    per-bucket histogram, using the revisited counts block as the
    running-offset accumulator.  The caller turns ranks into a
    partition-major permutation with one exclusive cumsum.
  * :func:`join_table_build_pallas` / :func:`join_table_probe_pallas` —
    the hash-join core in the packed int32 code domain.  The table
    (per-slot count + build-row id) is tiled over the code domain; both
    kernels run a 2-D grid (row tiles × domain blocks) and *skip* blocks
    a tile cannot touch via ``pl.when`` on the tile's code min/max.
    Radix-ordering the inputs first (via :func:`radix_rank_pallas`)
    clusters each tile's codes into one or two domain blocks, so the
    quadratic grid degenerates to a near-linear sweep — that is the
    radix-join structure, with static shapes throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "segment_sum_pallas",
    "radix_rank_pallas",
    "join_table_build_pallas",
    "join_table_probe_pallas",
]


def _segsum_kernel(seg_ref, val_ref, out_ref, *, num_segments):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    seg = seg_ref[...]                      # [tblk] i32
    val = val_ref[...]                      # [tblk] f32
    onehot = jnp.where(
        seg[:, None] == jax.lax.iota(jnp.int32, num_segments)[None, :],
        1.0, 0.0).astype(val.dtype)         # [tblk, S] built in VMEM
    out_ref[...] += jax.lax.dot_general(
        val[None, :], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)[0]


def segment_sum_pallas(seg_ids, values, num_segments: int, *,
                       tblk: int = 2048, interpret: bool = False):
    """seg_ids [N] i32 (< num_segments), values [N] → sums [num_segments]."""
    n = seg_ids.shape[0]
    tblk = min(tblk, n)
    assert n % tblk == 0, (n, tblk)
    kernel = functools.partial(_segsum_kernel, num_segments=num_segments)
    return pl.pallas_call(
        kernel,
        grid=(n // tblk,),
        in_specs=[
            pl.BlockSpec((tblk,), lambda t: (t,)),
            pl.BlockSpec((tblk,), lambda t: (t,)),
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), values.dtype),
        interpret=interpret,
    )(seg_ids, values)


# ---------------------------------------------------------------------------
# Radix partition: stable bucket ranks + histogram in one sequential pass
# ---------------------------------------------------------------------------

def _radix_rank_kernel(bkt_ref, pos_ref, cnt_ref, *, tblk, num_buckets):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        cnt_ref[...] = jnp.zeros(cnt_ref.shape, cnt_ref.dtype)

    bkt = bkt_ref[...]                                     # [tblk] i32
    onehot = (bkt[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tblk, num_buckets), 1)).astype(jnp.int32)
    # exclusive running count of this tile's rows per bucket → stable
    # within-tile rank; the revisited cnt block carries the running
    # cross-tile base (TPU grids execute sequentially).  Reductions pin
    # dtype=int32: under jax_enable_x64 sum/cumsum otherwise promote to
    # int64 and the int32 output-ref store rejects the value.
    excl = jnp.cumsum(onehot, axis=0, dtype=jnp.int32) - onehot
    rank = jnp.sum(excl * onehot, axis=1, dtype=jnp.int32)  # [tblk]
    base = cnt_ref[...]                                    # [num_buckets]
    pos_ref[...] = jnp.sum(onehot * base[None, :], axis=1,
                           dtype=jnp.int32) + rank
    cnt_ref[...] = base + jnp.sum(onehot, axis=0, dtype=jnp.int32)


def radix_rank_pallas(bucket_ids, num_buckets: int, *, tblk: int = 1024,
                      interpret: bool = False):
    """bucket_ids [N] i32 → ``(rank, counts)``: each row's stable rank
    within its bucket and the per-bucket histogram.  Rows with bucket ids
    outside ``[0, num_buckets)`` contribute nothing (rank 0, uncounted) —
    that is the padding contract."""
    n = bucket_ids.shape[0]
    tblk = min(tblk, n)
    assert n % tblk == 0, (n, tblk)
    kernel = functools.partial(_radix_rank_kernel, tblk=tblk,
                               num_buckets=num_buckets)
    return pl.pallas_call(
        kernel,
        grid=(n // tblk,),
        in_specs=[pl.BlockSpec((tblk,), lambda t: (t,))],
        out_specs=[
            pl.BlockSpec((tblk,), lambda t: (t,)),
            pl.BlockSpec((num_buckets,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((num_buckets,), jnp.int32),
        ],
        interpret=interpret,
    )(bucket_ids)


# ---------------------------------------------------------------------------
# Hash-join table build + probe, tiled over the packed code domain
# ---------------------------------------------------------------------------

def _table_build_kernel(bk_ref, brow_ref, cnt_ref, inv_ref, *, tblk, dblk):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros(cnt_ref.shape, cnt_ref.dtype)
        inv_ref[...] = jnp.zeros(inv_ref.shape, inv_ref.dtype)

    codes = bk_ref[...]                                    # [tblk] i32
    lo = j * dblk
    # radix-ordered inputs cluster each tile into one or two domain
    # blocks; every other (tile, block) cell skips the one-hot entirely
    @pl.when((jnp.max(codes) >= lo) & (jnp.min(codes) < lo + dblk))
    def _accum():
        local = codes - lo
        onehot = (local[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tblk, dblk), 1)).astype(jnp.int32)
        cnt_ref[...] += jnp.sum(onehot, axis=0, dtype=jnp.int32)
        rows = brow_ref[...]                               # [tblk] i32
        inv_ref[...] = jnp.maximum(
            inv_ref[...], jnp.max(onehot * (rows[:, None] + 1), axis=0))


def join_table_build_pallas(bk, brow, domain_pad: int, *, tblk: int = 1024,
                            dblk: int = 512, interpret: bool = False):
    """Build the tiled hash table: ``(cnt, inv)`` over ``[domain_pad]``
    slots, where ``cnt[c]`` counts build rows with code ``c`` and
    ``inv[c]`` holds the largest matching ``brow + 1`` (0 = empty slot).
    Codes ≥ ``domain_pad`` are ignored (padding contract)."""
    n = bk.shape[0]
    tblk = min(tblk, n)
    assert n % tblk == 0 and domain_pad % dblk == 0, (n, tblk, domain_pad)
    kernel = functools.partial(_table_build_kernel, tblk=tblk, dblk=dblk)
    return pl.pallas_call(
        kernel,
        grid=(n // tblk, domain_pad // dblk),
        in_specs=[
            pl.BlockSpec((tblk,), lambda i, j: (i,)),
            pl.BlockSpec((tblk,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((dblk,), lambda i, j: (j,)),
            pl.BlockSpec((dblk,), lambda i, j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((domain_pad,), jnp.int32),
            jax.ShapeDtypeStruct((domain_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(bk, brow)


def _table_probe_kernel(pk_ref, cnt_ref, inv_ref, cntp_ref, invp_ref, *,
                        tblk, dblk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cntp_ref[...] = jnp.zeros(cntp_ref.shape, cntp_ref.dtype)
        invp_ref[...] = jnp.zeros(invp_ref.shape, invp_ref.dtype)

    codes = pk_ref[...]                                    # [tblk] i32
    lo = j * dblk

    @pl.when((jnp.max(codes) >= lo) & (jnp.min(codes) < lo + dblk))
    def _accum():
        local = codes - lo
        onehot = (local[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (tblk, dblk), 1)).astype(jnp.int32)
        # per-probe table gather as a one-hot matmul over the block; a
        # probe's code lives in exactly one block so += never double-adds
        cntp_ref[...] += jax.lax.dot_general(
            onehot, cnt_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        invp_ref[...] += jax.lax.dot_general(
            onehot, inv_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)


def join_table_probe_pallas(pk, cnt, inv, *, tblk: int = 1024,
                            dblk: int = 512, interpret: bool = False):
    """Probe the tiled hash table: per probe row, ``(cnt_p, inv_p)`` =
    (matches in the build side, largest build-row-id + 1 or 0).  Codes ≥
    ``len(cnt)`` gather nothing (padding contract)."""
    n = pk.shape[0]
    domain_pad = cnt.shape[0]
    tblk = min(tblk, n)
    assert n % tblk == 0 and domain_pad % dblk == 0, (n, tblk, domain_pad)
    kernel = functools.partial(_table_probe_kernel, tblk=tblk, dblk=dblk)
    return pl.pallas_call(
        kernel,
        grid=(n // tblk, domain_pad // dblk),
        in_specs=[
            pl.BlockSpec((tblk,), lambda i, j: (i,)),
            pl.BlockSpec((dblk,), lambda i, j: (j,)),
            pl.BlockSpec((dblk,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tblk,), lambda i, j: (i,)),
            pl.BlockSpec((tblk,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(pk, cnt, inv)
