"""Gemma-2 9B [arXiv:2408.00118]: local/global alternating attention,
logit soft-capping, sandwich norms, tied embeddings."""
from .base import ArchConfig, register

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    vocab_size=256_000,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    mlp_type="gated_gelu",
    pattern=(("attn:local", "dense"), ("attn:global", "dense")),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    source="arXiv:2408.00118; hf google/gemma-2-9b",
)

SMOKE = ArchConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=4,
    d_model=64,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=192,
    mlp_type="gated_gelu",
    pattern=(("attn:local", "dense"), ("attn:global", "dense")),
    sliding_window=16,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
)

register(CONFIG, SMOKE)
