"""Flag p50 and scaling regressions in a fresh benchmark run vs the baseline.

    PYTHONPATH=src python -m benchmarks.run --fast --save results/bench_fresh.json
    PYTHONPATH=src python -m benchmarks.compare results/bench_fresh.json

Walks both summaries for numeric leaves whose key mentions ``p50`` (seconds,
lower is better) or ``speedup`` (a scaling ratio, higher is better — fig15's
sharded-over-single throughput gain), prints a ratio table, and exits
non-zero when any shared p50 exceeds the baseline by more than
``--threshold``x or any shared speedup falls below baseline/``--threshold``.
Entries present in only one file are reported but never fail the run (new
benchmarks land; subsets run with ``--only``), so the gate stays usable on
partial sweeps.  CI runs this with ``continue-on-error`` — shared-runner
timing noise should flag, not block.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, Tuple


def _leaves(obj, token: str,
            prefix: Tuple[str, ...] = ()) -> Dict[Tuple[str, ...], float]:
    """Numeric leaves whose FINAL key mentions ``token``."""
    out: Dict[Tuple[str, ...], float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_leaves(v, token, prefix + (str(k),)))
    elif isinstance(obj, (int, float)) and prefix and token in prefix[-1]:
        out[prefix] = float(obj)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="bench_summary.json from the run under test")
    ap.add_argument("--baseline", default="results/bench_summary.json",
                    help="committed reference summary")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="flag fresh/baseline p50 ratios above this, and "
                         "baseline/fresh speedup ratios above this")
    args = ap.parse_args()

    base_doc = json.loads(pathlib.Path(args.baseline).read_text())
    fresh_doc = json.loads(pathlib.Path(args.fresh).read_text())

    regressions = []
    # latency leaves: lower is better, flag fresh/base > threshold
    # scaling leaves: higher is better, flag base/fresh > threshold
    for token, unit, worse in (("p50", "s", lambda b, f: f / b),
                               ("speedup", "x", lambda b, f: b / f)):
        base = _leaves(base_doc, token)
        fresh = _leaves(fresh_doc, token)
        for key in sorted(base):
            name = "/".join(key)
            if key not in fresh:
                print(f"SKIPPED     {name} (not in fresh run)")
                continue
            bv, fv = base[key], fresh[key]
            ratio = worse(bv, fv) if bv > 0 and fv > 0 else float("inf")
            flag = ratio > args.threshold
            status = "REGRESSION" if flag else "ok"
            print(f"{status:11s} {name}: {bv:.4g}{unit} -> {fv:.4g}{unit} "
                  f"({ratio:.2f}x worse)" if flag else
                  f"{status:11s} {name}: {bv:.4g}{unit} -> {fv:.4g}{unit}")
            if flag:
                regressions.append(name)
        for key in sorted(set(fresh) - set(base)):
            print(f"NEW         {'/'.join(key)}: {fresh[key]:.4g}{unit} "
                  f"(no baseline)")

    if regressions:
        print(f"\n{len(regressions)} regression(s) beyond "
              f"{args.threshold:.2f}x: {', '.join(regressions)}")
        return 1
    print(f"\nno p50/speedup regressions beyond {args.threshold:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
