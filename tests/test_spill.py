"""SpillManager failure-mode regressions: zero-column run dirs and partial
writes must fail loudly, never silently."""
import os

import numpy as np
import pytest

from repro.core import Relation, SpillManager
from repro.core.metrics import SpillAccount


def test_run_reader_on_empty_dir_raises_value_error_not_stopiteration():
    """A run dir with no column files used to raise bare StopIteration from
    ``next(iter(...))`` — which a generator-based caller swallows as silent
    end-of-stream (PEP 479's exact failure mode).  It must be a ValueError.
    """
    with SpillManager() as mgr:
        empty = os.path.join(mgr.dir, "empty_run")
        os.makedirs(empty)
        with pytest.raises(ValueError, match="no column files"):
            mgr.open_run_reader(empty, SpillAccount())

        # regression shape: proof it surfaces inside a generator instead of
        # terminating it (the bug this guards against)
        def gen():
            yield mgr.open_run_reader(empty, SpillAccount())

        with pytest.raises(ValueError):
            next(gen())


def test_run_reader_roundtrip_still_works():
    rel = Relation({"a": np.arange(100, dtype=np.int64),
                    "b": np.arange(100, dtype=np.int64) * 3})
    with SpillManager() as mgr:
        acct = SpillAccount()
        path = mgr.write_relation(rel, "run", acct)
        reader = mgr.open_run_reader(path, acct)
        chunks = []
        while not reader.exhausted:
            chunks.append(reader.read_rows(33))
        out = chunks[0]
        for c in chunks[1:]:
            out = out.concat(c)
        assert out.equals(rel)


def test_write_relation_failure_removes_partial_dir():
    """A mid-write failure must not leave a partial spill dir behind: it
    would read back as a truncated relation (silently wrong results) and
    leak temp space for the life of the manager."""
    rel = Relation({"a": np.arange(64, dtype=np.int64),
                    "b": np.arange(64, dtype=np.int64),
                    "c": np.arange(64, dtype=np.int64)})
    with SpillManager() as mgr:
        acct = SpillAccount()
        real_save = np.save
        calls = {"n": 0}

        def failing_save(path, arr, **kw):
            calls["n"] += 1
            if calls["n"] == 2:  # first column lands, second write dies
                raise OSError("disk full")
            return real_save(path, arr, **kw)

        np.save = failing_save
        try:
            with pytest.raises(OSError, match="disk full"):
                mgr.write_relation(rel, "jb", acct)
        finally:
            np.save = real_save
        # the partial dir is gone, and the manager dir holds no leftovers
        assert os.listdir(mgr.dir) == []
        # files_created counts only COMPLETED relations
        assert acct.files_created == 0
        # ...and the manager still works afterwards
        path = mgr.write_relation(rel, "jb", acct)
        assert mgr.read_relation(path, SpillAccount()).equals(rel)
