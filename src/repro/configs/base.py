"""Architecture configuration schema + registry.

Each assigned architecture gets one module in ``repro/configs/<id>.py``
exporting ``CONFIG`` (the exact assigned numbers) and ``SMOKE`` (a reduced
same-family variant for CPU smoke tests).  Layer structure is described by a
*period pattern*: a tuple of ``(mixer, ffn)`` descriptors that tiles the depth
(plus optional non-tiled prefix layers), which is what lets the model
assembler ``lax.scan`` over homogeneous periods — the key to bounded HLO size
and compile time at 512 devices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

LayerSpec = Tuple[str, str]  # (mixer, ffn): mixer ∈ attn:global|attn:local|mamba
                             #               ffn   ∈ dense|moe|none

_REGISTRY: Dict[str, "ArchConfig"] = {}
_SMOKE: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    mlp_type: str = "gated_silu"
    # layer structure
    pattern: Tuple[LayerSpec, ...] = (("attn:global", "dense"),)
    prefix: Tuple[LayerSpec, ...] = ()
    # attention
    attn_type: str = "gqa"           # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    sliding_window: int = 0
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    use_post_norm: bool = False
    embed_scale: bool = False
    # MLA
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True
    router_aux_weight: float = 0.01
    # SSM
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    # structure / modality
    is_encoder: bool = False
    causal: bool = True
    modality: str = "text"           # text | audio_stub | vision_stub
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # vocab padding for sharded execution (pjit arguments must divide the
    # mesh axes; the launcher sets 256 = lcm of both axes, tests keep 1)
    vocab_pad_multiple: int = 1
    # provenance
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def num_periods(self) -> int:
        body = self.num_layers - len(self.prefix)
        assert body % self.period == 0, (self.name, body, self.period)
        return body // self.period

    @property
    def uses_attention(self) -> bool:
        specs = self.pattern + self.prefix
        return any(m.startswith("attn") for m, _ in specs)

    @property
    def uses_mamba(self) -> bool:
        specs = self.pattern + self.prefix
        return any(m == "mamba" for m, _ in specs)

    @property
    def uses_moe(self) -> bool:
        specs = self.pattern + self.prefix
        return any(f == "moe" for _, f in specs)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: decode state per token is O(1) or the
        arch is hybrid (bounded attention share)."""
        return self.uses_mamba

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings and not self.is_encoder:
            total += d * self.vocab_size
        if self.is_encoder:
            total += d * self.vocab_size  # classifier head
        def attn_params() -> int:
            if self.attn_type == "mla":
                qd = self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                return (d * qd + d * (self.kv_lora_rank + self.qk_rope_dim)
                        + self.kv_lora_rank * self.num_heads
                        * (self.qk_nope_dim + self.v_head_dim)
                        + self.num_heads * self.v_head_dim * d)
            hd, khd = self.num_heads * self.head_dim, self.num_kv_heads * self.head_dim
            return d * hd + 2 * d * khd + hd * d
        def mamba_params() -> int:
            d_inner = self.ssm_expand * d
            gn = self.ssm_groups * self.ssm_state
            return (2 * d * d_inner + 2 * d * gn
                    + d * (d_inner // self.ssm_headdim) + d_inner * d)
        def ffn_params(kind: str) -> int:
            if kind == "dense":
                mult = 3 if self.mlp_type.startswith("gated") else 2
                return mult * d * self.d_ff
            if kind == "moe":
                e = 3 * d * self.moe_d_ff
                return (self.num_experts * e + self.num_shared_experts * e
                        + d * self.num_experts)
            return 0
        for mixer, ffn in list(self.prefix) + list(self.pattern) * self.num_periods:
            total += attn_params() if mixer.startswith("attn") else mamba_params()
            total += ffn_params(ffn)
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only k experts count)."""
        if not self.uses_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        specs = list(self.prefix) + list(self.pattern) * self.num_periods
        n_moe = sum(1 for _, f in specs if f == "moe")
        e = 3 * d * self.moe_d_ff
        inactive = n_moe * (self.num_experts - self.experts_per_token) * e
        return full - inactive


def register(cfg: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ArchConfig:
    _ensure_loaded()
    return _SMOKE[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY.keys())


def _ensure_loaded():
    # import side-effect registration of all assigned architectures
    from . import (  # noqa: F401
        deepseek_v2_lite_16b, phi35_moe_42b, jamba15_large_398b, mamba2_370m,
        yi_9b, starcoder2_15b, yi_34b, gemma2_9b, hubert_xlarge, qwen2_vl_7b,
    )
