"""Int8 KV-cache quantization: accuracy + roundtrip + decode parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import decode_attention
from repro.serving.kv_quant import (QuantizedKV, append_quantized,
                                    decode_attention_quantized, dequantize_kv,
                                    quantize_kv)


def _kv(seed=0, B=2, S=128, KH=4, D=32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    k = jax.random.normal(ks[0], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    return k, v


def test_quantize_roundtrip_error_bounded():
    k, _ = _kv()
    deq = dequantize_kv(quantize_kv(k), jnp.float32)
    err = jnp.abs(deq - k)
    # symmetric int8: |err| <= scale/2 = amax/254 per (pos, head)
    amax = jnp.max(jnp.abs(k), axis=-1, keepdims=True)
    assert bool(jnp.all(err <= amax / 254 + 1e-6))


def test_outlier_positions_stay_local():
    """Per-(pos, head) scales: an outlier position cannot change the
    quantization of any other position (unlike per-tensor scaling)."""
    k, _ = _kv()
    k_out = k.at[:, 7].multiply(1000.0)
    deq_base = dequantize_kv(quantize_kv(k), jnp.float32)
    deq_out = dequantize_kv(quantize_kv(k_out), jnp.float32)
    np.testing.assert_array_equal(np.asarray(deq_out[:, 8:]),
                                  np.asarray(deq_base[:, 8:]))
    # contrast: per-TENSOR scaling would blow other positions' error up 1000×
    scale_pt = jnp.max(jnp.abs(k_out)) / 127.0
    deq_pt = jnp.round(k_out / scale_pt).clip(-127, 127) * scale_pt
    err_pt = float(jnp.abs(deq_pt[:, 8:] - k_out[:, 8:]).mean())
    err_local = float(jnp.abs(deq_out[:, 8:] - k_out[:, 8:]).mean())
    assert err_local < err_pt / 100


def test_decode_attention_parity():
    B, S, H, KH, D = 2, 128, 8, 4, 32
    k, v = _kv(B=B, S=S, KH=KH, D=D)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, 1, H, D), jnp.float32)
    ref = decode_attention(q, k, v, jnp.asarray(S - 1))
    got = decode_attention_quantized(q, quantize_kv(k), quantize_kv(v),
                                     jnp.asarray(S - 1))
    a = np.asarray(ref).ravel()
    b = np.asarray(got).ravel()
    cos = float(np.dot(a, b) / (np.linalg.norm(a) * np.linalg.norm(b)))
    assert cos > 0.999, cos
    np.testing.assert_allclose(b, a, rtol=0.05, atol=0.02)


def test_append_matches_full_quantization():
    k, _ = _kv(S=16)
    cache = QuantizedKV(jnp.zeros_like(quantize_kv(k).q),
                        jnp.zeros_like(quantize_kv(k).scale))
    for t in range(16):
        cache = append_quantized(cache, k[:, t:t + 1], t)
    full = quantize_kv(k)
    np.testing.assert_array_equal(np.asarray(cache.q), np.asarray(full.q))
    np.testing.assert_allclose(np.asarray(cache.scale),
                               np.asarray(full.scale), rtol=1e-6)
