"""Pallas TPU kernel: flash attention (online-softmax, VMEM-tiled).

The pure-JAX ``chunked_attention`` scan is the lowering-safe fallback; this
kernel is the TPU-native hot path: one (batch, head, q-block) output tile
stays resident in VMEM while the kv-block grid axis streams K/V through —
scores and probabilities never touch HBM.  GQA is handled in the BlockSpec
index map (kv head = q head // group), so grouped K/V are never repeated in
memory.

Accumulation across kv steps uses the revisiting-output pattern (same as
moe_dispatch): (acc, m, l) are kernel outputs indexed by (b, h, qi) only;
the final ``acc / l`` division happens in the jnp epilogue (ops.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, cap, q_blk, kv_blk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
        m_ref[...] = jnp.full(m_ref.shape, _NEG_INF, m_ref.dtype)
        l_ref[...] = jnp.zeros(l_ref.shape, l_ref.dtype)

    q = q_ref[...][0, 0]          # [q_blk, D]
    k = k_ref[...][0, 0]          # [kv_blk, D]
    v = v_ref[...][0, 0]          # [kv_blk, Dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    pos_q = qi * q_blk + jax.lax.iota(jnp.int32, q_blk)
    pos_k = ki * kv_blk + jax.lax.iota(jnp.int32, kv_blk)
    mask = jnp.ones((q_blk, kv_blk), dtype=jnp.bool_)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        mask &= (pos_q[:, None] - pos_k[None, :]) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...][0, 0]     # [q_blk]
    l_prev = l_ref[...][0, 0]
    acc_prev = acc_ref[...][0, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new[None, None]
    l_ref[...] = l_new[None, None]
    acc_ref[...] = acc_new[None, None]


def flash_attention_pallas(q, k, v, *, causal=True, window=None, cap=None,
                           scale=None, q_blk: int = 256, kv_blk: int = 256,
                           interpret: bool = False):
    """q [B,H,Sq,D]; k/v [B,KH,Sk,D(v)].  Returns [B,H,Sq,Dv] (f32)."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Sk)
    assert Sq % q_blk == 0 and Sk % kv_blk == 0, (Sq, q_blk, Sk, kv_blk)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    grid = (B, H, Sq // q_blk, Sk // kv_blk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, cap=cap,
        q_blk=q_blk, kv_blk=kv_blk)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            # GQA in the index map: kv head = q head // G (no repeat in memory)
            pl.BlockSpec((1, 1, kv_blk, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_blk, Dv),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q_blk, Dv), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, q_blk), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, q_blk), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, Dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return acc, m, l
