"""Roofline analysis from compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — for a
scan-over-depth program that under-counts flops/bytes by the layer count, so
we walk the optimized HLO ourselves:

  * computations are parsed into op lists with resolved operand/result shapes;
  * ``while`` ops carry ``"known_trip_count":{"n":...}`` in backend_config
    (JAX scans always do) — body & condition totals are scaled by it;
  * dot flops = 2 · numel(result) · Π contracting-dims(lhs);
  * bytes = Σ (operand + result bytes) per op at fusion granularity (ops
    *inside* fused computations are skipped for bytes — the fusion call site
    already accounts its true HBM traffic — but their dots still count flops);
  * collective bytes = result bytes per all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute, trip-scaled.

Everything is per-device (the module is the SPMD-partitioned program), so
roofline terms divide by per-chip peaks directly:

  compute    = flops / PEAK_FLOPS_BF16
  memory     = bytes / HBM_BW
  collective = collective_bytes / ICI_LINK_BW
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from .hw import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

__all__ = ["analyze_hlo", "roofline_terms", "collective_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s4|s8|s16|s32|s64|u4|u8|u16|u32|u64|c64|c128|f8e4m3fn|f8e5m2)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(r"^\s+(?:ROOT )?%?([\w.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_ARGS_RE = re.compile(r"\(((?:[^()]|\([^()]*\))*)\)")  # first (...) group
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


class _Comp:
    def __init__(self, name):
        self.name = name
        self.sym: Dict[str, list] = {}        # op/param name -> result shapes
        self.flops = 0.0
        self.bytes = 0.0
        self.coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
        self.edges: List[Tuple[str, float]] = []  # (callee, multiplier)
        self.transcendentals = 0.0


def _parse(hlo: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    for raw in hlo.splitlines():
        if not raw.strip():
            continue
        if not raw[0].isspace():
            # header params may contain nested tuple types — split the header
            # at the LAST "->" to isolate "name (params)"
            m = _COMP_HDR_RE.match(raw.strip())
            if m and "{" in raw:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if raw.startswith("ENTRY"):
                    entry = cur.name
                # parameters: "p.1: f32[2,3], p.2: (s32[], bf16[4])"
                for pm in re.finditer(
                        r"([\w.\-]+):\s*(\((?:[^()]|\([^()]*\))*\)|[\w\[\],]+)",
                        m.group(2)):
                    cur.sym[pm.group(1)] = _shape_list(pm.group(2))
            continue
        if cur is None:
            continue
        m = _OP_RE.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type = everything before the op token; op token = first
        # bare word after the type.  Tuple types may contain /*index=N*/
        # comments, so match balanced parens rather than excluding '='.
        op_m = re.match(
            r"((?:\((?:[^()]|\([^()]*\))*\))|(?:[\w\[\],{}\.:]+))\s+([\w\-]+)",
            rhs)
        if not op_m:
            continue
        result_type, op = op_m.group(1), op_m.group(2)
        shapes = _shape_list(result_type)
        cur.sym[name] = shapes
        out_bytes = _bytes_of(shapes)

        # operand bytes (resolve names; inline types if present)
        args_m = _ARGS_RE.search(rhs[op_m.end():])
        arg_bytes = 0
        max_arg = 0
        lhs_name = None
        if args_m:
            inner = args_m.group(1)
            inline = _shape_list(inner)
            names = _OPERAND_RE.findall(inner)
            if inline:
                per = [_bytes_of([s]) for s in inline]
            else:
                per = [_bytes_of(cur.sym.get(nm, [])) for nm in names]
            arg_bytes = sum(per)
            max_arg = max(per) if per else 0
            if names:
                lhs_name = names[0]

        # byte accounting: only ops that actually move data.  Loop plumbing
        # (tuple/GTE re-stating the whole carried scan state every iteration),
        # views and control ops would inflate traffic by orders of magnitude.
        skip_comp = (cur.name.startswith("fused_computation")
                     or cur.name.startswith("wrapped_"))
        plumbing = op in ("tuple", "get-tuple-element", "parameter", "constant",
                          "bitcast", "while", "call", "conditional",
                          "after-all", "iota", "get-dimension-size")
        if not skip_comp and not plumbing:
            # scan machinery aliases the big carried array: a DUS touches only
            # the update slice; a DS reads only the slice it produces
            if op == "dynamic-update-slice" or "dynamic-update-slice" in name:
                cur.bytes += max(2 * (arg_bytes - max_arg), 0)
            elif op == "dynamic-slice" or "dynamic-slice" in name:
                cur.bytes += 2 * out_bytes
            else:
                cur.bytes += out_bytes + arg_bytes

        if op == "dot":
            cdims = _LHS_CDIMS_RE.search(rhs)
            contract = 1
            if cdims and lhs_name:
                lhs_shapes = cur.sym.get(lhs_name) or (
                    _shape_list(args_m.group(1))[:1] if args_m else [])
                if lhs_shapes:
                    _, lshape = lhs_shapes[0]
                    for di in cdims.group(1).split(","):
                        if di and int(di) < len(lshape):
                            contract *= lshape[int(di)]
            out_n = sum(_prod(sh[1]) for sh in shapes) if shapes else 0
            cur.flops += 2.0 * out_n * contract
        elif op in ("exponential", "tanh", "log", "rsqrt", "power"):
            cur.transcendentals += _prod(shapes[0][1]) if shapes else 0

        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                cur.coll[c] += out_bytes
                break

        if op == "while":
            wm = _WHILE_RE.search(rhs)
            tm = _TRIP_RE.search(rhs)
            trip = float(tm.group(1)) if tm else 1.0
            if wm:
                cur.edges.append((wm.group(2), trip))
                cur.edges.append((wm.group(1), trip))
        elif op == "fusion":
            cm = _CALLS_RE.search(rhs)
            if cm:
                cur.edges.append((cm.group(1), 1.0))
        elif op in ("call", "custom-call", "reduce", "reduce-window", "sort",
                    "scatter", "select-and-scatter", "map", "conditional"):
            for pat in (_TO_APPLY_RE, _CALLS_RE):
                cm = pat.search(rhs)
                if cm:
                    cur.edges.append((cm.group(1), 1.0))
    return comps, entry


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps, entry = _parse(hlo)
    if entry is None:
        return {"error": 1.0}
    memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, depth=0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
                    **{c: 0.0 for c in _COLLECTIVES}}
        memo[name] = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
                      **{c: 0.0 for c in _COLLECTIVES}}  # cycle guard
        acc = {"flops": comp.flops, "bytes": comp.bytes,
               "transcendentals": comp.transcendentals,
               **{c: comp.coll[c] for c in _COLLECTIVES}}
        for callee, mult in comp.edges:
            sub = total(callee, depth + 1)
            for k in acc:
                acc[k] += mult * sub[k]
        memo[name] = acc
        return acc

    result = total(entry)
    result["collective_bytes"] = sum(result[c] for c in _COLLECTIVES)
    return result


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Back-compat helper: per-kind collective bytes, trip-scaled."""
    r = analyze_hlo(hlo_text)
    return {k: r.get(k, 0.0) for k in _COLLECTIVES}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    t_compute = flops_per_device / PEAK_FLOPS_BF16
    t_memory = bytes_per_device / HBM_BW
    t_coll = coll_bytes_per_device / ICI_LINK_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    total = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": t_compute / total if total > 0 else 0.0,
    }
