"""Concurrent serving: QueryServer closed loop, bit-for-bit parity with
serial execution, the governor invariant under real query traffic, and the
pressure-aware path selector."""
import threading

import numpy as np
import pytest

from repro.core import (MemoryGovernor, PathSelector, QueryServer, Relation,
                        RuntimeProfile, Session, col)

MB = 1 << 20


def star_tables(n_orders=60_000, n_users=2_000, n_parts=500, seed=7):
    rng = np.random.default_rng(seed)
    orders = Relation({
        "uid": rng.integers(0, n_users, n_orders).astype(np.int64),
        "pid": rng.integers(0, n_parts, n_orders).astype(np.int64),
        "w": rng.integers(-50, 50, n_orders).astype(np.int64),
    })
    users = Relation({
        "uid": np.arange(n_users, dtype=np.int64),
        "region": rng.integers(0, 4, n_users).astype(np.int64),
    })
    parts = Relation({
        "pid": np.arange(n_parts, dtype=np.int64),
        "price": rng.integers(1, 9, n_parts).astype(np.int64),
    })
    return {"orders": orders, "users": users, "parts": parts}


def mixed_workload(sess: Session):
    """Mixed star-join stream: scalar roots, a relation root, a group-by,
    and a packed multi-key join — every fragment shape the planner chains."""
    return [
        (sess.table("orders").join("users", on="uid")
         .filter((col("w") > 0) & (col("b_region") <= 2))
         .sort("uid").aggregate("w", "sum")),
        (sess.table("orders").join("users", on="uid")
         .join("parts", on="pid").filter(col("w") != 0)
         .aggregate("w", "count")),
        (sess.table("orders").join("parts", on="pid")
         .filter(col("b_price") >= 3).sort("pid", "w")
         .select("pid", "w", "b_price")),
        (sess.table("orders").join("users", on="uid")
         .group_by("b_region", {"w": "sum"})),
        (sess.table("orders").join("orders", on=["uid", "pid"])
         .aggregate("w", "count")),
    ]


@pytest.fixture(scope="module")
def serial_results():
    """Ground truth: the same workload through an ungoverned, single-thread
    session."""
    sess = Session(work_mem=64 * MB, policy="auto")
    for name, rel in star_tables().items():
        sess.register(name, rel)
    out = []
    for q in mixed_workload(sess):
        res = q.collect()
        out.append((res.scalar, res.relation))
    return out


def _assert_matches_serial(record, serial_results):
    expect_scalar, expect_rel = serial_results[record.workload_idx]
    if expect_scalar is not None:
        assert record.scalar == expect_scalar  # int64 sums: exact equality
    else:
        assert record.relation is not None
        assert expect_rel.sort_canonical().equals(
            record.relation.sort_canonical())


@pytest.mark.parametrize("policy", ["auto", "linear", "tensor"])
def test_concurrent_results_match_serial_bit_for_bit(policy, serial_results):
    """N workers x one shared Session x a constrained governor: every
    concurrently-served result equals the serial ground truth exactly.
    Concurrency and memory pressure may change PATHS (that is the point);
    they must never change ANSWERS."""
    server = QueryServer(star_tables(), total_mem=8 * MB, work_mem=4 * MB,
                         policy=policy, min_grant=1 * MB)
    workload = mixed_workload(server.session)
    report = server.serve(workload, concurrency=6, queries_per_worker=5,
                          warmup=1)
    assert len(report.queries) == 30
    for record in report.queries:
        _assert_matches_serial(record, serial_results)
    gov = report.governor
    assert gov.over_budget_events == 0
    assert gov.peak_in_use <= server.governor.total_bytes
    if policy == "linear":
        # linear traffic under an 8 MB budget must actually have contended
        assert gov.grants > 0
        assert report.queries and any(
            q.grant_bytes for q in report.queries)


def test_governor_never_overgrants_under_load(serial_results):
    """The budget invariant asserted through real query traffic plus the
    per-operator grant accounting (SpillAccount/OpMetrics peaks): every
    linear operator ran under a grant no larger than work_mem, spills only
    ever happened on degraded grants, and the governor's high-water mark
    stayed inside the budget."""
    work_mem = 4 * MB
    server = QueryServer(star_tables(), total_mem=6 * MB, work_mem=work_mem,
                         policy="linear", min_grant=1 * MB)
    workload = mixed_workload(server.session)
    results = []
    errors = []

    def worker():
        try:
            for q in workload:
                results.append(server.submit(q))
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(5)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120)
    assert not errors
    stats = server.governor.stats()
    assert stats.over_budget_events == 0
    assert stats.peak_in_use <= 6 * MB
    assert server.governor.in_use == 0  # every grant released
    spilled_ungoverned = 0
    for res in results:
        for m in res.metrics:
            if m.grant_bytes:
                assert m.grant_bytes <= work_mem
            if m.spill.bytes_written and not m.grant_bytes:
                spilled_ungoverned += 1
    assert spilled_ungoverned == 0  # no spill outside a governed grant


def test_shared_session_concurrent_threads_direct():
    """The satellite contract without the server wrapper: raw threads over
    one Session (shared compile cache, device cache, profile) stay
    bit-for-bit with serial."""
    sess = Session(work_mem=32 * MB, policy="auto")
    for name, rel in star_tables(n_orders=30_000).items():
        sess.register(name, rel)
    workload = mixed_workload(sess)
    expected = [(q.collect().scalar, q.collect().relation) for q in workload]
    failures = []

    def worker(wid: int):
        try:
            for i in range(len(workload)):
                q = workload[(wid + i) % len(workload)]
                res = q.collect()
                exp_s, exp_r = expected[(wid + i) % len(workload)]
                if exp_s is not None:
                    assert res.scalar == exp_s
                else:
                    assert exp_r.sort_canonical().equals(
                        res.relation.sort_canonical())
        except BaseException as e:
            failures.append(e)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120)
    assert not failures


def test_selector_pressure_shifts_auto_to_tensor():
    """The decision-time pressure signal: the SAME fragment on the SAME
    selector flips from linear to tensor when the would-be grant (passed as
    the work_mem override) collapses — no recalibration, no feedback."""
    from repro.core import FusedSpec

    rng = np.random.default_rng(3)
    n = 50_000
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
    spec = FusedSpec(join_key="k", filter_fn=None, sort_keys=("k",),
                     agg=("b_v", "sum"))
    sel = PathSelector(64 * MB, profile=RuntimeProfile())
    relaxed = sel.choose_fragment(spec, build, probe)
    squeezed = sel.choose_fragment(spec, build, probe, work_mem=256 * 1024)
    assert squeezed.path == "tensor"
    assert squeezed.predicted_spill_bytes > 0
    # the un-squeezed decision predicted no spill at 64 MB (whichever path
    # won on speed): pressure is what manufactured the spill term
    assert relaxed.predicted_spill_bytes == 0


def test_executor_effective_work_mem_tracks_governor():
    gov = MemoryGovernor(8 * MB, min_grant=1 * MB)
    sess = Session(work_mem=16 * MB, policy="auto", governor=gov)
    assert sess.executor._effective_work_mem() == 8 * MB  # budget-capped
    hold = gov.acquire(7 * MB)
    # full-or-floor: the 1 MB leftover cannot serve the 8 MB probe
    assert sess.executor._effective_work_mem() == 1 * MB
    hold.release()
    ungoverned = Session(work_mem=16 * MB, policy="auto")
    assert ungoverned.executor._effective_work_mem() == 16 * MB


def test_server_rejects_conflicting_construction():
    sess = Session(work_mem=4 * MB)
    with pytest.raises(ValueError):
        QueryServer({}, total_mem=8 * MB, session=sess)
    with pytest.raises(ValueError):
        QueryServer({"t": Relation({"a": np.arange(3)})},
                    total_mem=None).serve([], concurrency=1,
                                          queries_per_worker=1)


def test_device_queue_dispatch_is_fair():
    """The broker's device queue must preserve strict arrival order across
    distinct batch keys (the `_FifoLock` contract it replaced): a plain
    lock lets the releasing thread barge back in, which starves queries
    and manufactures a fake p99 tail.  Distinct keys never coalesce, so
    admission is one serial round per waiter, in arrival order."""
    from repro.core import DeviceQueue

    queue = DeviceQueue()
    order = []

    def worker(k: int):
        with queue.acquire(batch_key=("shape", k)) as lease:
            assert not lease.batched
            order.append(k)

    hold = queue.acquire(batch_key=("shape", "head"))
    threads = []
    import time
    for k in range(6):
        th = threading.Thread(target=worker, args=(k,))
        th.start()
        time.sleep(0.02)  # deterministic arrival order
        threads.append(th)
    hold.release()
    for th in threads:
        th.join(timeout=10)
    assert order == list(range(6))
    stats = queue.stats()
    assert stats.get("coalesced") == 0  # distinct shapes: no micro-batching
