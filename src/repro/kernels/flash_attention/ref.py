"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal=True, window=None, cap=None, scale=None):
    """q [B,H,Sq,D]; k/v [B,KH,Sk,D(v)] → [B,H,Sq,Dv] f32 (dense softmax)."""
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    pos_q = jnp.arange(Sq)
    pos_k = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        mask &= (pos_q[:, None] - pos_k[None, :]) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
