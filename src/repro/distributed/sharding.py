"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

2-D sharding on the ("data", "model") mesh axes:
  * "model"  — tensor/expert parallelism: attention head products, FFN hidden,
    expert axis, vocab.
  * "data"   — FSDP: the non-TP dimension of every large matrix is sharded
    over the data axis and all-gathered at use (GSPMD inserts the gathers).
  * "pod"    — pure data parallelism across pods: batch is additionally
    sharded over "pod"; parameters stay replicated across pods (FSDP gathers
    ride the fast intra-pod ICI, gradient all-reduce crosses pods once).

Rules are name-based over the flattened parameter path, right-aligned to the
leaf rank so the same table covers stacked (scan) and unstacked (prefix)
layers.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_specs", "batch_specs", "cache_specs", "tree_shardings",
    "DATA_AXIS", "MODEL_AXIS", "POD_AXIS", "dp_axes",
    "PART_AXIS", "relational_mesh", "partition_sharding",
    "available_partitions",
]

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"

# ---------------------------------------------------------------------------
# Relational partition mesh (sharded fused fragments)
# ---------------------------------------------------------------------------

PART_AXIS = "part"

_MESH_CACHE: dict = {}


def available_partitions() -> int:
    """Device lanes a sharded fragment can fan out over — the local device
    count (on CPU, the forced host-platform device count; see
    ``tests/conftest.py`` / the CI ``XLA_FLAGS`` env var)."""
    return jax.device_count()


def relational_mesh(num_parts: int) -> Mesh:
    """1-D mesh over the first ``num_parts`` local devices with the single
    named axis ``"part"`` — one hash/radix partition of a fused relational
    fragment per device.  Meshes are cached per partition count so the
    partitioned-column cache and the compiled ``shard_map`` programs agree
    on device placement (a mismatched mesh object would make XLA re-shard
    every input per call)."""
    num_parts = int(num_parts)
    if num_parts < 1:
        raise ValueError(f"num_parts must be >= 1, got {num_parts}")
    devs = jax.devices()
    if num_parts > len(devs):
        raise ValueError(
            f"num_parts={num_parts} exceeds the {len(devs)} local devices; "
            f"force a larger host mesh via XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N before importing jax")
    mesh = _MESH_CACHE.get(num_parts)
    if mesh is None:
        mesh = Mesh(np.array(devs[:num_parts]), (PART_AXIS,))
        _MESH_CACHE[num_parts] = mesh
    return mesh


def partition_sharding(num_parts: int) -> NamedSharding:
    """Sharding for a ``(num_parts, bucket)`` partitioned column: one row
    block per mesh device along the ``"part"`` axis."""
    return NamedSharding(relational_mesh(num_parts), P(PART_AXIS))


def dp_axes(mesh: Mesh):
    """Data-parallel axes: ("pod","data") when the mesh has a pod axis."""
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.axis_names)


# name → trailing-dims spec (right-aligned; missing leading dims → None)
_TRAILING_RULES = {
    # embedding (V, d): shard the EMBED dim, replicate vocab — a gather over a
    # vocab-sharded table triggers XLA SPMD "involuntary full remat" (the
    # [B,S,d] gather output gets replicated); d-sharding keeps the lookup
    # local and the output lands (dp, None, "model") for free.
    "table": (None, "model"),
    "lm_head": ("data", "model"),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wi": ("data", "model"),
    "wg": ("data", "model"),
    "wo": ("model", "data"),
    "w_uk": ("data", "model"),
    "w_uv": ("data", "model"),
    "w_dkv": ("data", None),
    "router": ("data", None),
    "wz": ("data", "model"),
    "wx": ("data", "model"),
    "wb": ("data", None),
    "wc": ("data", None),
    "wdt": ("data", None),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "proj": ("data", "model"),
}

# expert-stacked leaves (leading E axis → expert parallelism on "model")
_EXPERT_RULES = {
    "wg": ("model", "data", None),
    "wi": ("model", "data", None),
    "wo": ("model", None, "data"),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for part in path:
        if hasattr(part, "key"):
            names.append(str(part.key))
        elif hasattr(part, "idx"):
            names.append(str(part.idx))
    return tuple(names)


def _spec_for(path_names: Tuple[str, ...], shape: Tuple[int, ...],
              num_experts: int) -> P:
    if not path_names:
        return P()
    name = path_names[-1]
    nd = len(shape)
    is_expert = (
        name in _EXPERT_RULES
        and "shared" not in path_names
        and nd >= 3
        and num_experts > 0
        and shape[-3] == num_experts
    )
    rule = _EXPERT_RULES[name] if is_expert else _TRAILING_RULES.get(name)
    if rule is None or nd < len(rule):
        return P()  # small / unknown leaves: replicate
    spec = [None] * (nd - len(rule)) + list(rule)
    return P(*spec)


def param_specs(params_shape: Any, cfg, *, fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching a params (shape-struct) pytree.

    ``fsdp=False`` drops the "data" (FSDP) axis from every rule — pure tensor
    parallelism.  For models whose bf16 params fit HBM/model_parallel this
    removes the per-layer parameter all-gathers entirely (a §Perf lever)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        spec = _spec_for(_path_names(path), tuple(leaf.shape), cfg.num_experts)
        if not fsdp:
            spec = P(*[None if e == "data" else e for e in spec])
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Shard the batch dim over DP axes (replicate if batch < #dp shards)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec(path, leaf):
        names = _path_names(path)
        batch_axis = 1 if names and names[-1] == "positions" else 0
        if leaf.shape[batch_axis] % dp_size != 0 or leaf.shape[batch_axis] < dp_size:
            return P()
        s = [None] * len(leaf.shape)
        s[batch_axis] = dp
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shape)
    return jax.tree_util.tree_unflatten(treedef, [spec(p, l) for p, l in flat])


def cache_specs(cache_shape: Any, cfg, mesh: Mesh) -> Any:
    """Decode-cache specs.

    Attention KV: batch over DP, kv-head (or MLA latent / conv channels) over
    "model".  SSD state: heads over "model".  The scalar position replicates.
    """
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = len(leaf.shape)
        if name == "pos" or nd == 0:
            return P()
        # leading period axis present iff under "blocks"
        lead = [None] if names[0] == "blocks" else []
        model_size = mesh.shape[MODEL_AXIS]
        batch = dp if (leaf.shape[len(lead)] % dp_size == 0
                       and leaf.shape[len(lead)] >= dp_size) else None

        def fits(dim_idx):
            d = leaf.shape[len(lead) + dim_idx]
            return d % model_size == 0 and d >= model_size

        if name in ("k", "v"):
            # context-parallel decode: shard the SEQUENCE over "model".  KV
            # heads rarely divide a 16-wide axis, and head_dim sharding made
            # GSPMD re-layout the cache per step; with S sharded, scores stay
            # local and only the softmax stats + (B,H,D) output all-reduce.
            if fits(1):
                return P(*lead, batch, "model", None, None)
            return P(*lead, batch, None, None, None)
        if name == "ckv":
            return P(*lead, batch, "model" if fits(1) else None, None)
        if name == "conv":
            return P(*lead, batch, None, "model" if fits(2) else None)
        if name == "ssd":
            return P(*lead, batch, "model" if fits(1) else None, None, None)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(treedef, [spec(p, l) for p, l in flat])


def tree_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
