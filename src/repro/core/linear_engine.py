"""The LINEAR execution path (the paper's baseline).

This is the classic relational execution model the paper critiques: data is
flattened early into linearized intermediates —

  * hash join: the build side is collapsed into an open-addressing hash table
    (a 1-D linear memory structure); when the table exceeds ``work_mem`` the
    operator enters the *spill regime*: Grace-style recursive hash
    partitioning with real temp-file I/O (§VI: T_rel(N) = O(N) + α(N, M)).
  * sort: multi-attribute keys are collapsed into a single comparator
    (np.lexsort); above ``work_mem`` we switch to external merge sort with
    run spilling and multi-pass merges, each pass re-reading and re-writing
    the full dataset (spill amplification).

Everything here runs on the host CPU with numpy — faithful to the paper's
"CPU-based linear execution path" — and accounts every temp byte.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .metrics import OpMetrics, SpillAccount, Timer
from .relation import Relation
from .spill import SpillManager

__all__ = [
    "hash_join_linear",
    "sort_linear",
    "table_bytes_estimate",
    "HashTable",
]

_EMPTY = np.int64(-(2**62))
_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)
MAX_PARTITION_DEPTH = 6
MAX_FANOUT = 64
MERGE_BUFFER_BYTES = 96 * 1024  # per-run merge read buffer (PG tape buffer analog)
SLOT_BYTES = 16  # key (8B) + row pointer (8B) per open-addressing slot


def _splitmix64(x: np.ndarray, salt: int = 0) -> np.ndarray:
    """Vectorized splitmix64 over int64 keys → uint64 hashes."""
    salt_c = np.uint64((0x9E3779B97F4A7C15 * (salt + 1)) & 0xFFFFFFFFFFFFFFFF)
    with np.errstate(over="ignore"):
        z = x.astype(np.uint64) + salt_c
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def _next_pow2(n: int) -> int:
    return 1 << max(4, int(math.ceil(math.log2(max(1, n)))))


def table_bytes_estimate(n_build: int) -> int:
    """Open-addressing table footprint for n rows at load factor <= 0.5."""
    return SLOT_BYTES * _next_pow2(2 * max(1, n_build))


class HashTable:
    """Vectorized open-addressing (linear probing) hash table, int64 keys.

    The linearized intermediate of the paper's §II.B: the build relation is
    flattened into this 1-D slot array.  Duplicate build keys raise
    ``DuplicateKeys`` and the caller falls back to a sort-expand build (the
    semantics stay hash-join; only the duplicate-handling layout changes).
    """

    class DuplicateKeys(Exception):
        pass

    def __init__(self, keys: np.ndarray, salt: int = 0):
        n = len(keys)
        m = _next_pow2(2 * max(1, n))
        self.m = m
        self.salt = salt
        self.keys = keys
        self.tab_key = np.full(m, _EMPTY, dtype=np.int64)
        self.tab_row = np.zeros(m, dtype=np.int64)
        mask = np.uint64(m - 1)
        h = (_splitmix64(keys, salt) & mask).astype(np.int64)
        pending = np.arange(n, dtype=np.int64)
        probe = 0
        while pending.size:
            slots = (h[pending] + probe) & (m - 1)
            slot_keys = self.tab_key[slots]
            empty = slot_keys == _EMPTY
            if empty.any():
                cand_rows = pending[empty]
                cand_slots = slots[empty]
                uniq_slots, first = np.unique(cand_slots, return_index=True)
                winners = cand_rows[first]
                self.tab_key[uniq_slots] = keys[winners]
                self.tab_row[uniq_slots] = winners
                placed = np.zeros(n, dtype=bool)
                placed[winners] = True
                keep = ~placed[pending]
                pending = pending[keep]
                slots = slots[keep]
                slot_keys = self.tab_key[slots]
            # a pending row whose target slot holds its own key value → duplicate
            if pending.size and np.any(slot_keys == keys[pending]):
                raise HashTable.DuplicateKeys()
            probe += 1
            if probe > m:  # pragma: no cover - table provably has free slots
                raise RuntimeError("hash table full")

    @property
    def nbytes(self) -> int:
        return SLOT_BYTES * self.m

    def probe(self, probe_keys: np.ndarray) -> np.ndarray:
        """Return build-row index per probe key (-1 = no match)."""
        m = self.m
        mask = np.uint64(m - 1)
        h = (_splitmix64(probe_keys, self.salt) & mask).astype(np.int64)
        result = np.full(len(probe_keys), -1, dtype=np.int64)
        active = np.arange(len(probe_keys), dtype=np.int64)
        probe = 0
        while active.size:
            slots = (h[active] + probe) & (m - 1)
            sk = self.tab_key[slots]
            hit = sk == probe_keys[active]
            result[active[hit]] = self.tab_row[slots[hit]]
            done = hit | (sk == _EMPTY)
            active = active[~done]
            probe += 1
        return result


def _sort_expand_join(
    build_keys: np.ndarray, probe_keys: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Duplicate-tolerant in-memory join core: returns (build_idx, probe_idx)."""
    order = np.argsort(build_keys, kind="stable")
    sk = build_keys[order]
    left = np.searchsorted(sk, probe_keys, side="left")
    right = np.searchsorted(sk, probe_keys, side="right")
    counts = right - left
    total = int(counts.sum())
    probe_idx = np.repeat(np.arange(len(probe_keys)), counts)
    starts = np.repeat(left, counts)
    first_out = np.repeat(np.cumsum(counts) - counts, counts)
    offsets = np.arange(total) - first_out
    build_idx = order[starts + offsets]
    return build_idx, probe_idx


def _inmem_join(
    build: Relation, probe: Relation, key: str, peak: List[int]
) -> Relation:
    bk = build[key].astype(np.int64)
    pk = probe[key].astype(np.int64)
    try:
        tab = HashTable(bk)
        peak[0] = max(peak[0], tab.nbytes)
        hit_row = tab.probe(pk)
        matched = hit_row >= 0
        probe_idx = np.nonzero(matched)[0]
        build_idx = hit_row[probe_idx]
    except HashTable.DuplicateKeys:
        build_idx, probe_idx = _sort_expand_join(bk, pk)
        peak[0] = max(peak[0], table_bytes_estimate(len(bk)) + bk.nbytes * 2)
    out = {}
    for name, col in probe.columns.items():
        out[name] = col[probe_idx]
    for name, col in build.columns.items():
        if name == key:
            continue
        out[f"b_{name}"] = col[build_idx]
    if not out:  # key-only join
        out[key] = probe[key][probe_idx]
    peak[0] = max(peak[0], sum(c.nbytes for c in out.values()))
    return Relation(out)


def _grace_join(
    build: Relation,
    probe: Relation,
    key: str,
    work_mem: int,
    mgr: SpillManager,
    spill: SpillAccount,
    peak: List[int],
    depth: int = 0,
    cancel=None,
) -> Relation:
    est = table_bytes_estimate(len(build))
    if est <= work_mem or depth >= MAX_PARTITION_DEPTH or len(build) <= 64:
        return _inmem_join(build, probe, key, peak)
    if cancel is not None:
        # preemption poll: only the spill regime is cancellable — an
        # in-memory join finishes faster than any requeue could
        cancel.check()

    # Spill regime: recursive hash partitioning (Grace hash join).
    build_schema = {k: v for k, v in build.columns.items()}
    probe_schema = {k: v for k, v in probe.columns.items()}
    fanout = int(min(MAX_FANOUT, max(2, _next_pow2(int(math.ceil(est / work_mem))))))
    spill.partition_passes = max(spill.partition_passes, depth + 1)
    observe = getattr(cancel, "observe_fanout", None)
    if observe is not None:
        # execution-time guard: record the partition geometry actually chosen
        observe(est, fanout, depth)
    bh = (_splitmix64(build[key].astype(np.int64), salt=100 + depth) % np.uint64(fanout)).astype(np.int64)
    ph = (_splitmix64(probe[key].astype(np.int64), salt=100 + depth) % np.uint64(fanout)).astype(np.int64)

    # Intra-pass restart checkpoints: by the first pair boundary the whole
    # partitioning pass is sunk cost, so a badly mispriced decision is most
    # profitably abandoned *here*.  Mid-pass there is no reusable prefix —
    # the guard fires a restart SwitchPoint carrying the partial spill
    # files for deletion and the executor re-runs from the base relations.
    part_cp = getattr(cancel, "checkpoint_partition", None) if depth == 0 else None
    rows_total = len(build) + len(probe)
    rows_done = 0
    part_paths = []
    for f in range(fanout):
        if cancel is not None:
            cancel.check()  # per-partition poll: bounded preemption latency
        if part_cp is not None:
            part_cp(rows_done=rows_done, rows_total=rows_total,
                    files=[p for bp, pp, *_ in part_paths
                           for p in (bp, pp) if p],
                    spill=spill)
        b_part = build.take(np.nonzero(bh == f)[0])
        p_part = probe.take(np.nonzero(ph == f)[0])
        rows_done += len(b_part) + len(p_part)
        b_path = mgr.write_relation(b_part, f"jb{depth}", spill) if len(b_part) else None
        p_path = mgr.write_relation(p_part, f"jp{depth}", spill) if len(p_part) else None
        part_paths.append((b_path, p_path, len(b_part), len(p_part)))
    del build, probe  # the operator's working set is now on disk

    prefetch = getattr(mgr, "prefetch", None)
    if prefetch is not None:
        # Tiered manager: stream spilled BUILD partitions back up the
        # hierarchy (T2→T0) in the background while each earlier partition's
        # probe side is still being consumed — overlapping re-read latency
        # with join compute.  Promotion is best-effort: already-read or
        # deleted paths are skipped.
        prefetch([b for b, p, nb, npr in part_paths
                  if b is not None and p is not None and nb and npr])

    # Execution-time guard checkpoints fire only at depth 0, where partial
    # state is a clean prefix: ``results`` holds fully-joined partitions and
    # ``part_paths[i:]`` are untouched spilled pairs a tensor takeover can
    # reuse through the same spill manager.  Inside the recursion a pair is
    # half-consumed and a switch would lose work.
    checkpoint = getattr(cancel, "checkpoint", None) if depth == 0 else None
    results: List[Relation] = []
    for i, (b_path, p_path, nb, npr) in enumerate(part_paths):
        if checkpoint is not None:
            checkpoint(done=results, pending=part_paths[i:], spill=spill,
                       schema_hint=(build_schema, probe_schema))
        if b_path is None or p_path is None or nb == 0 or npr == 0:
            for p in (b_path, p_path):
                if p:
                    mgr.delete(p, spill)
            continue
        b_part = mgr.read_relation(b_path, spill)
        p_part = mgr.read_relation(p_path, spill)
        mgr.delete(b_path, spill)
        mgr.delete(p_path, spill)
        if cancel is not None:
            cancel.check()
        results.append(_grace_join(b_part, p_part, key, work_mem, mgr, spill,
                                   peak, depth + 1, cancel))
    if not results:
        # empty join result with the correct joined schema
        b_empty = Relation({k: v[:0] for k, v in build_schema.items()})
        p_empty = Relation({k: v[:0] for k, v in probe_schema.items()})
        return _inmem_join(b_empty, p_empty, key, peak)
    out = results[0]
    for r in results[1:]:
        out = out.concat(r)
    return out


def hash_join_linear(
    build: Relation,
    probe: Relation,
    key: str,
    work_mem: int,
    mgr: Optional[SpillManager] = None,
    cancel=None,
) -> Tuple[Relation, OpMetrics]:
    """Linear-path hash join with work_mem discipline and real spilling.

    ``cancel`` is an optional preemption token (any object with a
    ``check()`` raising :class:`~repro.core.faults.PreemptedError`): the
    spill regime polls it at partition boundaries so a floor-degraded join
    can abandon its spill mid-flight and be requeued on the tensor path."""
    own_mgr = mgr is None
    mgr = mgr or SpillManager()
    spill = SpillAccount()
    peak = [0]
    try:
        with Timer() as t:
            out = _grace_join(build, probe, key, work_mem, mgr, spill, peak,
                              cancel=cancel)
    finally:
        if own_mgr:
            mgr.cleanup()
    metrics = OpMetrics(
        op="hash_join",
        path="linear",
        rows_in=len(build) + len(probe),
        rows_out=len(out),
        wall_s=t.elapsed,
        spill=spill,
        peak_working_set_bytes=peak[0],
    )
    return out, metrics


# ---------------------------------------------------------------------------
# External merge sort
# ---------------------------------------------------------------------------

def _lexsort_rel(rel: Relation, keys: Sequence[str]) -> Relation:
    order = np.lexsort([rel[k] for k in reversed(keys)])
    return rel.take(order)


def _lex_le_bound(cols: Sequence[np.ndarray], bound: Sequence) -> np.ndarray:
    """Vectorized lexicographic `row <= bound` over key columns."""
    n = len(cols[0])
    result = np.zeros(n, dtype=bool)
    undecided = np.ones(n, dtype=bool)
    for c, b in zip(cols, bound):
        lt = c < b
        gt = c > b
        result |= undecided & lt
        undecided &= ~(lt | gt)
    result |= undecided  # equal on all keys
    return result


def _merge_runs(
    run_paths: List[str],
    keys: Sequence[str],
    mgr: SpillManager,
    spill: SpillAccount,
    row_bytes: int,
    final: bool,
) -> Tuple[Optional[str], Optional[Relation]]:
    """Streaming k-way merge via the splitter technique.

    Rows <= (min over streams of that stream's buffered tail) are globally
    safe to emit; they are cut from every buffer, merged with one lexsort,
    and appended to the output run.
    """
    readers = [mgr.open_run_reader(p, spill) for p in run_paths]
    buf_rows = max(64, MERGE_BUFFER_BYTES // max(1, row_bytes))
    buffers: List[Optional[Relation]] = [r.read_rows(buf_rows) for r in readers]
    out_chunks: List[Relation] = []

    def tail_tuple(rel: Relation):
        return tuple(rel[k][-1] for k in keys)

    while True:
        live = [i for i, b in enumerate(buffers) if b is not None and len(b) > 0]
        if not live:
            break
        # bound = smallest buffered tail among streams that still have data on disk;
        # fully-exhausted streams do not constrain the bound.
        bounding = [i for i in live if not readers[i].exhausted]
        if bounding:
            bound = min(tail_tuple(buffers[i]) for i in bounding)
        else:
            bound = max(tail_tuple(buffers[i]) for i in live)
        take_parts = []
        for i in live:
            b = buffers[i]
            mask = _lex_le_bound([b[k] for k in keys], bound)
            take_idx = np.nonzero(mask)[0]
            if len(take_idx):
                take_parts.append(b.take(take_idx))
                keep_idx = np.nonzero(~mask)[0]
                buffers[i] = b.take(keep_idx) if len(keep_idx) else None
            if (buffers[i] is None or len(buffers[i]) == 0) and not readers[i].exhausted:
                nxt = readers[i].read_rows(buf_rows)
                buffers[i] = nxt if len(nxt) else None
        if not take_parts:
            continue
        merged = take_parts[0]
        for p in take_parts[1:]:
            merged = merged.concat(p)
        out_chunks.append(_lexsort_rel(merged, keys))

    result = out_chunks[0]
    for c in out_chunks[1:]:
        result = result.concat(c)
    for p in run_paths:
        mgr.delete(p, spill)
    if final:
        return None, result
    path = mgr.write_relation(result, "run", spill)
    return path, None


def sort_linear(
    rel: Relation,
    keys: Sequence[str],
    work_mem: int,
    mgr: Optional[SpillManager] = None,
    cancel=None,
) -> Tuple[Relation, OpMetrics]:
    """Linear-path sort: in-memory lexsort or external merge sort with
    spilling.  ``cancel`` as in :func:`hash_join_linear`: polled at run and
    merge-pass boundaries so a degraded external sort is preemptible."""
    own_mgr = mgr is None
    mgr = mgr or SpillManager()
    spill = SpillAccount()
    peak = 0
    try:
        with Timer() as t:
            nbytes = rel.nbytes()
            if nbytes <= work_mem:
                out = _lexsort_rel(rel, keys)
                peak = 2 * nbytes
            else:
                # run generation
                row_bytes = rel.row_bytes()
                rows_per_run = max(64, work_mem // max(1, row_bytes))
                run_paths: List[str] = []
                # mid-pass restart checkpoint: sorted runs carry no
                # reusable cross-path state, so abandoning during run
                # formation (before the sunk cost grows) just deletes the
                # runs written so far and re-runs the tensor sort
                part_cp = getattr(cancel, "checkpoint_partition", None)
                for start in range(0, len(rel), rows_per_run):
                    if cancel is not None:
                        cancel.check()  # per-run poll
                    if part_cp is not None:
                        part_cp(rows_done=start, rows_total=len(rel),
                                files=list(run_paths), spill=spill)
                    chunk = Relation(
                        {k: v[start : start + rows_per_run] for k, v in rel.columns.items()}
                    )
                    run_paths.append(
                        mgr.write_relation(_lexsort_rel(chunk, keys), "run", spill)
                    )
                peak = 2 * rows_per_run * row_bytes
                # multi-pass merge limited by work_mem-funded buffers
                fan_in = max(2, work_mem // MERGE_BUFFER_BYTES - 1)
                # execution-time guard checkpoints at merge-pass boundaries:
                # sort has no reusable cross-path partial order, so a fired
                # guard hands the still-live run paths back for deletion and
                # the tensor sort re-runs from the base relation.
                checkpoint = getattr(cancel, "checkpoint_sort", None)
                out = None
                while True:
                    if cancel is not None:
                        cancel.check()  # per-merge-pass poll
                    if checkpoint is not None:
                        checkpoint(pending=run_paths, spill=spill)
                    spill.partition_passes += 1
                    if len(run_paths) <= fan_in:
                        _, out = _merge_runs(run_paths, keys, mgr, spill, row_bytes, final=True)
                        break
                    next_paths = []
                    for g in range(0, len(run_paths), fan_in):
                        if cancel is not None:
                            cancel.check()
                        group = run_paths[g : g + fan_in]
                        if len(group) == 1:
                            next_paths.append(group[0])
                        else:
                            p, _ = _merge_runs(group, keys, mgr, spill, row_bytes, final=False)
                            next_paths.append(p)
                    run_paths = next_paths
    finally:
        if own_mgr:
            mgr.cleanup()
    metrics = OpMetrics(
        op="sort",
        path="linear",
        rows_in=len(rel),
        rows_out=len(out),
        wall_s=t.elapsed,
        spill=spill,
        peak_working_set_bytes=peak,
    )
    return out, metrics
