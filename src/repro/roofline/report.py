"""Build the EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.

    PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .hw import HBM_BYTES

_MOVE_HINT = {
    "compute": "more MXU-efficient matmul shapes / less remat recompute",
    "memory": "fuse/bf16-ify the biggest intermediates; raise arithmetic "
              "intensity (larger microbatch, wider tiles)",
    "collective": "re-shard to cut the dominant collective (all-gather of "
                  "FSDP params or MoE all-to-all); overlap with compute",
}


def load(dir_: str, mesh: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        rows.append(json.loads(open(f).read()))
    return rows


def dryrun_table(dir_: str) -> str:
    out = ["| arch | shape | mesh | status | lower s | compile s | args GiB | temp GiB | HLO MB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        for r in load(dir_, mesh):
            if r["status"] == "skipped":
                out.append(f"| {r['arch']} | {r['shape']} | {mesh} | SKIP ({r['reason'][:40]}…) | | | | | |")
                continue
            ma = r.get("memory_analysis", {})
            out.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | {r['status']} "
                f"| {r.get('lower_s', '')} | {r.get('compile_s', '')} "
                f"| {ma.get('argument_size_in_bytes', 0) / 2**30:.2f} "
                f"| {ma.get('temp_size_in_bytes', 0) / 2**30:.2f} "
                f"| {r.get('hlo_text_bytes', 0) / 1e6:.0f} |")
    return "\n".join(out)


def roofline_table(dir_: str) -> str:
    out = ["| arch | shape | t_compute s | t_memory s | t_coll s | dominant | "
           "roofline frac | MODEL_FLOPS/dev | useful ratio | lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load(dir_, "single"):
        if r["status"] != "ok":
            continue
        rl = r.get("roofline", {})
        dom = rl.get("dominant", "?")
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl.get('t_compute_s', 0):.4f} | {rl.get('t_memory_s', 0):.4f} "
            f"| {rl.get('t_collective_s', 0):.4f} | {dom} "
            f"| {rl.get('roofline_fraction', 0):.3f} "
            f"| {r.get('model_flops_per_device', 0):.2e} "
            f"| {r.get('useful_flops_ratio') or 0:.2f} "
            f"| {_MOVE_HINT.get(dom, '')} |")
    return "\n".join(out)


def hbm_check(dir_: str) -> str:
    out = ["| arch | shape | mesh | args+temp GiB | fits 16 GiB HBM |",
           "|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        for r in load(dir_, mesh):
            if r["status"] != "ok":
                continue
            ma = r.get("memory_analysis", {})
            tot = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)) / 2**30
            fits = "yes" if tot * 2**30 <= HBM_BYTES else "**no**"
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | {tot:.2f} | {fits} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "hbm"])
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("### Dry-run\n")
        print(dryrun_table(args.dir))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single-pod 16×16)\n")
        print(roofline_table(args.dir))
    if args.section in ("all", "hbm"):
        print("\n### HBM budget\n")
        print(hbm_check(args.dir))


if __name__ == "__main__":
    main()
