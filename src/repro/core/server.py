"""Concurrent serving layer: closed-loop and open-loop query streams.

This is the repo's traffic model for the paper's headline claim.  Single-query
benchmarks (fig1–fig10) measure *throughput* per path; the phase transition
the paper actually reports — linear-path P99 going multi-second under
``work_mem`` pressure while the tensor path stays sub-second — only exists
when **concurrent queries contend for one memory pool**.  A
:class:`QueryServer` provides exactly that:

  * one :class:`~repro.core.session.Session` shared by every worker (shared
    device column cache, compiled-program cache, runtime profile — the
    serving configuration);
  * one :class:`~repro.core.memory_governor.MemoryGovernor` owning the total
    memory budget; every linear operator runs under a grant, so N concurrent
    linear queries genuinely squeeze each other into the spill regime;
  * two load drivers:

      - :meth:`QueryServer.serve` — **closed loop**: each of N workers
        submits its next query the moment the previous one completes, so
        offered concurrency is exactly N (the fig11/fig12 configuration);
      - :meth:`QueryServer.serve_open` — **open loop**: an
        :class:`~repro.core.slo.ArrivalProcess` schedules thousands of
        logical clients on their own clock (Poisson / bursty phases), a
        bounded worker pool drains a priority queue, and per-tenant
        :class:`~repro.core.slo.TenantClass` deadlines drive **admission
        shedding** (a sheddable query whose quoted wait already exceeds its
        deadline is rejected up front), **deadline enforcement** (an
        admitted query that starves past its deadline in queue is recorded
        as failed, not silently served late), and **preemption** (a
        positive-priority tenant facing blocked admission cancels
        floor-degraded linear operators mid-spill; they re-run on the
        tensor path).  This is the fig13 configuration — a closed loop
        cannot even *express* the overload it measures, because a closed
        loop's offered load politely throttles itself (the classic
        coordinated-omission trap).

Failure discipline (both drivers): every submitted query ends as exactly one
of **served**, **shed**, or **failed**.  Per-query exceptions — injected
faults that exhausted their retries, deadline misses, anything raised by
the engine — become :class:`FailedQuery` records, and the run keeps going.
Only a :class:`~repro.core.memory_governor.BrokerInvariantViolation` (the
never-over-budget invariant itself broke — the one condition that poisons
every subsequent measurement) aborts the run and re-raises.

:meth:`QueryServer.serve` and :meth:`~QueryServer.serve_open` return a
:class:`ServeReport` with the full latency sample set, P50/P99, per-query
spill volume and grant sizes, shed/failed partitions, per-tenant SLO
attainment, fault-injection counts, and the governor's invariant counters
(``over_budget_events`` must be 0).

    >>> server = QueryServer({"orders": orders, "users": users},
    ...                      total_mem=64 * MB, work_mem=32 * MB)
    >>> q = server.session.table("orders").join("users", on="uid") \\
    ...           .sort("uid").aggregate("w", "sum")
    >>> report = server.serve([q], concurrency=8, queries_per_worker=4)
    >>> report.latency.p99, report.governor.over_budget_events
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence

from .executor import QueryResult
from .faults import FaultInjector, SimulatedCrash
from .memory_governor import (BrokerInvariantViolation, GovernorStats,
                              MemoryGovernor)
from .metrics import LatencyStats, Timer, latency_stats
from .relation import Relation
from .resource_broker import (BrokerStats, DeviceQueue, ResourceBroker,
                              ResourceRequest)
from .session import Query, Session
from .slo import ArrivalProcess, TenantClass
from .tier import TierConfig

__all__ = ["QueryServer", "ServeReport", "ServedQuery", "ShedQuery",
           "FailedQuery"]

MB = 1 << 20


@dataclasses.dataclass
class ServedQuery:
    """One completed query of a serving run."""

    worker: int
    seq: int               # per-worker sequence number (closed loop) or
                           # global submission sequence (open loop)
    workload_idx: int      # which workload item this was
    wall_s: float          # end-to-end latency; open loop: arrival→done
                           # sojourn incl. queueing (no coordinated omission)
    temp_mb: float         # temp-file bytes this query spilled
    grant_bytes: int       # smallest grant any of its linear operators got
    paths: str             # "tensor", "linear", or "mixed"
    scalar: Optional[float]
    relation: Optional[Relation]
    mem_wait_s: float = 0.0    # total memory-admission wait across operators
    queue_wait_s: float = 0.0  # total device-lease wait across operators
    batched: bool = False      # any dispatch ran in a coalesced lease group
    tenant: str = ""           # open loop: the TenantClass this ran under
    arrival_s: float = 0.0     # open loop: arrival offset from run start
    service_s: float = 0.0     # open loop: execution time excl. queueing
    slo_ok: bool = True        # open loop: sojourn <= tenant deadline
    preempted: bool = False    # any operator was preempted → tensor re-run
    switched: bool = False     # any operator took a guard SwitchPoint:
                               # abandoned its mispriced path mid-query and
                               # finished on the tensor path (partition reuse)
    h2d_bytes: int = 0         # PHYSICAL host→device bytes (packed codes +
                               # dictionaries under compressed layouts; 0
                               # when every input was device-resident)
    h2d_bytes_logical: int = 0  # same transfers at logical column width —
                               # physical/logical is the query's effective
                               # H2D compression ratio


@dataclasses.dataclass
class ShedQuery:
    """One query rejected by admission control (load shedding): its quoted
    wait already exceeded its deadline, so serving it would have burned
    capacity on a result nobody could use."""

    tenant: str
    seq: int               # global submission sequence
    workload_idx: int
    arrival_s: float       # arrival offset from run start
    quoted_wait_s: float   # the wait admission quoted at arrival
    deadline_s: float      # the tenant deadline it exceeded


@dataclasses.dataclass
class FailedQuery:
    """One query that was admitted but did not produce a result: a typed
    engine error that survived retries, or a deadline miss while queued.
    ``error`` is the exception class name (``"DeadlineExceeded"``,
    ``"SpillIOError"``, ...)."""

    worker: int
    seq: int
    workload_idx: int
    error: str
    message: str = ""
    tenant: str = ""
    arrival_s: float = 0.0
    wall_s: float = 0.0    # arrival→failure (open loop) or submit→raise


@dataclasses.dataclass
class ServeReport:
    """Aggregate of one :meth:`QueryServer.serve` /
    :meth:`QueryServer.serve_open` run."""

    queries: List[ServedQuery]
    latency: LatencyStats
    wall_s: float                  # whole-run wall time
    total_temp_mb: float
    governor: GovernorStats
    concurrency: int
    # per-run broker accounting (device dispatch groups/coalescing, lease
    # waits, quote counts, reservations, preemptions); EWMA/peak fields are
    # end-of-run gauges
    broker: Optional[BrokerStats] = None
    shed: List[ShedQuery] = dataclasses.field(default_factory=list)
    failed: List[FailedQuery] = dataclasses.field(default_factory=list)
    submitted: int = 0             # every arrival: served + shed + failed
    # fault-injection counts for THIS run (None when no injector): the chaos
    # gate asserts these are nonzero, so "survived chaos" can never mean
    # "chaos never happened"
    faults: Optional[Dict[str, int]] = None
    # spill-tier ledger snapshot (None when the session spills straight to
    # disk): per tier {bytes_written, bytes_read, bytes_freed, live_bytes,
    # ...} plus pool_leaked_bytes / prefetches / managers — cumulative over
    # the server's session lifetime, because the balance invariant
    # (freed == written, live == 0, zero pool leak) is only meaningful at
    # quiesce over ALL managers, warmup included
    tiers: Optional[Dict[str, object]] = None

    @property
    def qps(self) -> float:
        return len(self.queries) / max(self.wall_s, 1e-9)

    @property
    def p99_over_p50(self) -> float:
        """The paper's stability metric: tail amplification of the latency
        distribution.  ~1 = predictable; >>1 = the spill-regime tail."""
        return self.latency.p99 / max(self.latency.p50, 1e-9)

    @property
    def total_h2d_bytes(self) -> int:
        """Physical host→device bytes across all served queries (warm
        serving over device-resident tables reports 0)."""
        return sum(q.h2d_bytes for q in self.queries)

    @property
    def total_h2d_bytes_logical(self) -> int:
        """The same transfers priced at logical column width; the run-level
        ratio physical/logical is what fig17's cold cells gate on."""
        return sum(q.h2d_bytes_logical for q in self.queries)

    @property
    def counts(self) -> Dict[str, int]:
        return {"submitted": self.submitted, "served": len(self.queries),
                "shed": len(self.shed), "failed": len(self.failed)}

    def by_workload(self, idx: int) -> List[ServedQuery]:
        return [q for q in self.queries if q.workload_idx == idx]

    # -- per-tenant views (open-loop runs) -----------------------------------
    def tenant_queries(self, tenant: str) -> List[ServedQuery]:
        return [q for q in self.queries if q.tenant == tenant]

    def tenant_latency(self, tenant: str) -> Optional[LatencyStats]:
        """Sojourn-latency stats for one tenant's served queries (None when
        it served nothing)."""
        samples = [q.wall_s for q in self.tenant_queries(tenant)]
        return latency_stats(samples) if samples else None

    def tenant_counts(self, tenant: str) -> Dict[str, int]:
        served = len(self.tenant_queries(tenant))
        shed = sum(1 for s in self.shed if s.tenant == tenant)
        failed = sum(1 for f in self.failed if f.tenant == tenant)
        return {"submitted": served + shed + failed, "served": served,
                "shed": shed, "failed": failed}

    def slo_attainment(self, tenant: str) -> float:
        """Fraction of this tenant's *served* queries that met their
        deadline (1.0 when it served nothing — no evidence of a miss)."""
        qs = self.tenant_queries(tenant)
        if not qs:
            return 1.0
        return sum(1 for q in qs if q.slo_ok) / len(qs)


def _min_grant_of(result: QueryResult) -> int:
    grants = [m.grant_bytes for m in result.metrics if m.grant_bytes > 0]
    return min(grants) if grants else 0


def _paths_of(result: QueryResult) -> str:
    paths = {d.path for d in result.decisions}
    if len(paths) == 1:
        return next(iter(paths))
    return "mixed" if paths else "none"


class QueryServer:
    """Owns the serving-scope state: session + tables + resource broker.

    ``total_mem`` is the budget EVERY concurrent linear operator shares;
    ``work_mem`` is the per-operator ceiling a single grant may reach (the
    classic PostgreSQL meaning).  ``total_mem=None`` runs ungoverned —
    every query gets the full ``work_mem``, which reduces to the
    single-query semantics of the earlier PRs.

    Every server owns its :class:`~repro.core.resource_broker.
    ResourceBroker` (private device queue + the governor): leases, queue
    depth, EWMA waits and pressure quotes are all per-server state, so one
    server's load never pollutes another's pricing.  That isolation trades
    away cross-server device serialization — servers meant to run
    CONCURRENTLY in one process should share a queue (build their sessions
    over brokers constructed with the same
    :class:`~repro.core.resource_broker.DeviceQueue`).  ``grant_policy``
    selects the governor's degradation policy (``"floor"`` default,
    ``"proportional"`` for the PG hash_mem_multiplier analogue, or a
    :class:`~repro.core.memory_governor.GrantPolicy` instance);
    ``queue_aware=False`` disables the broker's wait pricing — the
    queue-blind ablation fig12 measures against (grant sizing stays
    pressure-aware; only the wait terms vanish); ``device_max_batch``
    bounds a coalesced device-dispatch group (``1`` = strict PR-4
    one-at-a-time serialization, ``None`` = unbounded coalescing);
    ``reservations=False`` is the quote-only ablation — ``auto`` prices
    against non-binding quotes and fig13 counts the decide-then-lose
    incidents; ``faults`` plugs a :class:`~repro.core.faults.FaultInjector`
    into every fault site the serving path crosses (spill writes and reads,
    device dispatch, memory grants) for chaos runs; ``tiers`` (a
    :class:`~repro.core.tier.TierConfig`, or ``True`` for the defaults)
    routes every spill through the T0/T1/T2 hierarchy, makes grants
    tiered, and adds the session-lifetime per-tier books to the report
    (``report.tiers``).
    """

    def __init__(self, tables: Dict[str, Relation],
                 total_mem: Optional[int], work_mem: Optional[int] = None,
                 policy: Optional[str] = None,
                 min_grant: Optional[int] = None,
                 full_grant_wait_s: Optional[float] = None,
                 grant_policy=None,
                 queue_aware: Optional[bool] = None,
                 device_max_batch: Optional[int] = None,
                 reservations: Optional[bool] = None,
                 faults: Optional[FaultInjector] = None,
                 retry=None,
                 max_shards: Optional[int] = None,
                 tiers: Optional[TierConfig] = None,
                 guards: Optional[bool] = None,
                 session: Optional[Session] = None):
        if session is not None:
            # a prebuilt session owns its broker, governor, work_mem and
            # policy; silently dropping overrides would let a caller
            # believe it forced a configuration it never got
            conflicts = {"total_mem": total_mem, "work_mem": work_mem,
                         "policy": policy, "min_grant": min_grant,
                         "full_grant_wait_s": full_grant_wait_s,
                         "grant_policy": grant_policy,
                         "queue_aware": queue_aware,
                         "device_max_batch": device_max_batch,
                         "reservations": reservations,
                         "faults": faults, "retry": retry,
                         "max_shards": max_shards, "tiers": tiers,
                         "guards": guards}
            given = [k for k, v in conflicts.items() if v is not None]
            if given:
                raise ValueError(
                    f"pass either a prebuilt session or "
                    f"{'/'.join(given)}; an explicit session already owns "
                    f"its broker, governor, work_mem and policy")
        else:
            # one TierConfig instance shared by governor (tiered grants +
            # quote pricing), selector (staircase candidate) and executor
            # (per-query TierManager construction)
            if tiers is True:
                tiers = TierConfig()
            governor = (MemoryGovernor(
                total_mem,
                min_grant=1 * MB if min_grant is None else min_grant,
                full_grant_wait_s=full_grant_wait_s or 0.0,
                policy=grant_policy, tiers=tiers)
                if total_mem is not None else None)
            broker = ResourceBroker(
                governor,
                device_queue=DeviceQueue(max_group=device_max_batch),
                queue_pricing=True if queue_aware is None else queue_aware,
                reservations=True if reservations is None else reservations,
                faults=faults)
            session = Session(
                work_mem=32 * MB if work_mem is None else work_mem,
                policy=policy or "auto", broker=broker, retry=retry,
                max_shards=1 if max_shards is None else max_shards,
                tiers=tiers,
                guards=True if guards is None else guards)
        self.session = session
        self.governor = session.governor
        self.broker = session.broker
        self.faults = session.executor.faults
        # Sharded serving: pre-create the broker's device lanes at build
        # time (capped at the mesh's actual device count), so admission
        # quotes see per-lane waits from the first arrival instead of only
        # after the first gang dispatch lazily grew the lane set.
        if self.session.executor.max_shards > 1:
            from ..distributed.sharding import available_partitions

            self.broker.ensure_lanes(
                min(self.session.executor.max_shards,
                    available_partitions()))
        for name, rel in tables.items():
            self.session.register(name, rel)

    # -- single query --------------------------------------------------------
    def submit(self, query) -> QueryResult:
        """Run one query through the governed session (any :class:`Query`,
        logical tree, or legacy physical tree)."""
        return self.session.execute(query)

    # -- report assembly -----------------------------------------------------
    def _snapshot_base(self):
        gov = (self.governor.stats() if self.governor is not None
               else GovernorStats())
        fts = self.faults.counts() if self.faults is not None else None
        return gov, self.broker.stats(), fts

    def _build_report(self, base, served, shed, failed, submitted, wall_s,
                      concurrency) -> ServeReport:
        base_gov, base_broker, base_faults = base
        gov = (self.governor.stats() if self.governor is not None
               else GovernorStats())
        # report the governor's activity for THIS run (counters are
        # cumulative; peak and invariant counters are monotone so the
        # absolute values remain the right thing to assert on)
        gov.grants -= base_gov.grants
        gov.degraded -= base_gov.degraded
        gov.waits -= base_gov.waits
        gov.wait_s_total -= base_gov.wait_s_total
        gov.holds -= base_gov.holds
        gov.holds_converted -= base_gov.holds_converted
        gov.holds_expired -= base_gov.holds_expired
        gov.holds_cancelled -= base_gov.holds_cancelled
        fault_counts = None
        if self.faults is not None:
            now = self.faults.counts()
            fault_counts = {k: now[k] - (base_faults or {}).get(k, 0)
                            for k in now}
        return ServeReport(
            queries=served,
            latency=(latency_stats([q.wall_s for q in served]) if served
                     else LatencyStats(0.0, 0.0, 0.0, 0.0, 0.0, 0)),
            wall_s=wall_s,
            total_temp_mb=sum(q.temp_mb for q in served),
            governor=gov,
            concurrency=concurrency,
            broker=self.broker.stats().since(base_broker),
            shed=shed, failed=failed, submitted=submitted,
            faults=fault_counts,
            tiers=(self.session.tier_ledger.snapshot()
                   if getattr(self.session, "tier_ledger", None) is not None
                   else None))

    def _served_record(self, res: QueryResult, *, worker: int, seq: int,
                       idx: int, wall_s: float, keep: bool,
                       tenant: str = "", arrival_s: float = 0.0,
                       service_s: float = 0.0,
                       slo_ok: bool = True) -> ServedQuery:
        return ServedQuery(
            worker=worker, seq=seq, workload_idx=idx,
            wall_s=wall_s, temp_mb=res.total_temp_mb,
            grant_bytes=_min_grant_of(res),
            paths=_paths_of(res), scalar=res.scalar,
            relation=res.relation if keep else None,
            mem_wait_s=sum(m.mem_wait_s for m in res.metrics),
            queue_wait_s=sum(m.queue_wait_s for m in res.metrics),
            batched=any(m.batched for m in res.metrics),
            tenant=tenant, arrival_s=arrival_s,
            service_s=service_s or wall_s, slo_ok=slo_ok,
            preempted=any(m.preempted for m in res.metrics),
            switched=any(m.switched for m in res.metrics),
            h2d_bytes=res.total_h2d_bytes,
            h2d_bytes_logical=res.total_h2d_bytes_logical)

    # -- closed-loop stream --------------------------------------------------
    def serve(self, workload: Sequence, concurrency: int,
              queries_per_worker: int, warmup: int = 1,
              keep_relations: bool = True) -> ServeReport:
        """Drive ``concurrency`` workers in a closed loop.

        Each worker executes ``queries_per_worker`` queries back-to-back,
        cycling through ``workload`` (Query objects or logical/physical
        trees) at a per-worker offset so every item sees traffic from
        several workers.  ``warmup`` serial passes over the workload run
        first, off the clock — they converge the compile cache, the device
        column cache and the runtime profile, so the measured window
        reflects steady-state serving, not first-query compilation.

        ``keep_relations=False`` drops each relation-rooted result after
        recording its size — a long measurement run otherwise pins every
        result relation in memory until the report is dropped, making the
        harness itself the dominant memory consumer while it measures
        memory-pressure behavior.

        A query that raises is recorded as a :class:`FailedQuery` sample and
        the run continues — under fault injection, a failed query is data,
        not a reason to discard the measurement.  Only a
        :class:`~repro.core.memory_governor.BrokerInvariantViolation`
        aborts the run and re-raises: the budget invariant breaking poisons
        every subsequent sample.
        """
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        if queries_per_worker < 1:
            raise ValueError(f"queries_per_worker must be >= 1, got "
                             f"{queries_per_worker}")
        workload = list(workload)
        if not workload:
            raise ValueError("empty workload")
        for _ in range(max(0, warmup)):
            for item in workload:
                self.submit(item)

        base = self._snapshot_base()
        served: List[ServedQuery] = []
        failed: List[FailedQuery] = []
        errors: List[BaseException] = []
        lock = threading.Lock()

        def worker(wid: int) -> None:
            for seq in range(queries_per_worker):
                idx = (wid + seq) % len(workload)
                t = Timer()
                try:
                    with t:
                        res = self.submit(workload[idx])
                except BrokerInvariantViolation as e:
                    with lock:  # the one non-survivable failure
                        errors.append(e)
                    return
                except (Exception, SimulatedCrash) as e:
                    with lock:
                        failed.append(FailedQuery(
                            worker=wid, seq=seq, workload_idx=idx,
                            error=type(e).__name__, message=str(e),
                            wall_s=t.elapsed))
                    continue
                except BaseException as e:  # KeyboardInterrupt etc.
                    with lock:
                        errors.append(e)
                    return
                rec = self._served_record(res, worker=wid, seq=seq, idx=idx,
                                          wall_s=t.elapsed,
                                          keep=keep_relations)
                with lock:
                    served.append(rec)

        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(concurrency)]
        with Timer() as run_t:
            for th in threads:
                th.start()
            for th in threads:
                th.join()
        if errors:
            raise errors[0]

        return self._build_report(
            base, served, [], failed,
            submitted=len(served) + len(failed),
            wall_s=run_t.elapsed, concurrency=concurrency)

    # -- open-loop stream ----------------------------------------------------
    def serve_open(self, workloads: Mapping[str, Sequence],
                   arrivals: Mapping[str, ArrivalProcess],
                   duration_s: float, tenants: Sequence[TenantClass],
                   workers: int = 4, warmup: int = 1,
                   shed: bool = True, preempt: bool = True,
                   keep_relations: bool = False) -> ServeReport:
        """Open-loop SLO-aware serving: the fig13 driver.

        ``workloads`` maps tenant name → query sequence; ``arrivals`` maps
        tenant name → :class:`~repro.core.slo.ArrivalProcess` (each arrival
        is an independent logical client — a storm of thousands of arrivals
        models thousands of clients without thousands of threads); both key
        sets must exactly match the names in ``tenants``.  A dispatcher
        thread replays every arrival on the wall clock over ``duration_s``
        seconds and a pool of ``workers`` threads drains the ready queue in
        (priority, arrival) order.

        Per arrival, in order:

        1. **Admission** (``shed=True``): a sheddable tenant's query whose
           quoted wait — queue backlog ahead of it × EWMA service time ÷
           workers, plus the broker's memory-admission quote — already
           exceeds its deadline is shed (:class:`ShedQuery`); running it
           would burn capacity on a result nobody can use.  Non-sheddable
           tenants are always admitted.
        2. **Deadline enforcement at dequeue**: an admitted sheddable query
           that starved past its deadline while queued is recorded as a
           :class:`FailedQuery` (``error="DeadlineExceeded"``) — an
           admission mistake, measured instead of served late.
           Non-sheddable tenants run regardless; a late completion shows up
           as ``slo_ok=False`` on the served record.
        3. **Preemption** (``preempt=True``): a positive-priority tenant
           whose memory admission would block first cancels one
           floor-degraded linear operator mid-spill
           (:meth:`~repro.core.resource_broker.ResourceBroker.
           preempt_degraded`); the victim's operator re-runs on the tensor
           path (``ServedQuery.preempted``) instead of holding the spill
           wall in front of the premium tenant.

        Latency is the arrival→completion **sojourn** — queueing included,
        measured from the scheduled arrival time, so the report is free of
        coordinated omission by construction.  Every arrival ends as
        exactly one of served / shed / failed (``report.counts``).
        """
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if duration_s <= 0:
            raise ValueError(f"duration_s must be > 0, got {duration_s}")
        tenants = list(tenants)
        if not tenants:
            raise ValueError("need at least one TenantClass")
        by_name = {t.name: t for t in tenants}
        if len(by_name) != len(tenants):
            raise ValueError("duplicate tenant names")
        for label, mapping in (("workloads", workloads),
                               ("arrivals", arrivals)):
            if set(mapping) != set(by_name):
                raise ValueError(
                    f"{label} keys {sorted(mapping)} must match tenant "
                    f"names {sorted(by_name)}")
        workloads = {name: list(wl) for name, wl in workloads.items()}
        for name, wl in workloads.items():
            if not wl:
                raise ValueError(f"empty workload for tenant {name!r}")

        # Warmup: converge caches off the clock AND seed the service-time
        # EWMA the admission quote needs before the first real arrival.
        svc_ewma = 0.0
        for r in range(max(1, warmup)):
            for name in sorted(workloads):
                for item in workloads[name]:
                    try:
                        with Timer() as t:
                            self.submit(item)
                    except BrokerInvariantViolation:
                        raise
                    except (Exception, SimulatedCrash):
                        # a poisoned item fails here AND during serving —
                        # there it becomes a FailedQuery sample, so warmup
                        # must not abort the run over it
                        continue
                    svc_ewma = (t.elapsed if svc_ewma == 0.0
                                else 0.7 * svc_ewma + 0.3 * t.elapsed)

        # The full arrival schedule, merged across tenants in time order.
        # Workload items cycle per tenant, so every item sees traffic.
        events = []
        for name in sorted(workloads):
            ts = arrivals[name].times(duration_s)
            n_items = len(workloads[name])
            for i, t_off in enumerate(ts):
                events.append((float(t_off), name, i % n_items))
        events.sort()
        submitted = len(events)

        base = self._snapshot_base()
        probe_bytes = self.session.executor.work_mem
        served: List[ServedQuery] = []
        shed_q: List[ShedQuery] = []
        failed: List[FailedQuery] = []
        errors: List[BaseException] = []
        cond = threading.Condition()
        ready: list = []        # heap of (-priority, seq, payload)
        inflight = [0]
        done_dispatching = [False]
        abort = [False]
        ewma = [svc_ewma]

        def quoted_wait(tc: TenantClass) -> float:
            """Admission-time wait estimate: ready-queue work ahead of this
            tenant (same or higher priority) plus in-flight work, spread
            over the pool, plus the broker's memory-admission quote.  A
            sharded server (``max_shards > 1``) additionally charges the
            device gang wait — the max over the per-lane expected waits a
            fan-out dispatch would block on; single-lane servers skip the
            term so their admission pricing (and fig13's shed counts) is
            byte-for-byte the pre-sharding behavior."""
            with cond:
                ahead = inflight[0] + sum(
                    1 for e in ready if -e[0] >= tc.priority)
                est = (ahead / workers) * ewma[0]
            if self.governor is not None:
                q = self.broker.price(
                    ResourceRequest("memory", need_bytes=probe_bytes))
                est += q.expected_wait_s
            nlanes = self.session.executor.max_shards
            if nlanes > 1:
                dq = self.broker.price(
                    ResourceRequest("device", lanes=nlanes))
                est += dq.expected_wait_s
            return est

        def dispatcher() -> None:
            t0 = time.perf_counter()
            for seq, (t_off, name, idx) in enumerate(events):
                # sleep to the scheduled arrival in small slices so an
                # abort (invariant violation) stops the storm promptly
                while not abort[0]:
                    lag = (t0 + t_off) - time.perf_counter()
                    if lag <= 0:
                        break
                    time.sleep(min(lag, 0.05))
                if abort[0]:
                    return
                tc = by_name[name]
                if shed and tc.sheddable:
                    est = quoted_wait(tc)
                    if est > tc.deadline_s:
                        with cond:
                            shed_q.append(ShedQuery(
                                tenant=name, seq=seq, workload_idx=idx,
                                arrival_s=t_off, quoted_wait_s=est,
                                deadline_s=tc.deadline_s))
                        continue
                with cond:
                    heapq.heappush(ready,
                                   (-tc.priority, seq, (t0 + t_off, name,
                                                        idx)))
                    cond.notify()
            with cond:
                done_dispatching[0] = True
                cond.notify_all()

        def worker(wid: int) -> None:
            while True:
                with cond:
                    while not ready and not done_dispatching[0] \
                            and not abort[0]:
                        cond.wait()
                    if abort[0] or (not ready and done_dispatching[0]):
                        return
                    _, seq, (arr_abs, name, idx) = heapq.heappop(ready)
                    inflight[0] += 1
                tc = by_name[name]
                try:
                    lag = time.perf_counter() - arr_abs
                    if tc.sheddable and lag > tc.deadline_s:
                        # admitted, then starved past its deadline in queue:
                        # an admission mistake, recorded rather than served
                        # late (the result is already worthless)
                        with cond:
                            failed.append(FailedQuery(
                                worker=wid, seq=seq, workload_idx=idx,
                                error="DeadlineExceeded",
                                message=f"queued {lag:.3f}s > deadline "
                                        f"{tc.deadline_s:.3f}s",
                                tenant=name, wall_s=lag))
                        continue
                    if preempt and tc.priority > 0 \
                            and self.governor is not None:
                        _, would_block, waiters = \
                            self.governor.admission_probe(probe_bytes)
                        if would_block or waiters > 0:
                            # a premium tenant must not park behind a
                            # best-effort spill wall: cancel one degraded
                            # linear operator; it re-runs on the tensor path
                            self.broker.preempt_degraded(1)
                    with Timer() as t:
                        res = self.submit(workloads[name][idx])
                    sojourn = time.perf_counter() - arr_abs
                    rec = self._served_record(
                        res, worker=wid, seq=seq, idx=idx, wall_s=sojourn,
                        keep=keep_relations, tenant=name,
                        arrival_s=0.0, service_s=t.elapsed,
                        slo_ok=sojourn <= tc.deadline_s)
                    with cond:
                        served.append(rec)
                        ewma[0] = (t.elapsed if ewma[0] == 0.0
                                   else 0.7 * ewma[0] + 0.3 * t.elapsed)
                except BrokerInvariantViolation as e:
                    with cond:  # the one non-survivable failure
                        errors.append(e)
                        abort[0] = True
                        cond.notify_all()
                    return
                except (Exception, SimulatedCrash) as e:
                    with cond:
                        failed.append(FailedQuery(
                            worker=wid, seq=seq, workload_idx=idx,
                            error=type(e).__name__, message=str(e),
                            tenant=name,
                            wall_s=time.perf_counter() - arr_abs))
                except BaseException as e:  # KeyboardInterrupt etc.
                    with cond:
                        errors.append(e)
                        abort[0] = True
                        cond.notify_all()
                    return
                finally:
                    with cond:
                        inflight[0] -= 1

        disp = threading.Thread(target=dispatcher, daemon=True)
        pool = [threading.Thread(target=worker, args=(w,), daemon=True)
                for w in range(workers)]
        with Timer() as run_t:
            disp.start()
            for th in pool:
                th.start()
            disp.join()
            for th in pool:
                th.join()
        if errors:
            raise errors[0]

        # arrival offsets were only known to the dispatcher on the absolute
        # clock; stamp the report-relative offsets back onto the records
        for rec in served:
            rec.arrival_s = events[rec.seq][0]
        for f in failed:
            f.arrival_s = events[f.seq][0]
        return self._build_report(
            base, served, shed_q, failed, submitted=submitted,
            wall_s=run_t.elapsed, concurrency=workers)
