"""Device-resident tensor execution path: fused pipelines, late
materialization, capacity bucketing, and Pallas kernel wiring.

These tests are deliberately hypothesis-free so they always run: they carry
the tensor-vs-linear parity coverage for environments without the optional
property-testing dependency (see requirements.txt).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    Aggregate,
    DeviceRelation,
    Executor,
    Filter,
    GroupBy,
    Join,
    Relation,
    Scan,
    Sort,
    aligned_join_indices,
    capacity_bucket,
    group_aggregate_device,
    group_aggregate_linear,
    hash_join_linear,
    join_capacity,
    match_fragment,
    pipeline_cache_clear,
    pipeline_cache_info,
    sort_linear,
    tensor_join,
    tensor_join_aggregate,
    tensor_join_device,
    tensor_sort_device,
)


def _tables(rng, n_build, n_probe, bkeys=None, domain=None):
    domain = domain or max(1, n_build)
    build = Relation({
        "k": (bkeys if bkeys is not None
              else rng.integers(0, domain, n_build)).astype(np.int64),
        "v": rng.integers(-99, 99, n_build).astype(np.int64),
    })
    probe = Relation({
        "k": rng.integers(0, domain, n_probe).astype(np.int64),
        "w": rng.integers(-99, 99, n_probe).astype(np.int64),
    })
    return build, probe


# ---------------------------------------------------------------------------
# Parity: fused / device-resident tensor path vs linear, nasty key shapes
# ---------------------------------------------------------------------------

PARITY_CASES = {
    "unique_dense": lambda rng: _tables(rng, 3000, 4000,
                                        bkeys=rng.permutation(3000)),
    "duplicate_heavy": lambda rng: _tables(rng, 4000, 4000, domain=17),
    "skewed_90pct_one_key": lambda rng: _tables(
        rng, 3000, 3000,
        bkeys=np.where(rng.random(3000) < 0.9, 7,
                       rng.integers(0, 3000, 3000))),
    "sparse_wide_domain": lambda rng: _tables(
        rng, 2000, 3000, bkeys=rng.permutation(2000) * 10**9,
        domain=2000 * 10**9),
    "empty_probe": lambda rng: _tables(rng, 1024, 0),
    "empty_build": lambda rng: _tables(rng, 0, 1024),
    "single_row": lambda rng: _tables(rng, 1, 10, domain=1),
}


@pytest.mark.parametrize("case", sorted(PARITY_CASES))
def test_fused_pipeline_parity(case):
    rng = np.random.default_rng(hash(case) % 2**31)
    build, probe = PARITY_CASES[case](rng)
    plans = [
        lambda: Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"]),
        lambda: Aggregate(Sort(Join(Scan(build), Scan(probe), "k"),
                               ["k"]), "b_v", "sum"),
        lambda: Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                      lambda r: r["w"] % 2 == 0),
                               ["k", "w"]), "w", "sum"),
        lambda: Aggregate(Join(Scan(build), Scan(probe), "k"), "b_v", "count"),
    ]
    if len(build) == 0:
        plans = plans[:1]  # aggregates over an empty schema column set differ
    for mk in plans:
        q_lin = Executor(work_mem=1 << 30, policy="linear").execute(mk())
        q_ten = Executor(work_mem=1 << 30, policy="tensor").execute(mk())
        if q_lin.relation is not None:
            assert q_lin.relation.sort_canonical().equals(
                q_ten.relation.sort_canonical()), case
        else:
            assert q_lin.scalar == q_ten.scalar, case


@pytest.mark.parametrize("work_mem", [1 << 30, 64 * 1024])
def test_device_chain_groupby_parity(work_mem):
    """Join→Filter→GroupBy chains on the generic device-resident walk (not
    the fused matcher) agree with the linear path and materialize once."""
    rng = np.random.default_rng(5)
    build, probe = _tables(rng, 3000, 3000, domain=64)
    plan = lambda: GroupBy(
        Filter(Join(Scan(build), Scan(probe), "k"), lambda r: r["w"] > 0),
        "k", {"w": "sum", "b_v": "min"})
    q_lin = Executor(work_mem=work_mem, policy="linear").execute(plan())
    q_ten = Executor(work_mem=work_mem, policy="tensor").execute(plan())
    lin, ten = q_lin.relation, q_ten.relation
    assert set(lin.names) == set(ten.names)
    ol, ot = np.argsort(lin["k"]), np.argsort(ten["k"])
    for name in lin.names:
        np.testing.assert_allclose(lin[name][ol], ten[name][ot],
                                   rtol=1e-9, atol=1e-9, err_msg=name)
    # device-resident chain: the join's scalar capacity sync + root
    # materialization are the ONLY device→host events
    assert q_ten.total_host_syncs <= 2
    ops = [m.op for m in q_ten.metrics]
    assert ops[-1] == "materialize"


def test_fused_single_host_sync_and_metrics():
    rng = np.random.default_rng(7)
    build, probe = _tables(rng, 2048, 2048, bkeys=rng.permutation(2048))
    plan = Aggregate(Sort(Join(Scan(build), Scan(probe), "k"), ["k"]),
                     "b_v", "sum")
    q = Executor(work_mem=1 << 30, policy="tensor").execute(plan)
    assert [m.op for m in q.metrics] == ["fused_pipeline"]
    assert q.total_host_syncs == 1
    assert q.metrics[0].spill.temp_bytes == 0


# ---------------------------------------------------------------------------
# Capacity: device-computed, bucketed, overflow-detecting
# ---------------------------------------------------------------------------

def test_join_capacity_matches_exact_count():
    rng = np.random.default_rng(11)
    bk = rng.integers(0, 37, 5000).astype(np.int64)
    pk = rng.integers(0, 37, 3000).astype(np.int64)
    sk = np.sort(bk)
    exact = int((np.searchsorted(sk, pk, "right")
                 - np.searchsorted(sk, pk, "left")).sum())
    assert join_capacity(bk, pk) == exact
    assert join_capacity(bk[:0], pk) == 0


def test_aligned_join_indices_capacity_overflow():
    """total > capacity is detectable from the returned count; the valid mask
    covers every slot and the clipped gather indices stay in range."""
    bk = jnp.asarray(np.zeros(64, np.int64))  # every probe matches all 64
    pk = jnp.asarray(np.zeros(8, np.int64))
    capacity = 16  # exact need: 512
    b_idx, p_idx, valid, total = aligned_join_indices(bk, pk, capacity)
    assert int(total) == 512
    assert int(total) > capacity
    assert bool(valid.all())
    assert int(b_idx.max()) < 64 and int(p_idx.max()) < 8
    # the host wrapper refuses an insufficient explicit capacity
    build = Relation({"k": np.zeros(64, np.int64), "v": np.arange(64)})
    probe = Relation({"k": np.zeros(8, np.int64), "w": np.arange(8)})
    with pytest.raises(ValueError, match="capacity"):
        tensor_join(build, probe, "k", capacity=capacity)


def test_fused_capacity_overflow_recovers():
    """The optimistic capacity bucket (sample-based) can underestimate under
    skew the sample misses; the driver must re-run at the exact bucket and
    still return the right answer."""
    rng = np.random.default_rng(13)
    # first 65536-row sample looks unique; the tail repeats one key 200x
    n = 70000
    bk = np.arange(n, dtype=np.int64)
    bk[65536:65736] = 1  # duplicates hidden from the sample
    build = Relation({"k": bk, "v": rng.integers(0, 9, n).astype(np.int64)})
    probe = Relation({"k": np.ones(4096, np.int64),
                      "w": rng.integers(0, 9, 4096).astype(np.int64)})
    plan = lambda: Aggregate(Sort(Join(Scan(build), Scan(probe), "k"), ["k"]),
                             "b_v", "sum")
    q_lin = Executor(work_mem=1 << 30, policy="linear").execute(plan())
    q_ten = Executor(work_mem=1 << 30, policy="tensor").execute(plan())
    assert q_lin.scalar == q_ten.scalar


def test_pipeline_compile_cache_bucketing():
    """Shape bucketing prevents recompile churn: queries with drifting row
    counts inside one power-of-two bucket reuse the SAME compiled program."""
    pipeline_cache_clear()
    rng = np.random.default_rng(17)
    for n in (900, 1000, 1024, 770):  # all bucket to 1024
        assert capacity_bucket(n) == 1024
        build, probe = _tables(rng, n, n, bkeys=rng.permutation(n))
        plan = Aggregate(Sort(Join(Scan(build), Scan(probe), "k"), ["k"]),
                         "b_v", "sum")
        Executor(work_mem=1 << 30, policy="tensor").execute(plan)
    info = pipeline_cache_info()
    assert info["misses"] == 1, info  # ONE compile for the whole bucket
    assert info["hits"] == 3, info


# ---------------------------------------------------------------------------
# Device-resident relation mechanics
# ---------------------------------------------------------------------------

def test_device_relation_lazy_gather_and_single_fetch():
    rng = np.random.default_rng(19)
    rel = Relation({"a": rng.integers(0, 9, 100).astype(np.int64),
                    "b": rng.integers(0, 9, 100).astype(np.int64)})
    dev = DeviceRelation.from_host(rel)
    idx = jnp.asarray(np.arange(99, -1, -1))
    lazy = dev.take_lazy(idx).take_lazy(idx)  # double reversal == identity
    assert lazy.columns["a"].gather is not None  # still pending
    assert lazy.to_host().equals(rel)


def test_device_join_sort_matches_host_ops():
    rng = np.random.default_rng(23)
    build, probe = _tables(rng, 1500, 2000, domain=40)
    d_out, m = tensor_join_device(DeviceRelation.from_host(build),
                                  DeviceRelation.from_host(probe), "k")
    assert m.host_syncs == 1  # the scalar capacity sync only
    d_sorted, ms = tensor_sort_device(d_out, ["k", "w"])
    assert ms.host_syncs == 0
    got = d_sorted.to_host()
    want, _ = hash_join_linear(build, probe, "k", 1 << 30)
    assert got.sort_canonical().equals(want.sort_canonical())
    want_sorted, _ = sort_linear(want, ["k", "w"], 1 << 30)
    for c in ("k", "w"):  # identical sort order on key columns
        np.testing.assert_array_equal(got[c], want_sorted[c])


def test_group_aggregate_device_masked_rows_at_dtype_max():
    """A valid row keyed at int64 max must keep its own group even when
    masked rows exist (regression: sentinel remap used to merge them)."""
    kmax = np.iinfo(np.int64).max
    rel = Relation({"k": np.array([5, 7, kmax], np.int64),
                    "v": np.array([1, 999, 100], np.int64)})
    dev = DeviceRelation.from_host(rel).mask_and(
        jnp.asarray([True, False, True]))
    out, _ = group_aggregate_device(dev, "k", {"v": "sum"})
    host = out.to_host()
    assert sorted(host["k"].tolist()) == [5, kmax]
    got = dict(zip(host["k"].tolist(), host["sum_v"].tolist()))
    assert got[5] == 1.0 and got[kmax] == 100.0


def test_device_join_explicit_capacity_overflow_raises():
    """tensor_join_device must refuse an insufficient explicit capacity
    rather than silently truncate (regression)."""
    build = DeviceRelation.from_host(
        Relation({"k": np.zeros(64, np.int64), "v": np.arange(64)}))
    probe = DeviceRelation.from_host(
        Relation({"k": np.zeros(8, np.int64), "w": np.arange(8)}))
    with pytest.raises(ValueError, match="capacity"):
        tensor_join_device(build, probe, "k", capacity=16)


def test_pipeline_cache_hits_across_recreated_predicates():
    """Identical filter lambdas rebuilt per query (the normal plan-building
    pattern) must hit the compile cache, not grow it (regression: keyed on
    id(fn))."""
    pipeline_cache_clear()
    rng = np.random.default_rng(53)
    build, probe = _tables(rng, 512, 512, bkeys=rng.permutation(512))
    for _ in range(3):
        plan = Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                     lambda r: r["w"] > 0), ["k"]),
                         "b_v", "sum")
        Executor(work_mem=1 << 30, policy="tensor").execute(plan)
    info = pipeline_cache_info()
    assert info["misses"] == 1 and info["hits"] == 2, info
    # distinct captured values are distinct predicates — no stale reuse
    results = []
    for cut in (10, 80):
        plan = Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                     lambda r: r["w"] > cut), ["k"]),
                         "b_v", "count")
        results.append(
            Executor(work_mem=1 << 30, policy="tensor").execute(plan).scalar)
    assert results[0] > results[1]  # looser cut keeps more rows


def test_group_aggregate_device_masked_rows():
    rng = np.random.default_rng(29)
    rel = Relation({"k": rng.integers(0, 8, 500).astype(np.int64),
                    "v": rng.integers(-50, 50, 500).astype(np.int64)})
    keep = rng.random(500) < 0.5
    dev = DeviceRelation.from_host(rel).mask_and(jnp.asarray(keep))
    out, m = group_aggregate_device(dev, "k", {"v": "sum"})
    assert m.host_syncs == 0
    host = out.to_host()
    want, _ = group_aggregate_linear(
        Relation({k: v[keep] for k, v in rel.columns.items()}),
        "k", {"v": "sum"}, 1 << 30)
    assert host.sort_canonical().equals(want.sort_canonical())


# ---------------------------------------------------------------------------
# Pallas kernels wired into the engine (interpret fallback on CPU)
# ---------------------------------------------------------------------------

def test_pallas_segment_sum_padded_arbitrary_n():
    from repro.kernels.segment_join.ops import segment_sum
    rng = np.random.default_rng(31)
    for n in (100, 1000, 2048, 3000):  # incl. non-multiples of the tile
        seg = jnp.asarray(rng.integers(0, 32, n), jnp.int32)
        val = jnp.asarray(rng.normal(size=n), jnp.float32)
        got = segment_sum(seg, val, 32, interpret=True)
        want = np.zeros(32, np.float32)
        np.add.at(want, np.asarray(seg), np.asarray(val))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_pallas_multikey_sort_padded_matches_lexsort():
    from repro.kernels.multikey_sort.ops import multikey_sort_lsd_padded
    rng = np.random.default_rng(37)
    for n in (1000, 1024, 2500):
        cols = tuple(jnp.asarray(rng.integers(0, 9, n), jnp.int32)
                     for _ in range(2))
        perm = np.asarray(multikey_sort_lsd_padded(cols, tile=256,
                                                   interpret=True))
        ref = np.lexsort([np.asarray(c) for c in cols[::-1]])
        np.testing.assert_array_equal(perm, ref)


def test_engine_parity_with_pallas_forced(monkeypatch):
    """REPRO_PALLAS=1 routes the engine's segment/sort inner loops through
    the Pallas kernels (interpret mode on CPU) with identical results."""
    monkeypatch.setenv("REPRO_PALLAS", "1")
    rng = np.random.default_rng(41)
    rel = Relation({"k": rng.integers(0, 16, 512).astype(np.int64),
                    "v": rng.integers(-9, 9, 512).astype(np.int64)})
    from repro.core import group_aggregate_tensor
    ten, _ = group_aggregate_tensor(rel, "k", {"v": "sum"})
    lin, _ = group_aggregate_linear(rel, "k", {"v": "sum"}, 1 << 30)
    assert ten.sort_canonical().equals(lin.sort_canonical())
    # int32 sort keys dispatch to the bitonic tile kernel
    from repro.core.tensor_engine import sort_perm_device
    keys = (jnp.asarray(rng.integers(0, 7, 300), jnp.int32),)
    perm = np.asarray(sort_perm_device(keys))
    np.testing.assert_array_equal(np.asarray(keys[0])[perm],
                                  np.sort(np.asarray(keys[0])))


# ---------------------------------------------------------------------------
# Fused join-aggregate dtype contract (satellite: no mixed f64/f32 sides)
# ---------------------------------------------------------------------------

def test_join_aggregate_dtype_precision():
    """Σ(b·p) must not truncate either side to float32: values near 2^25
    would lose low bits.  Both sides now contract at one explicit dtype."""
    n, dom = 256, 16
    rng = np.random.default_rng(43)
    base = 1 << 25
    bv = (base + rng.integers(0, 7, n)).astype(np.float64)
    pv = (base + rng.integers(0, 7, n)).astype(np.float64)
    bk = rng.integers(0, dom, n).astype(np.int64)
    pk = rng.integers(0, dom, n).astype(np.int64)
    build = Relation({"k": bk, "v": bv})
    probe = Relation({"k": pk, "w": pv})
    out, _ = tensor_join_aggregate(build, probe, "k", "v", "w", key_domain=dom)
    # exact reference in python ints over the explicit join
    want_prod = want_add = want_cnt = 0
    for d in range(dom):
        bs = bv[bk == d]
        ps = pv[pk == d]
        want_cnt += len(bs) * len(ps)
        want_add += int(bs.sum()) * len(ps) + int(ps.sum()) * len(bs)
        want_prod += int(bs.sum()) * int(ps.sum())
    assert out["count"] == want_cnt
    np.testing.assert_allclose(out["sum_add"], want_add, rtol=1e-12)
    np.testing.assert_allclose(out["sum_prod"], want_prod, rtol=1e-12)
    # float32 truncation of either side would already be visible here:
    f32_loss = abs(float(np.float32(base + 3)) * n * n - want_prod)
    assert f32_loss > 0  # the test data genuinely exercises the lost bits


# ---------------------------------------------------------------------------
# Error/edge semantics parity (regression coverage from review)
# ---------------------------------------------------------------------------

def test_min_over_zero_match_join_raises_like_linear():
    """min/max over a zero-match (non-empty inputs) join must error on the
    tensor paths too, never return the sentinel fill value."""
    build = Relation({"k": np.arange(100, 200, dtype=np.int64),
                      "v": np.arange(100, dtype=np.int64)})
    probe = Relation({"k": np.arange(0, 50, dtype=np.int64),
                      "w": np.arange(50, dtype=np.int64)})
    for mk in [lambda: Aggregate(Join(Scan(build), Scan(probe), "k"),
                                 "b_v", "min"),
               lambda: Aggregate(Sort(Join(Scan(build), Scan(probe), "k"),
                                      ["k"]), "w", "max")]:
        with pytest.raises(ValueError):
            Executor(work_mem=1 << 30, policy="linear").execute(mk())
        with pytest.raises(ValueError):
            Executor(work_mem=1 << 30, policy="tensor").execute(mk())
        # sum/count stay well-defined (0) on both paths
    q = Executor(work_mem=1 << 30, policy="tensor").execute(
        Aggregate(Join(Scan(build), Scan(probe), "k"), "b_v", "sum"))
    assert q.scalar == 0.0


_GLOBAL_CUT = 3


def test_predicate_cache_tracks_global_captures():
    """Changing a module global referenced by the predicate must NOT reuse
    the stale compiled filter program (regression: globals missing from the
    cache key)."""
    global _GLOBAL_CUT
    rng = np.random.default_rng(59)
    build, probe = _tables(rng, 256, 256, bkeys=rng.permutation(256))
    def run():
        plan = Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                     lambda r: r["w"] > _GLOBAL_CUT), ["k"]),
                         "b_v", "count")
        return Executor(work_mem=1 << 30, policy="tensor").execute(plan).scalar
    _GLOBAL_CUT = -1000
    loose = run()
    _GLOBAL_CUT = 1000
    tight = run()
    assert loose > 0 and tight == 0.0, (loose, tight)


def test_fused_preserves_key_column_dtype_and_values():
    """Fused results must serve the ORIGINAL key column — same dtype (int32
    stays int32) and same values (float keys not truncated) as the unfused
    paths (regression: coerced int64 upload leaked into the output)."""
    rng = np.random.default_rng(67)
    build = Relation({"k": np.arange(64, dtype=np.int32),
                      "v": rng.integers(0, 9, 64).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, 64, 100).astype(np.int32),
                      "w": rng.integers(0, 9, 100).astype(np.int64)})
    plan = lambda: Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"])
    fused = Executor(work_mem=1 << 30, policy="tensor").execute(plan())
    unfused = Executor(work_mem=1 << 30, policy="tensor",
                       fuse=False).execute(plan())
    assert fused.relation["k"].dtype == unfused.relation["k"].dtype
    assert fused.relation.sort_canonical().equals(
        unfused.relation.sort_canonical())
    # float keys: join coerces coordinates, output keeps the float values
    buildf = Relation({"k": np.array([0.5, 2.5]),
                       "v": np.array([1, 2], np.int64)})
    probef = Relation({"k": np.array([0.25, 2.75]),
                       "w": np.array([3, 4], np.int64)})
    planf = lambda: Sort(Join(Scan(buildf), Scan(probef), "k"), ["k"])
    ff = Executor(work_mem=1 << 30, policy="tensor").execute(planf())
    uf = Executor(work_mem=1 << 30, policy="tensor", fuse=False).execute(planf())
    assert ff.relation.sort_canonical().equals(uf.relation.sort_canonical())
    assert set(np.asarray(ff.relation["k"]).tolist()) <= {0.25, 2.75}


def test_predicate_cache_identity_fallback_for_mutable_captures():
    """A predicate reading through a mutable captured object must not hit a
    stale compiled program when the plan is rebuilt (regression: identity-
    hashed captures were value-cached)."""
    class Cfg:
        thr = 0
    cfg = Cfg()
    rng = np.random.default_rng(71)
    build, probe = _tables(rng, 256, 256, bkeys=rng.permutation(256))
    def run():
        plan = Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                     lambda r: r["w"] > cfg.thr), ["k"]),
                         "b_v", "count")
        return Executor(work_mem=1 << 30, policy="tensor").execute(plan).scalar
    cfg.thr = -1000
    loose = run()
    cfg.thr = 1000
    tight = run()
    assert loose > 0 and tight == 0.0, (loose, tight)


def test_predicate_cache_rebound_cell_is_new_entry():
    """Rebinding a closure cell between queries (same lambda OBJECT) must
    produce a different pipeline-cache entry — the captured value is traced
    into the compiled program, so reusing the old entry would silently
    filter with the stale constant (regression)."""
    pipeline_cache_clear()
    rng = np.random.default_rng(73)
    build, probe = _tables(rng, 256, 256, bkeys=rng.permutation(256))

    cut = 2.0
    pred = lambda r: r["w"] > cut  # ONE lambda, cell rebound between runs

    def run():
        plan = Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                     pred), ["k"]), "b_v", "count")
        return Executor(work_mem=1 << 30, policy="tensor").execute(plan).scalar

    loose = run()
    assert pipeline_cache_info()["misses"] == 1
    cut = 80.0
    tight = run()
    assert pipeline_cache_info()["misses"] == 2  # rebound float → new entry
    assert loose > tight, (loose, tight)
    cut = 2.0
    again = run()  # rebinding BACK hits the first entry with the right value
    assert pipeline_cache_info()["misses"] == 2
    assert again == loose


def test_predicate_cache_type_tags_captured_values():
    """``1 == 1.0 == True`` as dict keys: a captured value rebound across
    equal-comparing types must be a distinct cache entry, not a collision
    resurrecting the program traced with the other dtype (regression)."""
    pipeline_cache_clear()
    rng = np.random.default_rng(79)
    build, probe = _tables(rng, 256, 256, bkeys=rng.permutation(256))

    cut = 1
    pred = lambda r: r["w"] > cut

    def run():
        plan = Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                     pred), ["k"]), "b_v", "count")
        return Executor(work_mem=1 << 30, policy="tensor").execute(plan).scalar

    r_int = run()
    cut = 1.0
    r_float = run()
    cut = True
    r_bool = run()
    assert pipeline_cache_info()["misses"] == 3  # int / float / bool distinct
    assert r_int == r_float == r_bool  # same comparison semantics, though


def test_ir_predicates_skip_bytecode_keying():
    """Expr-built filters cache by their canonical token: two structurally
    equal expressions built at different source locations share ONE compiled
    program (bytecode keying could never see through source location)."""
    from repro.core import col

    pipeline_cache_clear()
    rng = np.random.default_rng(83)
    build, probe = _tables(rng, 256, 256, bkeys=rng.permutation(256))

    def make_a():
        return (col("w") > 0) & col("k").isin([1, 2, 3])

    def make_b():  # different lines, same meaning
        lhs = col("w") > 0
        rhs = col("k").isin([1, 2, 3])
        return lhs & rhs

    results = []
    for mk in (make_a, make_b):
        plan = Aggregate(Sort(Filter(Join(Scan(build), Scan(probe), "k"),
                                     mk()), ["k"]), "b_v", "count")
        results.append(
            Executor(work_mem=1 << 30, policy="tensor").execute(plan).scalar)
    info = pipeline_cache_info()
    assert info["misses"] == 1 and info["hits"] == 1, info
    assert results[0] == results[1]


def test_filter_only_join_fragment_fuses():
    """Filter(Join(Scan, Scan)) — the shape pushed-down filters produce in
    multi-join chains — runs as ONE fused program with a single sync."""
    rng = np.random.default_rng(89)
    build, probe = _tables(rng, 512, 512, bkeys=rng.permutation(512))
    plan = lambda: Filter(Join(Scan(build), Scan(probe), "k"),
                          lambda r: r["w"] > 0)
    q = Executor(work_mem=1 << 30, policy="tensor").execute(plan())
    assert [m.op for m in q.metrics] == ["fused_pipeline"]
    assert q.total_host_syncs == 1
    ref = Executor(work_mem=1 << 30, policy="linear").execute(plan())
    assert q.relation.sort_canonical().equals(ref.relation.sort_canonical())


def test_projected_fragment_gathers_subset():
    """Project(Sort(Join)) fuses with the projection folded into the spec:
    only the projected columns cross the device→host boundary."""
    from repro.core import Project, match_fragment

    rng = np.random.default_rng(97)
    build, probe = _tables(rng, 512, 512, domain=32)
    plan = lambda: Project(Sort(Join(Scan(build), Scan(probe), "k"),
                                ["k", "w"]), ["k", "w"])
    frag = match_fragment(plan())
    assert frag is not None and frag[0].project == ("k", "w")
    q = Executor(work_mem=1 << 30, policy="tensor").execute(plan())
    assert [m.op for m in q.metrics] == ["fused_pipeline"]
    assert set(q.relation.names) == {"k", "w"}
    ref = Executor(work_mem=1 << 30, policy="linear").execute(plan())
    assert q.relation.sort_canonical().equals(ref.relation.sort_canonical())


def test_pallas_sort_empty_relation(monkeypatch):
    """REPRO_PALLAS=1 sort of a 0-row relation must return empty, not crash
    in the tile-size arithmetic (regression)."""
    monkeypatch.setenv("REPRO_PALLAS", "1")
    from repro.core import tensor_sort
    rel = Relation({"k": np.zeros(0, np.int32), "p": np.zeros(0, np.int64)})
    out, _ = tensor_sort(rel, ["k"])
    assert len(out) == 0


def test_pallas_segment_sum_empty_input(monkeypatch):
    """REPRO_PALLAS=1 join-aggregate over empty relations must return zeros,
    not divide by a zero tile size (regression)."""
    monkeypatch.setenv("REPRO_PALLAS", "1")
    from repro.kernels.segment_join.ops import segment_sum
    got = segment_sum(jnp.zeros(0, jnp.int32), jnp.zeros(0, jnp.float32), 8,
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(8, np.float32))
    build = Relation({"k": np.zeros(0, np.int64), "v": np.zeros(0)})
    probe = Relation({"k": np.zeros(0, np.int64), "w": np.zeros(0)})
    out, _ = tensor_join_aggregate(build, probe, "k", "v", "w", key_domain=8)
    assert out["count"] == 0.0 and out["sum_prod"] == 0.0


def test_pallas_sort_gate_rejects_uint32():
    from repro.core.tensor_engine import _keys_fit_int32
    assert _keys_fit_int32((jnp.zeros(4, jnp.int32),))
    assert _keys_fit_int32((jnp.zeros(4, jnp.int16),))
    assert not _keys_fit_int32((jnp.zeros(4, jnp.uint32),))  # would wrap
    assert not _keys_fit_int32((jnp.zeros(4, jnp.int64),))
    assert not _keys_fit_int32((jnp.zeros(4, jnp.float32),))


def test_group_aggregate_tensor_float_keys():
    """Seed accepted float group keys by truncating to int64; keep that."""
    from repro.core import group_aggregate_tensor
    rel = Relation({"k": np.array([1.0, 2.0, 1.0, 2.0]),
                    "v": np.array([10, 20, 30, 40], np.int64)})
    ten, _ = group_aggregate_tensor(rel, "k", {"v": "sum"})
    got = dict(zip(ten["k"].tolist(), ten["sum_v"].tolist()))
    assert got == {1: 40.0, 2: 60.0}


def test_untraceable_predicate_fallback_counts_sync():
    """A predicate that cannot trace forces a host materialization mid-
    pipeline; that regime crossing must appear in host_syncs."""
    rng = np.random.default_rng(61)
    build, probe = _tables(rng, 512, 512, domain=32)

    def hostile(r):  # touches a numpy-only attribute: device arrays raise
        _ = r["w"].flags
        return r["w"] % 2 == 0

    plan = lambda: GroupBy(Filter(Join(Scan(build), Scan(probe), "k"),
                                  hostile), "k", {"w": "sum"})
    q_ten = Executor(work_mem=1 << 30, policy="tensor").execute(plan())
    q_lin = Executor(work_mem=1 << 30, policy="linear").execute(plan())
    assert q_ten.relation.sort_canonical().equals(
        q_lin.relation.sort_canonical())
    assert any(m.op == "filter_materialize" and m.host_syncs == 1
               for m in q_ten.metrics)


# ---------------------------------------------------------------------------
# Fragment matcher
# ---------------------------------------------------------------------------

def test_match_fragment_shapes():
    rng = np.random.default_rng(47)
    build, probe = _tables(rng, 100, 100)
    j = Join(Scan(build), Scan(probe), "k")
    assert match_fragment(Sort(j, ["k"])) is not None
    assert match_fragment(Aggregate(Sort(j, ["k"]), "w", "sum")) is not None
    spec, _, _ = match_fragment(
        Aggregate(Sort(Filter(j, lambda r: r["w"] > 0), ["k"]), "w", "sum"))
    assert spec.filter_fn is not None and spec.sort_keys == ("k",)
    # a bare join gains nothing from fusion; deeper trees don't match
    assert match_fragment(j) is None
    assert match_fragment(Sort(Join(Sort(Scan(build), ["k"]), Scan(probe),
                                    "k"), ["k"])) is None
