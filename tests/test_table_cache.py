"""Device-resident base-table cache + key-cardinality sketch (PR 2).

Serving-path contract: repeated queries over unchanged base tables transfer
zero H2D bytes; a mutated relation invalidates its cached device columns and
sketches (fresh transfer, fresh stats); planning does not re-run the 64k-row
``np.unique`` sample per query.
"""
import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Executor,
    Join,
    PathSelector,
    Relation,
    RuntimeProfile,
    Scan,
    Sort,
    capacity_bucket,
    get_device_columns,
    key_stats,
    pending_upload_bytes,
    table_cache_clear,
    table_cache_info,
)


def _tables(n=20_000, seed=0):
    rng = np.random.default_rng(seed)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 1 << 30, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 1 << 30, n).astype(np.int64)})
    return build, probe


def _plan(build, probe):
    return Aggregate(Sort(Join(Scan(build), Scan(probe), "k"), ["k"]),
                     "b_v", "sum")


def test_warm_query_transfers_zero_h2d_bytes():
    build, probe = _tables()
    ex = Executor(work_mem=1 << 20, policy="tensor")
    q1 = ex.execute(_plan(build, probe))
    assert q1.total_h2d_bytes > 0  # cold: both relations cross to the device
    q2 = ex.execute(_plan(build, probe))
    assert q2.total_h2d_bytes == 0  # warm: base tables are device-resident
    assert q2.scalar == q1.scalar


def test_mutated_relation_forces_fresh_transfer():
    """In-place mutation of a cached column → fresh transfer AND the fresh
    data's answer (a stale cache would silently serve the old bytes)."""
    build, probe = _tables(4096, seed=1)
    probe.columns["k"][0] = build.columns["k"][0]  # row 0's match is certain
    ex = Executor(work_mem=1 << 30, policy="tensor")
    q1 = ex.execute(_plan(build, probe))
    assert ex.execute(_plan(build, probe)).total_h2d_bytes == 0
    build.columns["v"][0] += 1_000_000  # element 0 is always token-sampled
    q3 = ex.execute(_plan(build, probe))
    assert q3.total_h2d_bytes > 0
    want = Executor(work_mem=1 << 30, policy="linear").execute(
        _plan(build, probe)).scalar
    assert q3.scalar == want
    assert q3.scalar != q1.scalar


def test_invalidate_device_cache_explicit():
    build, _ = _tables(2048, seed=2)
    bucket = capacity_bucket(len(build))
    full = pending_upload_bytes(build, bucket)
    # packed layouts price the bucket-padded PACKED bytes — strictly less
    # than the two logical int64 columns would cost
    assert 0 < full < bucket * 8 * 2
    get_device_columns(build, bucket)
    assert pending_upload_bytes(build, bucket) == 0
    build.invalidate_device_cache()
    assert pending_upload_bytes(build, bucket) == full


def test_exact_and_bucketed_entries_coexist():
    build, _ = _tables(1000, seed=3)
    _, up_exact = get_device_columns(build, None)
    assert up_exact == build.nbytes()
    _, up_padded = get_device_columns(build, 1024)
    assert up_padded == 1024 * 8 * 2
    # both shapes now warm
    assert get_device_columns(build, None)[1] == 0
    assert get_device_columns(build, 1024)[1] == 0


def test_cache_toggle_disables_residency(monkeypatch):
    monkeypatch.setenv("REPRO_TABLE_CACHE", "0")
    build, probe = _tables(2048, seed=4)
    ex = Executor(work_mem=1 << 30, policy="tensor")
    q1 = ex.execute(_plan(build, probe))
    q2 = ex.execute(_plan(build, probe))
    assert q1.total_h2d_bytes > 0
    assert q2.total_h2d_bytes > 0  # every query re-uploads
    assert q1.scalar == q2.scalar


def test_fingerprint_tracks_column_content():
    build, _ = _tables(512, seed=9)
    f1 = build.fingerprint()
    assert f1 == build.fingerprint()  # stable while untouched
    build.columns["v"][0] += 1  # sampled position
    f2 = build.fingerprint()
    assert f2 != f1
    # only the mutated column's token changed
    changed = [name for (name, t1), (_, t2) in zip(f1, f2) if t1 != t2]
    assert changed == ["v"]


def test_key_stats_cached_and_invalidated():
    build, _ = _tables(4096, seed=5)
    s1 = key_stats(build, "k")
    assert s1.dup == 1.0 and s1.n == 4096  # permutation keys are unique
    assert key_stats(build, "k") is s1  # served from the sketch cache
    build.columns["k"][:] = 7  # constant keys: dup flips to the sample size
    s2 = key_stats(build, "k")
    assert s2 is not s1
    assert s2.card == 1 and s2.kmin == 7 and s2.kmax == 7


def test_choose_join_does_not_resample_per_query(monkeypatch):
    """Satellite regression: the selector used to pay a 65536-row np.unique
    on EVERY choose_join call; now the sketch is computed once per
    (relation, key, content)."""
    calls = []
    orig = np.unique

    def counting_unique(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    monkeypatch.setattr(np, "unique", counting_unique)
    build, probe = _tables(4096, seed=6)
    sel = PathSelector(work_mem=1 << 20, profile=RuntimeProfile())
    for _ in range(5):
        sel.choose_join(build, probe, "k")
    assert len(calls) == 1, f"np.unique ran {len(calls)} times for 5 queries"


def test_counters_track_hits_misses_invalidations():
    table_cache_clear()
    build, _ = _tables(1024, seed=7)
    get_device_columns(build, 1024)
    get_device_columns(build, 1024)
    build.columns["v"][0] ^= 1
    get_device_columns(build, 1024)
    info = table_cache_info()
    assert info["misses"] == 3  # 2 cold + 1 re-upload of the mutated column
    assert info["hits"] == 3    # 2 warm + the unmutated column's third hit
    assert info["invalidations"] == 1
    assert info["h2d_bytes"] == 1024 * 8 * 2 + 1024 * 8  # cold pair + re-upload
