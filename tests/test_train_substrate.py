"""Training substrate: optimizers, checkpointing, compression, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint, Checkpointer)
from repro.train.compression import (apply_error_feedback, dequantize_int8,
                                     init_error_state, quantize_int8)
from repro.train.fault_tolerance import ResilientLoop, plan_mesh
from repro.train.optimizer import adafactor, adamw, global_norm


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 16)),
            "b": jax.random.normal(k2, (16,)),
            "nested": {"u": jax.random.normal(k2, (4, 4, 4))}}


@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizer_reduces_quadratic(make_opt):
    opt = make_opt(lr=0.1)
    params = _toy_params(jax.random.PRNGKey(0))
    target = _toy_params(jax.random.PRNGKey(9))
    state = opt.init(params)

    def loss_fn(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    first = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state, metrics = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 0.2 * first
    assert np.isfinite(float(metrics["grad_norm"]))


def test_optimizer_state_structure_stable():
    """jit-compatibility: update preserves the state pytree structure."""
    opt = adamw()
    params = _toy_params(jax.random.PRNGKey(0))
    state = opt.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    _, new_state, _ = opt.update(grads, state, params)
    assert (jax.tree_util.tree_structure(state)
            == jax.tree_util.tree_structure(new_state))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": _toy_params(jax.random.PRNGKey(1)),
            "step_scalar": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 42, tree)
    assert latest_step(str(tmp_path)) == 42
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_corruption(tmp_path):
    tree = {"w": jnp.ones((4,))}
    for s in (10, 20, 30, 40):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [30, 40]
    # corrupt newest manifest → restore falls back is NOT automatic; but
    # latest_step must skip unreadable manifests
    (tmp_path / "step_00000040" / "manifest.json").write_text("{broken")
    assert latest_step(str(tmp_path)) == 30


def test_int8_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)
    grads = {"g": g}
    err = init_error_state(grads)
    # accumulate the same gradient 50 steps with and without feedback
    naive_sum = np.zeros(256)
    ef_sum = np.zeros(256)
    for _ in range(50):
        q, s = quantize_int8(g)
        naive_sum += np.asarray(dequantize_int8(q, s))
        restored, err = apply_error_feedback(grads, err)
        ef_sum += np.asarray(restored["g"])
    true_sum = np.asarray(g) * 50
    assert np.abs(ef_sum - true_sum).max() < np.abs(naive_sum - true_sum).max()


def test_plan_mesh_elasticity():
    assert plan_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh(256) == ((16, 16), ("data", "model"))
    # losing a host (8 devices): shrink data axis, keep model axis intact
    shape, axes = plan_mesh(248)
    assert axes == ("data", "model") and shape == (15, 16)
    with pytest.raises(ValueError):
        plan_mesh(8)


def test_resilient_loop_recovers_from_failure(tmp_path):
    """A mid-run failure restores the checkpoint and replays data."""
    ckpt = Checkpointer(str(tmp_path), interval=2)
    calls = {"n": 0}

    def step_fn(state, batch):
        return state + batch, float(state)

    def fail_once(step):
        if step == 5 and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("injected node failure")

    def data_factory():
        return iter([1] * 100)

    loop = ResilientLoop(step_fn, ckpt, lambda: {"consumed": 0},
                         lambda s: None, max_retries=2)
    state, report = loop.run(0, data_factory, num_steps=10,
                             fail_hook=fail_once)
    assert report.retries == 1
    assert report.restores == 1
    assert report.steps_run >= 10
