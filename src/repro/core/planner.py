"""Rewrite-based planner: logical IR → chained physical fragments.

This is where *representation timing* becomes a planning decision instead of
an accident of how the user typed the query.  The pipeline:

  1. **Filter pushdown** (:func:`push_filters`) — ``Expr`` conjuncts move
     below every join whose output they don't need, landing directly above
     the lowest join that can serve their columns.  They deliberately stop
     *above* joins rather than sinking into scans: a filter above a
     ``Join(Scan, Scan)`` folds into the fused pipeline's validity mask for
     free, while a filtered scan would be a fresh (device-cache-cold)
     relation every query.  Opaque legacy callables stay where they were.
  2. **Projection pruning** (:func:`prune_columns`) — required columns flow
     root→leaves; scans shrink to the referenced subset via
     :meth:`Relation.select`, whose shared device-cache contract means the
     pruned scan re-uses (and warms) the parent's uploaded columns — H2D
     traffic pays only for columns the query actually reads.
  3. **Multi-key packing** (:func:`pack_pair`) — an ``LJoin`` on several key
     columns lowers to a single-key physical join over a packed ``int64``
     coordinate (range-compressed when the key ranges fit, per-column
     factorized otherwise); the packed column is content-token cached on the
     base relation so repeated queries re-use both the host array and its
     device upload.
  4. **Fragment extraction** (:func:`plan_program`) — each join becomes one
     physical stage shaped ``Join→[Filter]→[Sort]→[Aggregate]`` (the fused
     pipeline's contract), with filters sunk to sit directly above the join;
     a multi-join plan becomes a *chain* of such stages, each independently
     priced by ``PathSelector.choose_fragment`` against the rewritten (not
     the typed) plan and each eligible for fusion.

``plan_program`` accepts logical IR or (via the lowering shim) legacy
physical trees; ``rewrite=False`` skips the optimization rewrites (steps
1–2) for before/after measurement (see ``benchmarks/figures.py::fig10``) —
packing (3) and fragment extraction (4) are structural lowering a multi-key
or multi-join plan cannot execute without, so they always apply.
"""
from __future__ import annotations

import dataclasses
import operator
import threading
from functools import reduce
from typing import Dict, FrozenSet, List, Optional, Tuple

import numpy as np

from .expr import CombinedPredicate, Expr
from .logical import (LAggregate, LFilter, LGroupBy, LJoin, LProject, LScan,
                      LSort, LogicalNode, from_physical, is_scalar,
                      join_schema, schema)
from .relation import Relation, column_token

__all__ = ["plan_program", "push_filters", "prune_columns", "pack_pair",
           "Program", "Stage", "PACK_COL"]

PACK_COL = "__pack__"

# Guards the per-relation packed-column caches: concurrent sessions plan
# multi-key joins over shared base tables, and the eviction sweeps below
# iterate the cache dict (unsafe against a concurrent insert).
_PACK_LOCK = threading.Lock()


# ---------------------------------------------------------------------------
# 1. Filter pushdown
# ---------------------------------------------------------------------------

def _has_join(node) -> bool:
    if isinstance(node, LJoin):
        return True
    child = getattr(node, "child", None)
    return child is not None and _has_join(child)


def _wrap_filters(node, preds):
    exprs = [p for p in preds if isinstance(p, Expr)]
    if exprs:
        node = LFilter(node, reduce(operator.and_, exprs))
    return node


def push_filters(node: LogicalNode, pending: Tuple = ()) -> LogicalNode:
    """Move ``Expr`` filter conjuncts below joins whose output they don't
    reference.  ``pending`` carries conjuncts still traveling downward; they
    re-attach directly above the lowest join (or scan, for single-table
    chains) that serves their columns."""
    pending = list(pending)
    if isinstance(node, LFilter):
        if isinstance(node.predicate, Expr):
            return push_filters(node.child,
                                pending + list(node.predicate.conjuncts()))
        # opaque callable: stays in place; Expr conjuncts commute past it
        return LFilter(push_filters(node.child, tuple(pending)),
                       node.predicate)
    if isinstance(node, (LSort, LProject)):
        # filters commute with (stable) sort and with projection: a filter
        # that sat above a projection only references surviving columns
        return dataclasses.replace(
            node, child=push_filters(node.child, tuple(pending)))
    if isinstance(node, (LGroupBy, LAggregate)):
        # aggregation boundaries: conjuncts from above reference aggregated
        # output names and must not cross
        new = dataclasses.replace(node, child=push_filters(node.child))
        return _wrap_filters(new, pending)
    if isinstance(node, LJoin):
        b_schema = set(schema(node.build))
        p_schema = set(schema(node.probe))
        keep, to_build, to_probe = [], [], []
        for c in pending:
            refs = c.columns()
            # the build side wins b_-named collisions (join naming contract):
            # any ref whose b_-stripped suffix exists on THIS build side is
            # served by THIS join and must not descend into a probe subtree
            # where the same name means a different column
            build_served = {r for r in refs
                            if r.startswith("b_") and r[2:] in b_schema}
            if refs and refs == build_served:
                if _has_join(node.build):
                    to_build.append(c.rename_columns(
                        {r: r[2:] for r in refs}))
                else:
                    keep.append(c)  # lands above THIS join: fusable as-is
            elif (refs <= p_schema and not build_served
                  and _has_join(node.probe)):
                to_probe.append(c)
            else:
                keep.append(c)
        new = LJoin(push_filters(node.build, tuple(to_build)),
                    push_filters(node.probe, tuple(to_probe)), node.on)
        return _wrap_filters(new, keep)
    if isinstance(node, LScan):
        return _wrap_filters(node, pending)
    raise TypeError(f"not a logical node: {node!r}")


# ---------------------------------------------------------------------------
# 2. Projection pruning
# ---------------------------------------------------------------------------

def prune_columns(node: LogicalNode,
                  needed: Optional[FrozenSet[str]] = None) -> LogicalNode:
    """Shrink scans to the columns the plan above actually references.

    ``needed=None`` means "everything" (a relation-valued root serves its
    full schema, matching legacy semantics); scalar aggregates, group-bys
    and explicit projections narrow it on the way down.  An opaque callable
    predicate forces ``None`` below it — it could read anything.
    """
    if isinstance(node, LScan):
        if needed is None:
            return node
        keep = [c for c in node.relation.names if c in needed]
        if not keep or len(keep) == len(node.relation.names):
            return node
        return LScan(node.relation.select(keep), node.name)
    if isinstance(node, LFilter):
        if needed is None or not isinstance(node.predicate, Expr):
            child_needed = None
        else:
            child_needed = needed | node.predicate.columns()
        return LFilter(prune_columns(node.child, child_needed),
                       node.predicate)
    if isinstance(node, LProject):
        cols = (node.columns if needed is None
                else tuple(c for c in node.columns if c in needed)
                or node.columns)
        return LProject(prune_columns(node.child, frozenset(cols)), cols)
    if isinstance(node, LSort):
        child_needed = None if needed is None else needed | set(node.keys)
        return LSort(prune_columns(node.child, child_needed), node.keys)
    if isinstance(node, LAggregate):
        return LAggregate(prune_columns(node.child,
                                        frozenset((node.column,))),
                          node.column, node.fn)
    if isinstance(node, LGroupBy):
        child_needed = frozenset((node.key,)) | set(node.values)
        return LGroupBy(prune_columns(node.child, child_needed), node.key,
                        node.values)
    if isinstance(node, LJoin):
        if needed is None:
            return LJoin(prune_columns(node.build),
                         prune_columns(node.probe), node.on)
        b_schema = set(schema(node.build))
        p_schema = set(schema(node.probe))
        p_needed = ({c for c in needed if c in p_schema}
                    | set(node.on))
        b_needed = ({c[2:] for c in needed
                     if c.startswith("b_") and c[2:] in b_schema}
                    | set(node.on))
        return LJoin(prune_columns(node.build, frozenset(b_needed)),
                     prune_columns(node.probe, frozenset(p_needed)),
                     node.on)
    raise TypeError(f"not a logical node: {node!r}")


# ---------------------------------------------------------------------------
# 3. Multi-key equi-join lowering: key packing
# ---------------------------------------------------------------------------

def _pack_params(build: Relation, probe: Relation, keys) -> Optional[Tuple]:
    """Range-compression parameters shared by both sides, or None when the
    combined key ranges don't fit an int64 coordinate (or keys aren't
    integers).  Reads only the cached key-cardinality sketches."""
    from .table_cache import key_stats

    lows, spans = [], []
    span_prod = 1
    for k in keys:
        if not (np.issubdtype(build[k].dtype, np.integer)
                and np.issubdtype(probe[k].dtype, np.integer)):
            return None
        bs, ps = key_stats(build, k), key_stats(probe, k)
        if bs.n == 0 or ps.n == 0:
            return None
        lo = min(int(bs.kmin), int(ps.kmin))
        hi = max(int(bs.kmax), int(ps.kmax))
        lows.append(lo)
        spans.append(hi - lo + 1)
        span_prod *= spans[-1]
        if span_prod >= 1 << 62:
            return None
    # row-major strides: last key varies fastest
    strides, acc = [0] * len(keys), 1
    for i in range(len(keys) - 1, -1, -1):
        strides[i] = acc
        acc *= spans[i]
    return tuple(zip(keys, lows, strides))


def _packed_column(rel: Relation, params) -> np.ndarray:
    """The packed int64 key coordinate, content-token cached on the relation
    so repeated queries reuse the same array object (and therefore its
    device upload — `column_token` keys on the buffer)."""
    tokens = tuple(column_token(rel[k]) for k, _, _ in params)
    with _PACK_LOCK:
        cache = rel.__dict__.setdefault("_packed_cols", {})
        hit = cache.get(params)
        if hit is not None and hit[0] == tokens:
            return hit[1]
    # the O(N) pack runs OUTSIDE the lock: the lock protects the cache
    # dicts, not the compute, and a rare racing double-pack of the same
    # relation is cheaper than serializing every session's planning
    arr = np.zeros(len(rel), np.int64)
    for k, lo, stride in params:
        arr += (rel[k].astype(np.int64) - lo) * stride
    with _PACK_LOCK:
        hit = cache.get(params)
        if hit is not None and hit[0] == tokens:
            return hit[1]  # a racer finished first; one array wins
        # drifting probe key ranges produce distinct params per query; cap
        # the range-packed entries like the factorized path caps its own
        stale = [k for k in cache if k and k[0] != "factorized"]
        for k in stale[:max(0, len(stale) - 7)]:
            del cache[k]
        cache[params] = (tokens, arr)
    return arr


def _factorized_pack(build: Relation, probe: Relation,
                     keys) -> Tuple[np.ndarray, np.ndarray]:
    """Fallback packing for non-integer or range-overflowing keys: factorize
    each key column jointly across both sides, folding progressively with a
    re-factorization per step so the accumulator range stays bounded.

    The result depends on BOTH sides' content, so it is cached on the build
    relation keyed by (keys, probe identity) with both sides' key-column
    tokens as the staleness check — repeated serving queries skip the
    per-key np.unique passes (and, because the arrays are reused, their
    device uploads), including workloads that alternate one build table
    against several probe tables."""
    keys = tuple(keys)
    probe_tokens = tuple(column_token(probe[k]) for k in keys)
    tokens = (tuple(column_token(build[k]) for k in keys), probe_tokens)
    ck = ("factorized", keys, probe_tokens)
    with _PACK_LOCK:
        cache = build.__dict__.setdefault("_packed_cols", {})
        hit = cache.get(ck)
        if hit is not None and hit[0] == tokens:
            return hit[1]
    # the np.unique factorization passes run OUTSIDE the lock (see
    # _packed_column): a racing duplicate pack beats serialized planning
    nb = len(build)
    acc = np.zeros(nb + len(probe), np.int64)
    for k in keys:
        comb = np.concatenate([np.asarray(build[k]),
                               np.asarray(probe[k])])
        _, inv = np.unique(comb, return_inverse=True)
        merged = acc * (int(inv.max(initial=0)) + 1) + inv
        _, acc = np.unique(merged, return_inverse=True)
        acc = acc.astype(np.int64)
    out = (np.ascontiguousarray(acc[:nb]), np.ascontiguousarray(acc[nb:]))
    with _PACK_LOCK:
        hit = cache.get(ck)
        if hit is not None and hit[0] == tokens:
            return hit[1]
        # per-probe entries let one build table alternate against several
        # probe tables without thrash, but a stream of ad-hoc probes must
        # not grow the build's cache without bound: evict the oldest beyond
        # a small cap
        stale = [k for k in cache if k[0] == "factorized" and k[1] == keys]
        for k in stale[:max(0, len(stale) - 7)]:
            del cache[k]
        cache[ck] = (tokens, out)
    return out


def _with_pack(rel: Relation, arr: np.ndarray) -> Relation:
    aug = rel.select(rel.names)  # shares the device-cache dicts
    aug.columns[PACK_COL] = np.ascontiguousarray(arr)
    return aug


def pack_pair(build: Relation, probe: Relation,
              keys) -> Tuple[Relation, Relation]:
    """Augment both relations with a shared single-column join coordinate
    ``PACK_COL`` such that packed equality ⟺ key-tuple equality."""
    for rel in (build, probe):
        if PACK_COL in rel.names:
            raise ValueError(
                f"column name {PACK_COL!r} is reserved for multi-key join "
                f"packing; rename it before joining on multiple keys")
    params = _pack_params(build, probe, keys)
    if params is not None:
        return (_with_pack(build, _packed_column(build, params)),
                _with_pack(probe, _packed_column(probe, params)))
    bp, pp = _factorized_pack(build, probe, keys)
    return _with_pack(build, bp), _with_pack(probe, pp)


# ---------------------------------------------------------------------------
# 4. Fragment extraction → chained physical stages
# ---------------------------------------------------------------------------

def _merge_preds(preds):
    if len(preds) == 1:
        return preds[0]
    if all(isinstance(p, Expr) for p in preds):
        return reduce(operator.and_, preds)
    return CombinedPredicate(preds)


@dataclasses.dataclass
class Stage:
    """One physical execution unit: a join fragment or a single-table chain.

    ``ops`` is bottom-up; sources are ``("rel", Relation)`` for base tables
    or ``("stage", i)`` for a previous stage's output.
    """

    join: Optional[Tuple[object, object, Tuple[str, ...]]]
    input: Optional[Tuple]
    ops: Tuple

    def build_physical(self, outputs: List[Optional[Relation]]):
        from .executor import (Aggregate, Filter, GroupBy, Join, Project,
                               Scan, Sort)

        def resolve(src):
            return outputs[src[1]] if src[0] == "stage" else src[1]

        if self.join is not None:
            bsrc, psrc, on = self.join
            brel, prel = resolve(bsrc), resolve(psrc)
            if len(on) == 1:
                node = Join(Scan(brel), Scan(prel), on[0])
            else:
                brel, prel = pack_pair(brel, prel, on)
                node = Join(Scan(brel), Scan(prel), PACK_COL)
        else:
            node = Scan(resolve(self.input))
        for op in self.ops:
            kind = op[0]
            if kind == "filter":
                node = Filter(node, op[1])
            elif kind == "sort":
                node = Sort(node, list(op[1]))
            elif kind == "project":
                node = Project(node, list(op[1]))
            elif kind == "group_by":
                node = GroupBy(node, op[1], dict(op[2]))
            elif kind == "agg":
                node = Aggregate(node, op[1], op[2])
            else:
                raise ValueError(kind)
        return node

    def describe(self) -> str:
        if self.join is not None:
            bsrc, psrc, on = self.join
            src = (f"join[{','.join(on)}]("
                   f"{_src_name(bsrc)}, {_src_name(psrc)})")
            if len(on) > 1:
                src += " (packed)"
        else:
            src = f"scan({_src_name(self.input)})"
        parts = [src]
        for op in self.ops:
            if op[0] == "filter":
                parts.append(f"filter({op[1]!r})"
                             if isinstance(op[1], Expr) else "filter(<fn>)")
            elif op[0] == "sort":
                parts.append(f"sort{list(op[1])}")
            elif op[0] == "project":
                parts.append(f"project{list(op[1])}")
            elif op[0] == "group_by":
                parts.append(f"group_by[{op[1]}]{dict(op[2])}")
            elif op[0] == "agg":
                parts.append(f"agg[{op[2]}({op[1]})]")
        return " → ".join(parts)


def _src_name(src) -> str:
    if src[0] == "stage":
        return f"#{src[1]}"
    rel = src[1]
    return f"rel[{len(rel)}x{len(rel.names)}]"


@dataclasses.dataclass
class Program:
    """An ordered chain of physical stages; each stage's output feeds later
    stages by index.  Running a program walks the chain through ONE executor
    so every fragment is priced by the same selector/profile and all metrics
    merge into a single :class:`~repro.core.executor.QueryResult`."""

    stages: List[Stage]
    scalar: bool

    def run(self, executor):
        from .executor import QueryResult

        outputs: List[Optional[Relation]] = []
        metrics, decisions = [], []
        result = None
        for stage in self.stages:
            result = executor.execute(stage.build_physical(outputs))
            metrics.extend(result.metrics)
            decisions.extend(result.decisions)
            outputs.append(result.relation)
        return QueryResult(result.relation, result.scalar, metrics,
                           decisions)

    def explain(self) -> str:
        lines = [f"stage {i}: {s.describe()}"
                 for i, s in enumerate(self.stages)]
        return "\n".join(lines)


def _source(node, stages) -> Tuple:
    if isinstance(node, LScan):
        return ("rel", node.relation)
    return ("stage", _compile_stage(node, stages))


def _compile_stage(node, stages) -> int:
    """Peel the wrapper chain down to this subtree's core (join or scan),
    sink filters to sit directly above the join (the fused-fragment shape),
    and emit one Stage.  Join children that are themselves plan subtrees
    become their own (earlier) stages."""
    wrappers = []
    cur = node
    while isinstance(cur, (LFilter, LSort, LProject, LGroupBy, LAggregate)):
        wrappers.append(cur)
        cur = cur.child
    wrappers.reverse()  # inner (nearest core) → outer

    ops: List[Tuple] = []
    if isinstance(cur, LJoin):
        join = (_source(cur.build, stages), _source(cur.probe, stages),
                tuple(cur.on))
        input_src = None
        # sink filters below sorts/projects (they commute) so the stage
        # matches Join→Filter→Sort→Aggregate; aggregation is a barrier
        sink, rest, barrier = [], [], False
        for w in wrappers:
            if isinstance(w, LFilter) and not barrier:
                sink.append(w.predicate)
            else:
                if isinstance(w, (LGroupBy, LAggregate)):
                    barrier = True
                rest.append(w)
        if sink:
            ops.append(("filter", _merge_preds(sink)))
        wrappers = rest
        if len(cur.on) > 1 and not any(
                isinstance(w, (LGroupBy, LAggregate, LProject))
                for w in wrappers):
            # relation-rooted packed stage: drop the synthetic coordinate
            # and the build side's duplicated key columns at the root (an
            # aggregation/explicit projection root already excludes them)
            wrappers.append(LProject(None, schema(cur)))
    else:
        join = None
        input_src = ("rel", cur.relation)
    for w in wrappers:
        if isinstance(w, LFilter):
            ops.append(("filter", w.predicate))
        elif isinstance(w, LSort):
            ops.append(("sort", tuple(w.keys)))
        elif isinstance(w, LProject):
            ops.append(("project", tuple(w.columns)))
        elif isinstance(w, LGroupBy):
            ops.append(("group_by", w.key, tuple(w.values.items())))
        elif isinstance(w, LAggregate):
            ops.append(("agg", w.column, w.fn))
    stages.append(Stage(join, input_src, tuple(ops)))
    return len(stages) - 1


def plan_program(plan, rewrite: bool = True) -> Program:
    """Plan a logical (or legacy physical) tree into a chained-stage
    physical program.  ``rewrite=False`` skips the pushdown/pruning
    rewrites for A/B measurement; fragment chaining and multi-key packing
    are structural lowering and always apply."""
    from .executor import PHYSICAL_NODES

    if isinstance(plan, PHYSICAL_NODES):
        plan = from_physical(plan)
    if rewrite:
        plan = push_filters(plan)
        plan = prune_columns(plan)
    stages: List[Stage] = []
    _compile_stage(plan, stages)
    return Program(stages, scalar=is_scalar(plan))
