"""Data pipeline: relational preprocessing through the dual-path engine."""
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline, PipelineConfig, batches, prepare_order
from repro.data.synthetic import synth_corpus


def test_corpus_has_duplicates():
    docs = synth_corpus(5000, 1000)
    assert len(np.unique(docs["content_hash"])) < len(docs)


@pytest.mark.parametrize("policy", ["linear", "tensor", "auto"])
def test_prepare_order_policies_agree(policy):
    cfg = PipelineConfig(num_docs=3000, policy=policy, work_mem=64 * 1024)
    rel, metrics, decisions = prepare_order(cfg)
    # dedup: content hashes unique afterwards
    assert len(np.unique(rel["content_hash"])) == len(rel)
    # quality filter applied
    assert rel["quality"].min() >= cfg.min_quality
    # multi-key order: (domain, bucket, length) lexicographic
    d, b, l = rel["domain"], rel["bucket"], rel["length"]
    key = (d.astype(object) * 10**12 + b * 10**6 + l)
    assert np.all(key[:-1] <= key[1:])


def test_policies_produce_identical_order():
    rels = {}
    for policy in ("linear", "tensor"):
        cfg = PipelineConfig(num_docs=3000, policy=policy, work_mem=64 * 1024)
        rel, _, _ = prepare_order(cfg)
        rels[policy] = rel
    assert rels["linear"].sort_canonical().equals(rels["tensor"].sort_canonical())


def test_batches_shape_and_determinism():
    cfg = PipelineConfig(num_docs=2000, seq_len=64, batch_size=4)
    b1 = list(batches(cfg))
    b2 = list(batches(cfg))
    assert len(b1) > 2
    assert b1[0]["tokens"].shape == (4, 64)
    assert b1[0]["labels"].shape == (4, 64)
    np.testing.assert_array_equal(b1[1]["tokens"], b2[1]["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["tokens"][:, 1:], b1[0]["labels"][:, :-1])


def test_pipeline_resume_deterministic():
    cfg = PipelineConfig(num_docs=2000, seq_len=64, batch_size=4)
    p1 = DataPipeline(cfg)
    it = iter(p1)
    consumed = [next(it) for _ in range(3)]
    state = p1.state()
    # fresh pipeline restored from state yields the SAME next batch
    p2 = DataPipeline(cfg)
    p2.restore(state)
    nxt_resumed = next(iter(p2))
    nxt_original = next(it)
    np.testing.assert_array_equal(nxt_resumed["tokens"], nxt_original["tokens"])
