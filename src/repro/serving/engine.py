"""Serving: prefill/decode step factories + a continuous-batching scheduler.

The scheduler orders admitted requests with the relational core's tensor sort
(multi-key: priority, arrival) — the paper's execution path applied to the
serving control plane — and drives the jitted decode step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core import Relation, tensor_sort
from ..models import decode_step, init_cache, prefill

__all__ = ["make_prefill_step", "make_decode_step", "Request", "BatchScheduler",
           "generate"]


def make_prefill_step(cfg: ArchConfig, **fw_kw) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, **fw_kw)
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def step(params, cache, batch):
        return decode_step(params, cfg, cache, batch)
    return step


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] token ids
    max_new_tokens: int
    priority: int = 0
    arrived_s: float = dataclasses.field(default_factory=time.monotonic)
    output: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


class BatchScheduler:
    """Admits up to ``batch_size`` requests; orders the admission queue via the
    tensor execution path (multi-key sort: priority desc, arrival asc)."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self, free_slots: int) -> List[Request]:
        if not self.queue or free_slots <= 0:
            return []
        rel = Relation({
            "neg_priority": np.asarray([-r.priority for r in self.queue], np.int64),
            "arrival_us": np.asarray([int(r.arrived_s * 1e6) for r in self.queue], np.int64),
            "idx": np.arange(len(self.queue), dtype=np.int64),
        })
        ordered, _ = tensor_sort(rel, ["neg_priority", "arrival_us"])
        take = [self.queue[i] for i in ordered["idx"][:free_slots]]
        taken_ids = {r.rid for r in take}
        self.queue = [r for r in self.queue if r.rid not in taken_ids]
        return take


def generate(params, cfg: ArchConfig, prompts: np.ndarray, max_new_tokens: int,
             *, greedy: bool = True, cache_len: Optional[int] = None):
    """Batched greedy generation on CPU (example/e2e-test scale)."""
    B, S = prompts.shape
    total = S + max_new_tokens
    cache_len = cache_len or total
    cache = init_cache(cfg, B, cache_len)
    step = jax.jit(lambda p, c, b: decode_step(p, cfg, c, b))
    tokens = jnp.asarray(prompts, jnp.int32)
    out = []
    last = None
    for t in range(total - 1):
        if t < S:
            tok = tokens[:, t:t + 1]
        else:
            tok = last
            out.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, {"tokens": tok})
        last = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out.append(np.asarray(last)[:, 0])
    return np.stack(out, axis=1)  # [B, max_new_tokens]
