"""Shared benchmark utilities: workload generation + latency collection.

Workloads mirror the paper's setup (§V.A): PK-FK equi-joins (unique build
keys, uniform probe) and multi-attribute sorts over 8-byte integer columns,
measured for wall latency (P50/P95/P99/max), Temp_MB (real temp-file bytes)
and peak working set, across work_mem settings.
"""
from __future__ import annotations

import gc
from typing import Callable, Dict, List

import numpy as np

from repro.core import Relation, latency_stats

ROW_BYTES_JOIN = 16   # key + payload
SORT_KEYS = ["k0", "k1", "k2", "k3"]


def join_tables(n: int, seed: int = 0, probe_factor: int = 1):
    rng = np.random.default_rng(seed)
    build = Relation({
        "k": rng.permutation(n).astype(np.int64),
        "v": rng.integers(0, 1 << 40, n).astype(np.int64),
    })
    probe = Relation({
        "k": rng.integers(0, n, n * probe_factor).astype(np.int64),
        "w": rng.integers(0, 1 << 40, n * probe_factor).astype(np.int64),
    })
    return build, probe


def sort_table(n: int, num_keys: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    cols = {}
    domains = [64, 1 << 16, 1 << 30, 1 << 40]
    for i in range(num_keys):
        cols[f"k{i}"] = rng.integers(0, domains[i % 4], n).astype(np.int64)
    cols["p0"] = rng.integers(0, 1 << 40, n).astype(np.int64)
    cols["p1"] = rng.integers(0, 1 << 40, n).astype(np.int64)
    return Relation(cols)


def measure(fn: Callable[[], object], reps: int = 12, warmup: int = 2) -> Dict:
    """Run fn repeatedly; return latency stats + last metrics object."""
    for _ in range(warmup):
        last = fn()
    samples: List[float] = []
    for _ in range(reps):
        gc.collect()
        last = fn()
        samples.append(last.wall_s if hasattr(last, "wall_s") else last[1].wall_s)
    metrics = last[1] if isinstance(last, tuple) else last
    stats = latency_stats(samples)
    return {"stats": stats, "metrics": metrics}


def emit(name: str, us_per_call: float, derived: Dict) -> None:
    """CSV row per the harness contract: name,us_per_call,derived."""
    derived_s = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{derived_s}", flush=True)
