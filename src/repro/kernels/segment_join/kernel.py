"""Pallas TPU kernel: blocked segment-sum (the fused join-aggregate core).

``tensor_join_aggregate`` (core/tensor_engine) reduces both relations along
the shared key axis and contracts — the join result is never materialized.
The reduction is this kernel: per-tile one-hot masked matmul into a
VMEM-resident [num_segments] accumulator (revisited across all tiles), so a
billion-row aggregate join streams rows exactly once through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_sum_pallas"]


def _segsum_kernel(seg_ref, val_ref, out_ref, *, num_segments):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    seg = seg_ref[...]                      # [tblk] i32
    val = val_ref[...]                      # [tblk] f32
    onehot = jnp.where(
        seg[:, None] == jax.lax.iota(jnp.int32, num_segments)[None, :],
        1.0, 0.0).astype(val.dtype)         # [tblk, S] built in VMEM
    out_ref[...] += jax.lax.dot_general(
        val[None, :], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)[0]


def segment_sum_pallas(seg_ids, values, num_segments: int, *,
                       tblk: int = 2048, interpret: bool = False):
    """seg_ids [N] i32 (< num_segments), values [N] → sums [num_segments]."""
    n = seg_ids.shape[0]
    tblk = min(tblk, n)
    assert n % tblk == 0, (n, tblk)
    kernel = functools.partial(_segsum_kernel, num_segments=num_segments)
    return pl.pallas_call(
        kernel,
        grid=(n // tblk,),
        in_specs=[
            pl.BlockSpec((tblk,), lambda t: (t,)),
            pl.BlockSpec((tblk,), lambda t: (t,)),
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), values.dtype),
        interpret=interpret,
    )(seg_ids, values)
