"""Flash-attention Pallas kernel vs dense-softmax oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _mk(B, S, H, KH, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, D), dtype)
    return q, k, v


def _ref(q, k, v, **kw):
    out = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), **kw)
    return out.transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,H,KH,D", [
    (2, 128, 4, 4, 32),   # MHA
    (1, 256, 8, 2, 16),   # GQA (kv heads via BlockSpec index map)
    (2, 64, 4, 1, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KH, D, dtype):
    q, k, v = _mk(B, S, H, KH, D, dtype)
    out = flash_attention(q, k, v, q_blk=32, kv_blk=64, interpret=True)
    ref = _ref(q, k, v, causal=True).astype(dtype)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("causal,window,cap", [
    (True, 16, None), (False, None, None), (True, None, 30.0)])
def test_flash_attention_variants(causal, window, cap):
    q, k, v = _mk(1, 128, 4, 2, 32, jnp.float32, seed=3)
    out = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                          q_blk=32, kv_blk=32, interpret=True)
    ref = _ref(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_attention():
    """Kernel contract == the model's pure-JAX chunked_attention."""
    from repro.models.attention import chunked_attention
    q, k, v = _mk(2, 128, 8, 4, 32, jnp.float32, seed=7)
    out_kernel = flash_attention(q, k, v, q_blk=64, kv_blk=64, interpret=True)
    out_model = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=2e-5, atol=2e-5)
