"""Execution-time guards: re-check the path decision while it runs.

The ``PathSelector`` prices a plan once, before the first operator runs.
When the estimate that priced it was wrong — a skewed key the duplication
sketch never sampled, a grant squeezed below the quote, stale cost
constants — the query is locked onto the linear spill cliff for its whole
lifetime.  Graefe's robustness maps and Chang's decision-timing work both
argue the fix is not better one-shot estimates but *re-checkable*
decisions: observe the running operator and abandon it when reality
crosses a guard band.

``ExecutionGuard`` is that observer.  It is duck-type compatible with the
``PreemptToken`` protocol the linear operators already poll (``check()``
simply delegates to the wrapped token), and adds explicit *checkpoints*
that the Grace join and external sort call at depth-0 partition
boundaries — the only places where the operator's partial state is a
clean prefix (joined partitions + still-spilled pairs) rather than a
half-built hash table.  At a checkpoint the guard compares elapsed wall
and observed spill/fan-out against the decision's estimates; when drift
crosses the band *and* the priced cost of finishing linear exceeds the
priced cost of a tensor takeover by the hysteresis margin, it raises
:class:`SwitchPoint` carrying everything the executor needs to finish the
operator on the tensor path without losing work: the already-joined
partition results, the still-spilled partition pairs (readable through
the same ``SpillManager``/``TierManager``), and the operator's
``SpillAccount`` so reuse stays on the same byte books.

Like ``PreemptedError``, ``SwitchPoint`` is control flow, not a failure:
it deliberately does not subclass the repo's error taxonomy so retry and
fault-injection machinery never confuse a re-plan with a fault.

A guard fires at most once (``fired`` disarms it) and the takeover path
runs guard-free, so a borderline operator can never oscillate between
paths — the hysteresis margin makes the switch strictly profitable under
the model before it is taken at all.
"""
from __future__ import annotations

import math
import time
from typing import List, Optional, Sequence, Tuple

__all__ = ["SwitchPoint", "ExecutionGuard"]


class SwitchPoint(Exception):
    """Abandon a running linear operator and re-enter the tensor path.

    Raised only from an :class:`ExecutionGuard` checkpoint at a partition
    boundary, where partial state is a loss-free prefix.  Fields:

    ``done``
        Already-joined partition results (list of ``Relation``), in
        partition order.  Empty for sort switches.
    ``pending``
        Remaining work still on the spill device.  For joins: the
        ``(build_path, probe_path, n_build, n_probe)`` pairs written by
        the Grace partitioning pass (``None`` paths mark empty
        partitions).  For sorts: the run paths awaiting merge.
    ``spill``
        The operator's ``SpillAccount``; the executor reads/deletes the
        pending spill through it so the tier books stay balanced.
    ``schema_hint``
        ``(build_schema, probe_schema)`` for joins so an all-empty switch
        still produces a schema-correct result.
    ``rows_done``
        Output rows already produced by the linear prefix.
    ``elapsed_s``
        Wall seconds burned by the abandoned linear attempt up to the
        switch point (attributed to the *pre-switch* path, never the
        takeover path's profile cell).
    ``restart``
        True when the switch fired *mid-partition-pass*: there is no
        reusable prefix yet, ``pending`` holds the partial spill file
        paths to delete, and the executor re-runs the whole operator on
        the tensor path from the base relations (which hit the device
        column cache, so the restart pays no H2D for registered tables).
    """

    def __init__(self, reason: str, *, op: str, done: Optional[List] = None,
                 pending: Optional[Sequence] = None, spill=None,
                 schema_hint: Optional[Tuple] = None, rows_done: int = 0,
                 elapsed_s: float = 0.0, restart: bool = False):
        super().__init__(reason)
        self.reason = reason
        self.op = op
        self.done = done if done is not None else []
        self.pending = list(pending) if pending is not None else []
        self.spill = spill
        self.schema_hint = schema_hint
        self.rows_done = rows_done
        self.elapsed_s = elapsed_s
        self.restart = restart


class ExecutionGuard:
    """Runtime re-check of one linear operator's path decision.

    Constructed by the executor (via ``PathSelector.make_guard``) around
    the estimates the decision was priced with; passed to the operator as
    its ``cancel`` token.  The operator keeps polling ``check()`` exactly
    as it polls a plain ``PreemptToken`` — preemption still works through
    the guard — and additionally calls the ``observe_*`` /
    ``checkpoint*`` hooks at partition boundaries.  All hooks are invoked
    through ``getattr`` duck-typing in the engine, so a bare
    ``PreemptToken`` (or ``None``) remains a valid cancel token.
    """

    def __init__(self, model, *, op: str, t_linear: float, t_tensor: float,
                 predicted_spill_bytes: int, rows_in: int,
                 token=None, enabled: bool = True, allow_restart: bool = True):
        self.model = model
        self.op = op
        self.t_linear = max(t_linear, 1e-9)
        self.t_tensor = max(t_tensor, 0.0)
        self.predicted_spill_bytes = int(predicted_spill_bytes)
        self.rows_in = int(rows_in)
        self.token = token
        self.enabled = enabled
        self.allow_restart = allow_restart
        self.fired = False
        self.checkpoints = 0      # all checkpoint calls (observability)
        self._pair_cps = 0        # pair-boundary checkpoints only
        self._sort_cps = 0        # merge-pass checkpoints only
        self.observed_fanout = 0
        self.observed_depth = 0
        self.start_s = time.perf_counter()
        # elapsed at the first depth-0 boundary (end of the partition /
        # run-formation pass): observed throughput is measured from here
        self._pairs_t0: Optional[float] = None
        self._first_runs = 0  # run count at the first merge boundary
        # elapsed at the first *intra-pass* checkpoint (start of the
        # partition / run-formation write loop)
        self._part_t0: Optional[float] = None

    # -- PreemptToken protocol -------------------------------------------
    def check(self) -> None:
        if self.token is not None:
            self.token.check()

    # -- observations -----------------------------------------------------
    def observe_fanout(self, est_bytes: int, fanout: int, depth: int) -> None:
        """Record the partition geometry the Grace join actually chose."""
        self.observed_fanout = max(self.observed_fanout, int(fanout))
        self.observed_depth = max(self.observed_depth, int(depth) + 1)

    def elapsed(self) -> float:
        return time.perf_counter() - self.start_s

    def _armed(self) -> bool:
        return self.enabled and not self.fired and self.model is not None

    def _drift_ratio(self) -> float:
        """How much slower reality is than the decision's estimate.

        The guard's whole premise is that the model that priced the plan
        was wrong — so re-quoting the remaining linear work with the same
        constants would be wrong by the same factor and the hysteresis
        check could never clear.  The observed wall-vs-estimate ratio is
        the one piece of ground truth the guard owns; scaling the
        remaining-linear quote by it turns ``price_switch`` into an
        observation-corrected comparison (tensor constants are measured
        on-device by calibration and stay trusted as-is).
        """
        return max(1.0, self.elapsed() / self.t_linear)

    def _drifted(self, spill) -> Tuple[bool, str]:
        """Has observed execution left the decision's guard band?"""
        c = self.model.c
        band = 1.0 + c.guard_band
        elapsed = self.elapsed()
        if elapsed > self.t_linear * band:
            return True, (f"wall {elapsed * 1e3:.0f}ms > "
                          f"est {self.t_linear * 1e3:.0f}ms x{band:.2f}")
        written = int(getattr(spill, "bytes_written", 0))
        if written > max(self.predicted_spill_bytes, 1) * band:
            return True, (f"spill {written >> 10}KiB > "
                          f"est {self.predicted_spill_bytes >> 10}KiB x{band:.2f}")
        if self.predicted_spill_bytes == 0 and written > 0:
            return True, f"unpredicted spill {written >> 10}KiB"
        return False, ""

    # -- checkpoints ------------------------------------------------------
    def checkpoint(self, *, done, pending, spill, schema_hint=None) -> None:
        """Grace-join depth-0 partition boundary.

        ``done`` holds the partition results joined so far; ``pending``
        the spilled pairs not yet processed.  Raises :class:`SwitchPoint`
        when drift has crossed the band and the priced takeover wins by
        the hysteresis margin.
        """
        self.checkpoints += 1
        self._pair_cps += 1
        elapsed = self.elapsed()
        if self._pairs_t0 is None:
            self._pairs_t0 = elapsed
        if not self._armed():
            return
        drifted, why = self._drifted(spill)
        if not drifted:
            return
        rows_pending = sum(int(nb) + int(np_) for _b, _p, nb, np_ in pending
                           if _b is not None and _p is not None)
        pairs = sum(1 for _b, _p, nb, np_ in pending
                    if _b is not None and _p is not None)
        live = int(getattr(spill, "live_bytes", 0))
        t_rem, t_switch = self.model.price_switch(
            rows_pending=rows_pending, pending_bytes=live, pairs=pairs)
        t_rem *= self._drift_ratio()
        # once at least one pair has been processed the guard owns a
        # direct throughput measurement; it beats any model quote scaled
        # by whatever the stale constants got wrong (empty partitions are
        # counted on both sides, so the per-pair rate stays unbiased)
        done_pairs = self._pair_cps - 1
        if done_pairs >= 1:
            per_pair = (elapsed - self._pairs_t0) / done_pairs
            t_rem = max(t_rem, per_pair * len(pending))
        if t_switch * self.model.c.guard_hysteresis >= t_rem:
            return
        self.fired = True
        rows_done = sum(len(r) for r in done)
        raise SwitchPoint(
            f"guard: {why}; finish-linear {t_rem * 1e3:.0f}ms > "
            f"switch {t_switch * 1e3:.0f}ms",
            op=self.op, done=list(done), pending=pending, spill=spill,
            schema_hint=schema_hint, rows_done=rows_done,
            elapsed_s=self.elapsed())

    def checkpoint_partition(self, *, rows_done, rows_total, files,
                             spill) -> None:
        """Intra-pass checkpoint inside the partition / run-formation loop.

        By the first pair boundary the whole partitioning pass is sunk
        cost; when the decision was badly mispriced the profitable moment
        to abandon is *during* that pass.  There is no reusable prefix
        mid-pass, so a fire here is a ``restart``: the executor deletes
        the partial spill ``files`` and re-runs the operator on the
        tensor path from the base relations.  Pricing is observation-led:
        the measured write-loop throughput extrapolates the rest of the
        pass, and the follow-on phase (probe / merge) re-reads every byte
        and does the real work on top, so it is floored at one more full
        pass equivalent.  The model quote, drift-corrected, is kept as a
        second floor.
        """
        self.checkpoints += 1
        elapsed = self.elapsed()
        if self._part_t0 is None:
            self._part_t0 = elapsed
        if not self._armed() or not self.allow_restart:
            return
        if rows_done <= 0 or rows_total <= 0:
            return
        drifted, why = self._drifted(spill)
        if not drifted:
            return
        t_rem, t_switch = self.model.price_switch(
            rows_pending=rows_total, pending_bytes=0, pairs=0)
        t_rem *= self._drift_ratio()
        span = elapsed - self._part_t0
        if span > 0:
            per_row = span / rows_done
            t_rem = max(t_rem, per_row * (rows_total - rows_done)
                        + per_row * rows_total)
        if t_switch * self.model.c.guard_hysteresis >= t_rem:
            return
        self.fired = True
        raise SwitchPoint(
            f"guard: {why}; finish-linear {t_rem * 1e3:.0f}ms > "
            f"restart {t_switch * 1e3:.0f}ms",
            op=self.op, done=None, pending=files, spill=spill,
            elapsed_s=self.elapsed(), restart=True)

    def checkpoint_sort(self, *, pending, spill) -> None:
        """External-sort merge-pass boundary.

        Sort has no reusable partial order across paths, so a fired guard
        abandons the runs outright: ``pending`` carries the still-live
        run paths for the executor to delete (balancing the spill books)
        before the tensor sort re-runs from the base relation.
        """
        self.checkpoints += 1
        self._sort_cps += 1
        elapsed = self.elapsed()
        runs = len(pending)
        if self._pairs_t0 is None:
            self._pairs_t0 = elapsed
            self._first_runs = runs
        if not self._armed():
            return
        drifted, why = self._drifted(spill)
        if not drifted:
            return
        live = int(getattr(spill, "live_bytes", 0))
        t_rem, t_switch = self.model.price_switch(
            rows_pending=self.rows_in, pending_bytes=live, pairs=0)
        t_rem *= self._drift_ratio()
        # after one full merge pass the guard has a measured per-pass cost
        # and an observed run-shrink factor; remaining passes follow from
        # the run count still on disk (every pass touches all bytes, so
        # per-pass cost is stable across passes)
        passes_done = self._sort_cps - 1
        if passes_done >= 1 and runs > 1 and self._first_runs > runs:
            per_pass = (elapsed - self._pairs_t0) / passes_done
            shrink = max(2.0,
                         (self._first_runs / runs) ** (1.0 / passes_done))
            rem_passes = math.ceil(math.log(runs) / math.log(shrink))
            t_rem = max(t_rem, per_pass * max(1, rem_passes))
        if t_switch * self.model.c.guard_hysteresis >= t_rem:
            return
        self.fired = True
        raise SwitchPoint(
            f"guard: {why}; finish-linear {t_rem * 1e3:.0f}ms > "
            f"switch {t_switch * 1e3:.0f}ms",
            op=self.op, done=None, pending=pending, spill=spill,
            elapsed_s=self.elapsed())

    def observe_fragment(self, total: int, capacity: int) -> None:
        """Fused-fragment capacity overflow: observed join fan-out.

        The fused path's optimistic capacity bucket is itself an estimate;
        an overflow is the device telling us the actual fan-out.  The
        guard records it and — only when the priced linear fragment beats
        the cost of re-running the fused program at the exact bucket by
        the hysteresis margin — abandons the retry loop so the executor's
        generic walk re-prices with ground truth.  In practice the retry
        almost always wins (the observation is still recorded for the
        profile); the escape hatch exists for the pathological corner.
        """
        self.observed_fanout = max(self.observed_fanout,
                                   int(total) // max(1, int(capacity)) + 1)
        if not self._armed():
            return
        c = self.model.c
        t_retry = (c.fused_fixed_cost + c.fused_row_cost * max(0, int(total))
                   + c.switch_fixed_cost)
        if self.t_linear * c.guard_hysteresis < t_retry:
            self.fired = True
            raise SwitchPoint(
                f"guard: fragment overflow total={total} capacity={capacity}; "
                f"retry {t_retry * 1e3:.1f}ms > linear "
                f"{self.t_linear * 1e3:.1f}ms", op="fused_pipeline",
                elapsed_s=self.elapsed())
