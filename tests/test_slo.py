"""SLO primitives: tenant contracts and open-loop arrival processes."""
import dataclasses

import numpy as np
import pytest

from repro.core import ArrivalProcess, TenantClass


# -- TenantClass -------------------------------------------------------------

def test_tenant_defaults():
    t = TenantClass("be", deadline_s=0.5)
    assert t.priority == 0 and t.sheddable


def test_tenant_rejects_nonpositive_deadline():
    with pytest.raises(ValueError):
        TenantClass("x", deadline_s=0.0)
    with pytest.raises(ValueError):
        TenantClass("x", deadline_s=-1.0)


def test_tenant_is_frozen():
    t = TenantClass("prem", deadline_s=1.0, priority=2, sheddable=False)
    with pytest.raises(dataclasses.FrozenInstanceError):
        t.priority = 5


# -- ArrivalProcess ----------------------------------------------------------

def test_poisson_times_sorted_and_in_range():
    ts = ArrivalProcess(rate_qps=50, seed=1).times(10.0)
    assert len(ts) > 0
    assert np.all(np.diff(ts) >= 0)
    assert ts[0] >= 0.0 and ts[-1] < 10.0


def test_times_are_seeded():
    a = ArrivalProcess(rate_qps=20, seed=7).times(5.0)
    b = ArrivalProcess(rate_qps=20, seed=7).times(5.0)
    c = ArrivalProcess(rate_qps=20, seed=8).times(5.0)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_poisson_rate_is_roughly_honored():
    # long window + fixed seed: deterministic, so a tight-ish band is safe
    ts = ArrivalProcess(rate_qps=100, seed=3).times(50.0)
    assert 0.9 * 5000 < len(ts) < 1.1 * 5000


def test_zero_rate_is_silent():
    assert len(ArrivalProcess(rate_qps=0.0, seed=0).times(5.0)) == 0


def test_phases_burst_and_gap():
    # calm 2s @ 5qps, storm 1s @ 200qps, silence 2s @ 0
    ap = ArrivalProcess(phases=[(2.0, 5), (1.0, 200), (2.0, 0)], seed=2)
    ts = ap.times(5.0)
    calm = np.sum(ts < 2.0)
    storm = np.sum((ts >= 2.0) & (ts < 3.0))
    silent = np.sum(ts >= 3.0)
    assert storm > 5 * calm  # the burst dominates
    assert silent == 0       # zero-rate phase generates nothing
    assert storm > 100


def test_phases_cycle_past_their_total():
    # 1s on / 1s off cycled over 6s -> arrivals only in even-second windows
    ts = ArrivalProcess(phases=[(1.0, 50), (1.0, 0)], seed=4).times(6.0)
    assert len(ts) > 0
    assert np.all((ts.astype(np.int64) % 2) == 0)


def test_phase_validation():
    with pytest.raises(ValueError):
        ArrivalProcess(phases=[])
    with pytest.raises(ValueError):
        ArrivalProcess(phases=[(0.0, 5)])
    with pytest.raises(ValueError):
        ArrivalProcess(phases=[(1.0, -2)])
    with pytest.raises(ValueError):
        ArrivalProcess(rate_qps=-1)


def test_max_n_guard_raises_instead_of_truncating():
    with pytest.raises(ValueError):
        ArrivalProcess(rate_qps=1e6, seed=0).times(10.0, max_n=1000)
