"""Pallas kernel validation: interpret=True vs pure-jnp oracles, with
shape/dtype sweeps (assignment requirement: per kernel, sweep shapes/dtypes
and assert_allclose against the ref.py oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moe_dispatch.ops import combine, dispatch, moe_dispatch_pallas
from repro.kernels.moe_dispatch.ref import combine_ref, dispatch_ref
from repro.kernels.multikey_sort.ops import multikey_sort_lsd, tile_sort
from repro.kernels.multikey_sort.ref import tile_sort_ref
from repro.kernels.segment_join.ops import (join_aggregate_kernel,
                                            radix_hash_probe, radix_partition,
                                            segment_sum)
from repro.kernels.segment_join.ref import (radix_hash_probe_ref,
                                            radix_partition_ref,
                                            segment_sum_ref)


# ---------------------------------------------------------------------------
# moe_dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,d,E,C", [
    (256, 128, 4, 64),
    (512, 256, 8, 128),
    (1024, 128, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_dispatch_sweep(T, d, E, C, dtype):
    rng = np.random.default_rng(T + E)
    x = jnp.asarray(rng.normal(size=(T, d)), dtype)
    eidx = jnp.asarray(rng.integers(0, E, T), jnp.int32)
    slot = jnp.asarray(rng.integers(0, C + C // 4, T), jnp.int32)  # overflow mix
    w = jnp.asarray(rng.random(T), jnp.float32)
    buf = dispatch(x, eidx, slot, E, C, interpret=True)
    buf_r = dispatch_ref(x, eidx, slot, E, C)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(buf, np.float32),
                               np.asarray(buf_r, np.float32), rtol=tol, atol=tol)
    y = combine(buf_r, eidx, slot, w, interpret=True)
    y_r = combine_ref(buf_r, eidx, slot, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32), rtol=tol, atol=tol)


def test_moe_dispatch_matches_model_einsum_path():
    """The kernel path reproduces the model's einsum dispatch end to end."""
    from repro.configs import get_smoke_config
    from repro.models.moe import (_dispatch_einsum, _expert_ffn, _route,
                                  capacity_per_expert, init_moe)
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    params = init_moe(jax.random.PRNGKey(0), cfg)
    T = 128
    x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model), jnp.float32)
    topk_idx, topk_w, _ = _route(params, x, cfg)
    cap = capacity_per_expert(T, cfg.num_experts, cfg.experts_per_token,
                              cfg.capacity_factor)
    y_einsum = _dispatch_einsum(params, x, topk_idx, topk_w, cfg, cap)
    y_kernel = moe_dispatch_pallas(params, x, topk_idx, topk_w, cfg, cap,
                                   _expert_ffn, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_einsum),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# multikey_sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,tile", [(256, 64), (1024, 256), (2048, 2048)])
@pytest.mark.parametrize("domain", [8, 1 << 20])
def test_bitonic_tile_sort_sweep(n, tile, domain):
    rng = np.random.default_rng(n + domain)
    keys = jnp.asarray(rng.integers(0, domain, n), jnp.int32)
    vals = jnp.asarray(rng.permutation(n), jnp.int32)
    ks, vs = tile_sort(keys, vals, tile=tile, interpret=True)
    kr, vr = tile_sort_ref(keys, vals, tile)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vr))


def test_bitonic_stability_via_index_payload():
    n = 512
    keys = jnp.zeros(n, jnp.int32)  # all equal keys
    vals = jnp.arange(n, dtype=jnp.int32)
    ks, vs = tile_sort(keys, vals, tile=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(vs), np.arange(n))


@pytest.mark.parametrize("nkeys", [1, 2, 3])
def test_multikey_sort_lsd_matches_lexsort(nkeys):
    rng = np.random.default_rng(nkeys)
    n = 1024
    cols = tuple(jnp.asarray(rng.integers(0, 16, n), jnp.int32)
                 for _ in range(nkeys))
    perm = multikey_sort_lsd(cols, tile=256, interpret=True)
    ref = np.lexsort([np.asarray(c) for c in cols[::-1]])
    got = np.stack([np.asarray(c)[np.asarray(perm)] for c in cols])
    want = np.stack([np.asarray(c)[ref] for c in cols])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# segment_join
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,S,tblk", [(2048, 64, 512), (4096, 256, 1024),
                                      (1024, 1024, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_segment_sum_sweep(n, S, tblk, dtype):
    rng = np.random.default_rng(n + S)
    seg = jnp.asarray(rng.integers(0, S, n), jnp.int32)
    val = jnp.asarray(rng.normal(size=n), dtype)
    got = segment_sum(seg, val, S, tblk=tblk, interpret=True)
    want = segment_sum_ref(seg, val, S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,nbuckets,tblk", [
    (1000, 8, 256),        # non-pow2 n: padded tail rows must stay uncounted
    (2048, 64, 512),
    (4096, 1, 1024),       # single bucket: pure stable identity ordering
    (513, 16, 256),
])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.int64, jnp.int8])
def test_radix_partition_parity(n, nbuckets, tblk, dtype):
    rng = np.random.default_rng(n + nbuckets)
    hi = min(nbuckets, np.iinfo(np.dtype(dtype)).max + 1)
    ids = jnp.asarray(rng.integers(0, hi, n), dtype)
    dest, counts = radix_partition(ids, nbuckets, tblk=tblk, interpret=True)
    dest_r, counts_r = radix_partition_ref(ids, nbuckets)
    np.testing.assert_array_equal(np.asarray(dest), np.asarray(dest_r))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_r))


def test_radix_partition_empty():
    dest, counts = radix_partition(jnp.zeros((0,), jnp.int32), 8,
                                   interpret=True)
    assert dest.shape == (0,)
    np.testing.assert_array_equal(np.asarray(counts), np.zeros(8, np.int32))


def _probe_case(nb, npr, domain, seed, dup=False, dead=False):
    """Codes in [0, domain]; slot ``domain`` is the dead/padding slot."""
    rng = np.random.default_rng(seed)
    hi = domain if not dead else domain + 1
    bk = rng.integers(0, domain, nb) if not dup else \
        rng.integers(0, max(1, domain // 4), nb)
    if not dup and nb <= domain:
        bk = rng.permutation(domain)[:nb]  # unique live build keys
    pk = rng.integers(0, hi, npr)
    if dead:
        bk[rng.random(nb) < 0.1] = domain
    return jnp.asarray(bk, jnp.int32), jnp.asarray(pk, jnp.int32)


@pytest.mark.parametrize("nb,npr,domain", [
    (256, 1024, 512),
    (1000, 3000, 1024),     # non-pow2 sizes
    (2048, 2048, 4096),     # max dense width the dispatcher allows
    (64, 128, 16),          # domain smaller than dblk
])
@pytest.mark.parametrize("dup", [False, True])
@pytest.mark.parametrize("dead", [False, True])
def test_radix_hash_probe_parity(nb, npr, domain, dup, dead):
    bk, pk = _probe_case(nb, npr, domain, nb + npr + domain, dup, dead)
    cnt, row, has_dup = radix_hash_probe(bk, pk, domain, interpret=True)
    cnt_r, row_r, has_dup_r = radix_hash_probe_ref(bk, pk, domain)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))
    np.testing.assert_array_equal(np.asarray(row), np.asarray(row_r))
    assert bool(has_dup) == bool(has_dup_r)


@pytest.mark.parametrize("nb,npr", [(0, 256), (256, 0), (0, 0)])
def test_radix_hash_probe_empty_sides(nb, npr):
    rng = np.random.default_rng(7)
    bk = jnp.asarray(rng.integers(0, 64, nb), jnp.int32)
    pk = jnp.asarray(rng.integers(0, 64, npr), jnp.int32)
    cnt, row, has_dup = radix_hash_probe(bk, pk, 64, interpret=True)
    cnt_r, row_r, has_dup_r = radix_hash_probe_ref(bk, pk, 64)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))
    np.testing.assert_array_equal(np.asarray(row), np.asarray(row_r))
    assert bool(has_dup) == bool(has_dup_r) == False  # noqa: E712


def test_radix_hash_probe_all_dead_and_max_width():
    """Every build row dead (slot == domain) and probes at the dead slot:
    matches at the dead slot are the CALLER's masking problem — the kernel
    must still agree with the oracle bit for bit."""
    domain = 4096
    bk = jnp.full((512,), domain, jnp.int32)
    pk = jnp.concatenate([jnp.full((100,), domain, jnp.int32),
                          jnp.arange(100, dtype=jnp.int32)])
    cnt, row, has_dup = radix_hash_probe(bk, pk, domain, interpret=True)
    cnt_r, row_r, has_dup_r = radix_hash_probe_ref(bk, pk, domain)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))
    np.testing.assert_array_equal(np.asarray(row), np.asarray(row_r))
    # dead-slot pile-ups are NOT live duplicates (has_dup scans [0, domain))
    assert bool(has_dup) == bool(has_dup_r) == False  # noqa: E712


def test_join_aggregate_kernel_matches_core():
    """Kernel-path fused aggregate join == relational-core tensor path."""
    from repro.core import Relation, tensor_join_aggregate
    rng = np.random.default_rng(9)
    nb, npr, dom = 2048, 4096, 128
    bk = rng.integers(0, dom, nb)
    pk = rng.integers(0, dom, npr)
    bv = rng.integers(0, 50, nb).astype(np.float64)
    pv = rng.integers(0, 50, npr).astype(np.float64)
    agg = join_aggregate_kernel(
        jnp.asarray(bk, jnp.int32), jnp.asarray(bv, jnp.float32),
        jnp.asarray(pk, jnp.int32), jnp.asarray(pv, jnp.float32),
        dom, interpret=True)
    core, _ = tensor_join_aggregate(
        Relation({"k": bk.astype(np.int64), "v": bv}),
        Relation({"k": pk.astype(np.int64), "w": pv}),
        "k", "v", "w", key_domain=dom)
    np.testing.assert_allclose(float(agg["count"]), core["count"], rtol=1e-6)
    np.testing.assert_allclose(float(agg["sum_prod"]), core["sum_prod"], rtol=1e-5)
    np.testing.assert_allclose(float(agg["sum_add"]), core["sum_add"], rtol=1e-5)
