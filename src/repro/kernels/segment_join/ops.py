"""Jit'd wrappers: segment sum, radix partition and hash probe kernels.

The raw Pallas kernels require row counts to be multiples of their tile
sizes; these wrappers pad arbitrary relation sizes (segment id 0 with
value 0 is sum-neutral; out-of-domain codes are the partition/probe
padding contract) so the core engine can hand them real workloads.
Value dtype is preserved (float64 works in interpret mode, which is the
CPU fallback); TPU hardware runs float32.

:func:`radix_hash_probe` is the full radix-join probe: both sides are
radix-ordered by the top bits of their packed int32 codes (one
:func:`radix_partition` pass each), the domain-tiled hash table is built
and probed with per-tile block skipping, and the per-probe results are
gathered back to original row order.  The join cores in
``core/fused.py`` consume it through ``tensor_engine``'s ``use_pallas``
dispatch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import (join_table_build_pallas, join_table_probe_pallas,
                     radix_rank_pallas, segment_sum_pallas)

__all__ = ["segment_sum", "join_aggregate_kernel", "radix_partition",
           "radix_hash_probe"]


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("num_segments", "tblk", "interpret"))
def segment_sum(seg_ids, values, num_segments: int, tblk: int = 2048,
                interpret=None):
    interpret = _auto_interpret(interpret)
    n = seg_ids.shape[0]
    if n == 0:
        dt = values.dtype if values.dtype.kind == "f" else jnp.float32
        return jnp.zeros((num_segments,), dt)
    tblk = min(tblk, n)
    vals = values
    if vals.dtype == jnp.float64 and not interpret:
        vals = vals.astype(jnp.float32)  # TPU hardware path has no f64
    elif vals.dtype.kind not in "f":
        vals = vals.astype(jnp.float32)
    pad = (-n) % max(1, tblk)
    seg = seg_ids.astype(jnp.int32)
    if pad:
        seg = jnp.concatenate([seg, jnp.zeros((pad,), jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    return segment_sum_pallas(seg, vals, num_segments,
                              tblk=tblk, interpret=interpret)


@partial(jax.jit, static_argnames=("num_segments", "interpret"))
def join_aggregate_kernel(build_keys, build_vals, probe_keys, probe_vals,
                          num_segments: int, interpret=None):
    """Σ over (virtual) join pairs of b·p — join output never materialized."""
    sb = segment_sum(build_keys, build_vals, num_segments, interpret=interpret)
    sp = segment_sum(probe_keys, probe_vals, num_segments, interpret=interpret)
    cb = segment_sum(build_keys, jnp.ones_like(build_vals, jnp.float32),
                     num_segments, interpret=interpret)
    cp = segment_sum(probe_keys, jnp.ones_like(probe_vals, jnp.float32),
                     num_segments, interpret=interpret)
    return {"count": jnp.dot(cb, cp), "sum_prod": jnp.dot(sb, sp),
            "sum_add": jnp.dot(sb, cp) + jnp.dot(cb, sp)}


@partial(jax.jit, static_argnames=("num_buckets", "tblk", "interpret"))
def radix_partition(bucket_ids, num_buckets: int, tblk: int = 1024,
                    interpret=None):
    """Stable partition positions: ``(dest, counts)`` where ``dest[i]`` is
    row ``i``'s position in partition-major order (rows of the same bucket
    keep their relative order) and ``counts`` is the bucket histogram.
    ``bucket_ids`` must lie in ``[0, num_buckets)``."""
    interpret = _auto_interpret(interpret)
    n = bucket_ids.shape[0]
    if n == 0:
        return (jnp.zeros((0,), jnp.int32),
                jnp.zeros((num_buckets,), jnp.int32))
    tblk = min(tblk, n)
    b = bucket_ids.astype(jnp.int32)
    pad = (-n) % tblk
    if pad:
        # padded rows use bucket id == num_buckets: ranked 0, uncounted
        b = jnp.concatenate([b, jnp.full((pad,), num_buckets, jnp.int32)])
    rank, counts = radix_rank_pallas(b, num_buckets, tblk=tblk,
                                     interpret=interpret)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    dest = jnp.take(offsets, b[:n]) + rank[:n]
    return dest, counts


def _order(arr, dest, n):
    """Apply partition positions: ``out[dest[i]] = arr[i]``."""
    inv = jnp.zeros((n,), jnp.int32).at[dest].set(
        jnp.arange(n, dtype=jnp.int32))
    return jnp.take(arr, inv), inv


@partial(jax.jit, static_argnames=("domain", "tblk", "dblk", "interpret"))
def radix_hash_probe(bk, pk, domain: int, tblk: int = 1024, dblk: int = 512,
                     interpret=None):
    """Radix-partitioned hash-join probe in the packed code domain.

    ``bk``/``pk`` are int32 codes in ``[0, domain]`` — slot ``domain`` is
    the dead/padding slot by the dense-core convention (dead build and
    dead probe rows both land there; callers mask dead probes with their
    liveness predicate exactly as on the pure-jnp path).

    Returns ``(cnt_p, build_row, has_dup)``: per probe row the number of
    matching build rows and the largest matching build-row id (−1 on
    miss), plus whether any *live* slot holds more than one build row
    (the caller's retry-to-sorted-core signal).
    """
    interpret = _auto_interpret(interpret)
    nb, np_ = bk.shape[0], pk.shape[0]
    nblocks = -(-(domain + 1) // dblk)
    dpad = nblocks * dblk
    shift = max(1, dblk).bit_length() - 1          # log2(dblk), dblk pow2
    if nb == 0 or np_ == 0:
        cnt_p = jnp.zeros((np_,), jnp.int32)
        return cnt_p, cnt_p - 1, jnp.asarray(False)
    bk = bk.astype(jnp.int32)
    pk = pk.astype(jnp.int32)
    # 1. radix-order both sides by domain block (top code bits); codes
    # are non-negative so arithmetic >> equals a logical shift, and the
    # jnp operator keeps int32 under jax_enable_x64 (lax.shift_* would
    # reject the weakly-typed int64 shift operand)
    bdest, _ = radix_partition(bk >> shift, nblocks, tblk=tblk,
                               interpret=interpret)
    bk_ord, brow = _order(bk, bdest, nb)
    pdest, _ = radix_partition(pk >> shift, nblocks, tblk=tblk,
                               interpret=interpret)
    pk_ord, _ = _order(pk, pdest, np_)
    # 2. build the domain-tiled table (pad rows use code dpad: no block)
    bpad = (-nb) % min(tblk, nb)
    if bpad:
        bk_ord = jnp.concatenate([bk_ord,
                                  jnp.full((bpad,), dpad, jnp.int32)])
        brow = jnp.concatenate([brow, jnp.zeros((bpad,), jnp.int32)])
    cnt_t, inv_t = join_table_build_pallas(bk_ord, brow, dpad,
                                           tblk=tblk, dblk=dblk,
                                           interpret=interpret)
    # 3. probe in radix order, then gather back to original row order
    ppad = (-np_) % min(tblk, np_)
    if ppad:
        pk_ord = jnp.concatenate([pk_ord,
                                  jnp.full((ppad,), dpad, jnp.int32)])
    cnt_po, inv_po = join_table_probe_pallas(pk_ord, cnt_t, inv_t,
                                             tblk=tblk, dblk=dblk,
                                             interpret=interpret)
    cnt_p = jnp.take(cnt_po, pdest)
    build_row = jnp.take(inv_po, pdest) - 1
    has_dup = jnp.max(cnt_t[:domain]) > 1
    return cnt_p, build_row, has_dup
