"""Jit'd wrappers: tile sort + full multi-key sort (tile runs + XLA merge)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import bitonic_tile_sort_pallas

__all__ = ["tile_sort", "multikey_sort_lsd", "multikey_sort_lsd_padded"]

_I32_MAX = 2**31 - 1


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@partial(jax.jit, static_argnames=("tile", "interpret"))
def tile_sort(keys, vals, tile: int = 1024, interpret=None):
    return bitonic_tile_sort_pallas(keys.astype(jnp.int32),
                                    vals.astype(jnp.int32), tile=tile,
                                    interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("tile", "interpret"))
def multikey_sort_lsd(key_cols, tile: int = 1024, interpret=None):
    """Stable LSD multi-key sort (paper §IV.B) with the Pallas tile sorter as
    the inner stage.  key_cols: tuple of [N] int32 arrays, most-significant
    first.  Returns the permutation.  Requires N % tile == 0; the core engine
    calls :func:`multikey_sort_lsd_padded` for arbitrary N.

    Each LSD pass: bitonic tile runs (VMEM) + one jnp merge of the sorted
    runs (argsort over run-local ranks is XLA's efficient merge path)."""
    n = key_cols[0].shape[0]
    perm = jnp.arange(n, dtype=jnp.int32)
    for col in key_cols[::-1]:
        keyed = col[perm]
        # stage 1: VMEM tile runs, payload = current perm position (stable)
        pos = jnp.arange(n, dtype=jnp.int32)
        k_sorted, v_sorted = tile_sort(keyed, pos, tile=tile,
                                       interpret=interpret)
        # stage 2: merge runs — stable argsort over tile-sorted keys is a
        # merge of pre-sorted runs for XLA's sort
        merge = jnp.argsort(k_sorted, stable=True)
        take = v_sorted[merge]
        perm = perm[take]
    return perm


@partial(jax.jit, static_argnames=("tile", "interpret"))
def multikey_sort_lsd_padded(key_cols, tile: int = 1024, interpret=None):
    """Arbitrary-N entry point for the kernel-path multi-key sort.

    Pads each LSD pass to a tile multiple with INT32_MAX sentinel keys.  The
    composite (key, position) tie-break makes every stage stable in the
    original position, so padded entries — whose positions exceed every real
    position — always land *after* real rows of equal key; dropping the tail
    of the merged order recovers the exact permutation of the real rows.

    Contract: key values must fit int32 and be < INT32_MAX (the sentinel);
    the caller (core tensor engine) gates on dtype before dispatching here.
    """
    n = key_cols[0].shape[0]
    if n == 0:
        return jnp.arange(0, dtype=jnp.int32)
    tile = min(tile, _next_pow2(n))
    n_pad = -(-n // tile) * tile
    perm = jnp.arange(n, dtype=jnp.int32)
    pad = jnp.full((n_pad - n,), _I32_MAX, jnp.int32)
    for col in key_cols[::-1]:
        keyed = jnp.concatenate([col.astype(jnp.int32)[perm], pad])
        pos = jnp.arange(n_pad, dtype=jnp.int32)
        k_sorted, v_sorted = tile_sort(keyed, pos, tile=tile,
                                       interpret=interpret)
        merge = jnp.argsort(k_sorted, stable=True)
        take = v_sorted[merge][:n]  # padded entries occupy the tail
        perm = perm[take]
    return perm
