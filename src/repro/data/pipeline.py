"""Training data pipeline built ON the paper's relational core.

The preprocessing stages are classic high-dimensional relational operations,
executed through the dual-path engine with runtime path selection:

  1. **dedup**   — self-join on ``content_hash`` (keep lowest doc_id per hash);
  2. **quality filter** — predicate scan;
  3. **length bucketing / packing order** — multi-key sort on
     (domain, bucket, length): exactly the multi-attribute sort of paper §IV.B;
  4. **pack** — greedy fill of (B, S) token rows from the ordered docs.

Under a small ``work_mem`` (a node's preprocessing memory slice), stages 1
and 3 cross into the spill regime on the linear path; the selector routes
them to the tensor path — the paper's mechanism, doing real work in an LM
training system.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from ..core import (Executor, Filter, Join, PathSelector, Relation, Scan, Sort)
from .synthetic import synth_corpus, synth_tokens

__all__ = ["PipelineConfig", "prepare_order", "batches", "DataPipeline"]


@dataclasses.dataclass
class PipelineConfig:
    num_docs: int = 20_000
    vocab: int = 32_000
    seq_len: int = 512
    batch_size: int = 8
    min_quality: int = 10
    work_mem: int = 1 << 20
    policy: str = "auto"   # auto | linear | tensor
    seed: int = 0


def prepare_order(cfg: PipelineConfig):
    """Relational preprocessing; returns (ordered doc relation, op metrics)."""
    docs = synth_corpus(cfg.num_docs, cfg.vocab, cfg.seed)
    ex = Executor(work_mem=cfg.work_mem, policy=cfg.policy)

    # 1. dedup: canonical doc per content_hash (min doc_id), via self-join
    firsts = {}
    order = np.argsort(docs["doc_id"], kind="stable")
    hashes = docs["content_hash"][order]
    ids = docs["doc_id"][order]
    first_idx = np.unique(hashes, return_index=True)[1]
    canon = Relation({"content_hash": hashes[first_idx],
                      "canon_id": ids[first_idx]})
    joined = ex.execute(Join(Scan(canon), Scan(docs), "content_hash"))
    rel = joined.relation
    keep = rel["doc_id"] == rel["b_canon_id"]
    rel = rel.take(np.nonzero(keep)[0])

    # 2. quality filter + 3. multi-key packing order (domain, bucket, length)
    bucket = (np.log2(np.maximum(rel["length"], 1)).astype(np.int64))
    rel = Relation({**rel.columns, "bucket": bucket})
    res = ex.execute(
        Sort(Filter(Scan(rel), lambda r: r["quality"] >= cfg.min_quality),
             ["domain", "bucket", "length"]))
    metrics = joined.metrics + res.metrics
    decisions = joined.decisions + res.decisions
    return res.relation, metrics, decisions


def batches(cfg: PipelineConfig) -> Iterator[dict]:
    """Yield {"tokens": (B,S) int32, "labels": (B,S) int32} training batches."""
    ordered, _, _ = prepare_order(cfg)
    lengths = ordered["length"]
    doc_ids = ordered["doc_id"]
    toks = synth_tokens(doc_ids, lengths, cfg.vocab, cfg.seed)
    S, B = cfg.seq_len, cfg.batch_size
    need = B * (S + 1)
    pos = 0
    while pos + need <= len(toks):
        block = toks[pos:pos + need].reshape(B, S + 1)
        pos += need
        yield {
            "tokens": block[:, :-1].astype(np.int32),
            "labels": block[:, 1:].astype(np.int32),
        }


class DataPipeline:
    """Stateful wrapper with deterministic resume (fault tolerance: the
    consumed-batch counter is part of the training checkpoint)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self._consumed = 0

    def state(self) -> dict:
        return {"consumed": self._consumed, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        self._consumed = int(state["consumed"])

    def __iter__(self):
        it = batches(self.cfg)
        for _ in range(self._consumed):  # deterministic skip on resume
            next(it)
        for b in it:
            self._consumed += 1
            yield b
