"""Synthetic corpus: documents as relations (the data pipeline's raw input)."""
from __future__ import annotations

import numpy as np

from ..core import Relation

__all__ = ["synth_corpus", "synth_tokens"]


def synth_corpus(num_docs: int, vocab: int, seed: int = 0,
                 mean_len: int = 512) -> Relation:
    """Document metadata table: one row per doc.  ``content_hash`` collides
    for duplicated documents (10% dup rate) so dedup has real work to do."""
    rng = np.random.default_rng(seed)
    lengths = np.maximum(8, rng.geometric(1.0 / mean_len, num_docs)).astype(np.int64)
    base_hash = rng.integers(0, 1 << 60, num_docs).astype(np.int64)
    # duplicate ~10% of docs: share another doc's hash & length
    dup = rng.random(num_docs) < 0.10
    src = rng.integers(0, num_docs, num_docs)
    content_hash = np.where(dup, base_hash[src], base_hash)
    lengths = np.where(dup, lengths[src], lengths)
    return Relation({
        "doc_id": np.arange(num_docs, dtype=np.int64),
        "content_hash": content_hash,
        "length": lengths,
        "domain": rng.integers(0, 16, num_docs).astype(np.int64),
        "quality": rng.integers(0, 100, num_docs).astype(np.int64),
    })


def synth_tokens(doc_ids: np.ndarray, lengths: np.ndarray, vocab: int,
                 seed: int = 0) -> np.ndarray:
    """Deterministic per-doc token stream (zipf-ish), concatenated."""
    rng = np.random.default_rng(seed)
    total = int(lengths.sum())
    # zipf via inverse-CDF over a power-law; cheap + heavy-tailed like text
    u = rng.random(total)
    toks = np.minimum((u ** -1.2).astype(np.int64), vocab - 1)
    return toks % vocab
