"""Serving demo: batched requests through continuous batching.

Submits a mixed-priority request set; the scheduler orders admission via the
tensor execution path (multi-key sort on (priority, arrival)), prefill+decode
run through the shared model substrate.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.serving.engine import BatchScheduler, Request, generate


def main():
    cfg = get_smoke_config("qwen2-vl-7b")
    # text-only serving of the VLM backbone (frontend stubbed per assignment)
    import dataclasses
    cfg = dataclasses.replace(cfg, mrope_sections=(), modality="text")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    sched = BatchScheduler(batch_size=4)
    for i in range(10):
        sched.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 12),
            max_new_tokens=8, priority=int(rng.integers(0, 3))))

    t0 = time.time()
    done = 0
    while sched.queue:
        reqs = sched.admit(4)
        outs = generate(params, cfg,
                        np.stack([r.prompt for r in reqs]), 8)
        for r, o in zip(reqs, outs):
            r.output = list(o)
        done += len(reqs)
        print(f"admitted {[r.rid for r in reqs]} "
              f"(priorities {[r.priority for r in reqs]}) -> "
              f"{len(reqs)} responses")
    dt = time.time() - t0
    print(f"{done} requests, {done * 8} tokens in {dt:.1f}s "
          f"({done * 8 / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
