"""Model assembly: period-patterned blocks scanned over depth.

Architectures are described by a *period pattern* (configs.base): a tuple of
(mixer, ffn) slots tiled ``num_periods`` times, plus optional prefix layers.
Parameters for the scanned body are stacked on a leading period axis and the
depth loop is a single ``lax.scan`` — keeping HLO size (and 512-device compile
time) independent of depth.  Heterogeneous stacks (Gemma-2 local/global,
Jamba 7:1 Mamba:attention with alternating MoE) are periods with several
slots, unrolled inside the scan body.

Three entry points share the block code:
  * ``forward``      — train/eval logits (+ MoE aux loss)
  * ``prefill``      — forward that also returns a decode cache
  * ``decode_step``  — one-token step against a preallocated cache
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (gqa_decode, gqa_forward, init_gqa, init_mla,
                        mla_decode, mla_forward)
from .common import init_dense, init_rmsnorm, mlp, init_mlp, mrope_freqs, rmsnorm, rope, softcap
from .mamba2 import init_mamba2, mamba2_decode, mamba2_forward, _dims as mamba_dims
from .moe import init_moe, moe_forward
from .pspec import constrain

__all__ = ["init_model", "forward", "prefill", "decode_step", "init_cache",
           "cross_entropy_loss", "model_input_dtypes"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_slot(key, cfg: ArchConfig, spec, dtype):
    mixer, ffn = spec
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if mixer == "mamba":
        p["mixer"] = init_mamba2(ks[0], cfg, dtype)
    elif cfg.attn_type == "mla":
        p["mixer"] = init_mla(ks[0], cfg, dtype)
    else:
        p["mixer"] = init_gqa(ks[0], cfg, dtype)
    if ffn != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if ffn == "moe":
            p["ffn"] = init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    if cfg.use_post_norm:
        p["postnorm1"] = init_rmsnorm(cfg.d_model, dtype)
        if ffn != "none":
            p["postnorm2"] = init_rmsnorm(cfg.d_model, dtype)
    return p


def _init_period(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, len(cfg.pattern))
    return {f"s{i}": _init_slot(ks[i], cfg, spec, dtype)
            for i, spec in enumerate(cfg.pattern)}


def init_model(key, cfg: ArchConfig, dtype=jnp.float32):
    k_embed, k_prefix, k_blocks, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if cfg.modality == "audio_stub":
        # frame embeddings arrive precomputed at d_model; learned input norm+proj
        params["frontend"] = {
            "proj": init_dense(k_embed, cfg.d_model, cfg.d_model, dtype),
            "norm": init_rmsnorm(cfg.d_model, dtype),
        }
    else:
        params["embed"] = {
            "table": (jax.random.normal(k_embed, (cfg.padded_vocab, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype)
        }
    if cfg.prefix:
        pk = jax.random.split(k_prefix, len(cfg.prefix))
        params["prefix"] = {f"p{i}": _init_slot(pk[i], cfg, spec, dtype)
                            for i, spec in enumerate(cfg.prefix)}
    if cfg.num_periods:
        bk = jax.random.split(k_blocks, cfg.num_periods)
        params["blocks"] = jax.vmap(lambda k: _init_period(k, cfg, dtype))(bk)
    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head, cfg.d_model, cfg.padded_vocab, dtype)
    return params


# ---------------------------------------------------------------------------
# shared block application
# ---------------------------------------------------------------------------

def _mixer_window(cfg, mixer):
    return cfg.sliding_window if mixer == "attn:local" else None


def _apply_slot(p, cfg: ArchConfig, spec, x, sin, cos, *, moe_dispatch,
                moe_budget, moe_token_chunk, q_chunk, kv_chunk):
    """Full-sequence slot application. Returns (x, cache_entry, aux)."""
    mixer, ffn = spec
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    cache_entry = {}
    if mixer == "mamba":
        out, (conv_state, ssd_state) = mamba2_forward(p["mixer"], h, cfg)
        cache_entry = {"conv": conv_state, "ssd": ssd_state}
    elif cfg.attn_type == "mla":
        out, ckv = mla_forward(p["mixer"], h, cfg, sin, cos,
                               q_chunk=q_chunk, kv_chunk=kv_chunk)
        cache_entry = {"ckv": ckv}
    else:
        out, (k, v) = gqa_forward(p["mixer"], h, cfg, sin, cos,
                                  window=_mixer_window(cfg, mixer),
                                  is_causal=cfg.causal,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk)
        cache_entry = {"k": k, "v": v}
    if cfg.use_post_norm:
        out = rmsnorm(p["postnorm1"], out, cfg.norm_eps)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            out, aux = moe_forward(p["ffn"], h, cfg, dispatch=moe_dispatch,
                                   budget_bytes=moe_budget,
                                   token_chunk=moe_token_chunk)
        else:
            out = mlp(p["ffn"], h, cfg.mlp_type)
        if cfg.use_post_norm:
            out = rmsnorm(p["postnorm2"], out, cfg.norm_eps)
        x = x + out
    return x, cache_entry, aux


def _rope_tables(cfg: ArchConfig, batch, seq_len, q_offset=0):
    if cfg.mrope_sections:
        positions = batch["positions"]  # [3, B, S]
        return mrope_freqs(positions, cfg.head_dim if cfg.attn_type != "mla"
                           else cfg.qk_rope_dim, cfg.rope_theta,
                           cfg.mrope_sections)
    positions = (jnp.arange(seq_len) + q_offset)[None, :]  # [1, S]
    dim = cfg.qk_rope_dim if cfg.attn_type == "mla" else cfg.head_dim
    return rope(positions, dim, cfg.rope_theta)


def _embed(params, cfg: ArchConfig, batch):
    if cfg.modality == "audio_stub":
        f = params["frontend"]
        x = rmsnorm(f["norm"], batch["features"] @ f["proj"], cfg.norm_eps)
    else:
        x = params["embed"]["table"][batch["tokens"]]
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return x


def _head(params, cfg: ArchConfig, x):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = x @ params["lm_head"]
    logits = softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padding columns; keeps logsumexp/argmax/CE exact while the
        # vocab axis stays mesh-divisible end to end
        pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) >= cfg.vocab_size,
                             -1e30, 0.0).astype(jnp.float32)
        logits = (logits.astype(jnp.float32) + pad_mask).astype(logits.dtype)
    return logits


# ---------------------------------------------------------------------------
# forward (train / eval / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, batch, *, collect_cache: bool = False,
            moe_dispatch: str = "auto", moe_budget: int = 2 << 30,
            moe_token_chunk: int = 32_768,
            remat: bool = False, remat_policy: str = "full",
            q_chunk: int = 256, kv_chunk: int = 1024,
            logits_sharding=None, return_hidden: bool = False):
    """batch: {"tokens": [B,S]} | {"features": [B,S,d]} (+ "positions" for
    M-RoPE).  Returns (logits [B,S,V], aux_loss, cache|None).

    ``logits_sharding`` (a NamedSharding/PartitionSpec) constrains the logits
    to stay vocab-sharded — without it GSPMD may replicate the [B,S,V] tensor,
    which at 4k×100k-vocab is the single largest activation in the program.
    """
    x = _embed(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    sin, cos = _rope_tables(cfg, batch, S)
    apply_kw = dict(moe_dispatch=moe_dispatch, moe_budget=moe_budget,
                    moe_token_chunk=moe_token_chunk,
                    q_chunk=q_chunk, kv_chunk=kv_chunk)

    aux_total = jnp.zeros((), jnp.float32)
    prefix_cache = {}
    for i, spec in enumerate(cfg.prefix):
        x, entry, aux = _apply_slot(params["prefix"][f"p{i}"], cfg, spec, x,
                                    sin, cos, **apply_kw)
        aux_total += aux
        if collect_cache:
            prefix_cache[f"p{i}"] = entry

    if cfg.num_periods:
        def period_body(carry, period_params):
            x, aux_acc = carry
            # pin the residual stream: batch over dp, replicated elsewhere —
            # keeps the scan's saved carries from being batch-replicated
            x = constrain(x, "dp", None, None)
            entries = {}
            for i, spec in enumerate(cfg.pattern):
                x, entry, aux = _apply_slot(period_params[f"s{i}"], cfg, spec,
                                            x, sin, cos, **apply_kw)
                aux_acc += aux
                entries[f"s{i}"] = entry
            outputs = entries if collect_cache else None
            return (constrain(x, "dp", None, None), aux_acc), outputs

        if remat:
            ckpt_policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                           if remat_policy == "dots" else None)
            body = jax.checkpoint(period_body, policy=ckpt_policy)
        else:
            body = period_body
        (x, aux_total), block_cache = jax.lax.scan(
            body, (x, aux_total), params["blocks"])
    else:
        block_cache = None

    if return_hidden:
        logits = x
    else:
        logits = _head(params, cfg, x)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    cache = None
    if collect_cache:
        cache = {"prefix": prefix_cache, "blocks": block_cache,
                 "pos": jnp.asarray(S, jnp.int32)}
    return logits, aux_total, cache


def prefill(params, cfg: ArchConfig, batch, **kw):
    """Forward returning (last-token logits, cache) — the serving prefill.

    The head is applied to the LAST position only: at 32k×256k-vocab the full
    [B,S,V] logits would dwarf everything else in the prefill program."""
    kw.pop("logits_sharding", None)
    hidden, aux, cache = forward(params, cfg, batch, collect_cache=True,
                                 return_hidden=True, **kw)
    logits = _head(params, cfg, hidden[:, -1:, :])
    return logits[:, 0, :], cache


def hidden_forward(params, cfg: ArchConfig, batch, **kw):
    """Forward WITHOUT the head: returns (hidden [B,S,d], aux_loss).

    Training uses this + ``chunked_softmax_xent`` so the [B,S,V] logits tensor
    is never materialized (at 4k seq × 100k vocab it would be the largest
    activation in the program by an order of magnitude)."""
    kw.pop("logits_sharding", None)
    hidden, aux, _ = forward(params, cfg, batch, return_hidden=True, **kw)
    return hidden, aux


def chunked_softmax_xent(params, cfg: ArchConfig, hidden, labels, *,
                         chunk: int = 512, logits_sharding=None):
    """CE over sequence chunks: head-matmul + logsumexp + gold extraction per
    chunk, rematerialized in backward.  Peak memory is O(B·chunk·V/shards)
    instead of O(B·S·V)."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xs = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_body(carry, inp):
        nll_acc, cnt_acc = carry
        xc, lc = inp
        logits = _head(params, cfg, xc)
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
        mask = (lc >= 0).astype(jnp.float32)
        lab = jnp.maximum(lc, 0)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        onehot = jax.nn.one_hot(lab, lf.shape[-1], dtype=lf.dtype)
        gold = jnp.einsum("bcv,bcv->bc", lf, onehot)
        nll = ((lse - gold) * mask).sum()
        return (nll_acc + nll, cnt_acc + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        chunk_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    return nll / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
               dtype=jnp.float32):
    """Preallocated decode cache (zeros).  Layout mirrors forward's
    collect_cache pytree, but attention entries are fixed at max_seq."""
    def slot_cache(spec):
        mixer, _ = spec
        if mixer == "mamba":
            d_inner, nheads, g, n, conv_ch = mamba_dims(cfg)
            return {
                "conv": jnp.zeros((batch_size, cfg.conv_width - 1, conv_ch), dtype),
                "ssd": jnp.zeros((batch_size, nheads, cfg.ssm_headdim, n),
                                 jnp.float32),
            }
        if cfg.attn_type == "mla":
            width = cfg.kv_lora_rank + cfg.qk_rope_dim
            return {"ckv": jnp.zeros((batch_size, max_seq, width), dtype)}
        return {
            "k": jnp.zeros((batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        }

    cache: Dict[str, Any] = {
        "prefix": {f"p{i}": slot_cache(spec) for i, spec in enumerate(cfg.prefix)},
        "blocks": None,
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.num_periods:
        per = {f"s{i}": slot_cache(spec) for i, spec in enumerate(cfg.pattern)}
        cache["blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape).copy(),
            per)
    return cache


def _decode_slot(p, cfg: ArchConfig, spec, x, sin, cos, cache_entry, pos):
    mixer, ffn = spec
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer == "mamba":
        out, (conv_s, ssd_s) = mamba2_decode(p["mixer"], h, cfg,
                                             cache_entry["conv"],
                                             cache_entry["ssd"])
        new_entry = {"conv": conv_s, "ssd": ssd_s}
    elif cfg.attn_type == "mla":
        out, ckv = mla_decode(p["mixer"], h, cfg, sin, cos,
                              cache_entry["ckv"], pos)
        new_entry = {"ckv": ckv}
    else:
        out, (k_c, v_c) = gqa_decode(p["mixer"], h, cfg, sin, cos,
                                     cache_entry["k"], cache_entry["v"], pos,
                                     window=_mixer_window(cfg, mixer))
        new_entry = {"k": k_c, "v": v_c}
    if cfg.use_post_norm:
        out = rmsnorm(p["postnorm1"], out, cfg.norm_eps)
    x = x + out
    if ffn != "none":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            out, _ = moe_forward(p["ffn"], h, cfg, dispatch="einsum")
        else:
            out = mlp(p["ffn"], h, cfg.mlp_type)
        if cfg.use_post_norm:
            out = rmsnorm(p["postnorm2"], out, cfg.norm_eps)
        x = x + out
    return x, new_entry


def decode_step(params, cfg: ArchConfig, cache, batch):
    """One decode step.  batch: {"tokens": [B, 1]} (+ "positions" [3,B,1] for
    M-RoPE).  Returns (logits [B, V], new_cache)."""
    pos = cache["pos"]
    x = _embed(params, cfg, batch)
    if cfg.mrope_sections:
        sin, cos = _rope_tables(cfg, batch, 1)
    else:
        positions = pos[None, None].astype(jnp.int32)  # [1,1]
        dim = cfg.qk_rope_dim if cfg.attn_type == "mla" else cfg.head_dim
        sin, cos = rope(positions, dim, cfg.rope_theta)

    new_prefix = {}
    for i, spec in enumerate(cfg.prefix):
        x, entry = _decode_slot(params["prefix"][f"p{i}"], cfg, spec, x,
                                sin, cos, cache["prefix"][f"p{i}"], pos)
        new_prefix[f"p{i}"] = entry

    new_blocks = None
    if cfg.num_periods:
        def body(x, inp):
            period_params, period_cache = inp
            new_entries = {}
            for i, spec in enumerate(cfg.pattern):
                x, entry = _decode_slot(period_params[f"s{i}"], cfg, spec, x,
                                        sin, cos, period_cache[f"s{i}"], pos)
                new_entries[f"s{i}"] = entry
            return x, new_entries

        x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))

    logits = _head(params, cfg, x)[:, 0, :]
    new_cache = {"prefix": new_prefix, "blocks": new_blocks, "pos": pos + 1}
    return logits, new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits, labels, mask=None):
    """Stable CE.  labels [B,S] int; mask 1.0/0.0 (or labels<0 → masked).

    The gold logit is extracted with a one-hot contraction rather than
    ``take_along_axis`` — under a vocab-sharded mesh the contraction stays
    local + one small all-reduce, whereas a gather over the sharded axis
    forces GSPMD to all-gather the full logits."""
    if mask is None:
        mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    gold = jnp.einsum("...v,...v->...", lf, onehot)
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def model_input_dtypes(cfg: ArchConfig):
    """Which inputs this arch consumes (used by input_specs / data pipeline)."""
    inputs = {}
    if cfg.modality == "audio_stub":
        inputs["features"] = "float32"
    else:
        inputs["tokens"] = "int32"
    if cfg.mrope_sections:
        inputs["positions"] = "int32"
    return inputs
