"""Execution-time path selection (§III.C) and regime-shift model (§VI)."""
import numpy as np
import pytest

from repro.core import (
    CostModel,
    Executor,
    Join,
    PathSelector,
    Relation,
    Scan,
    Sort,
    table_bytes_estimate,
)


def _tables(n=40_000, seed=0):
    rng = np.random.default_rng(seed)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 99, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 99, n).astype(np.int64)})
    return build, probe


def test_selector_prefers_linear_when_fits():
    build, probe = _tables(1000)
    sel = PathSelector(work_mem=1 << 30)
    d = sel.choose_join(build, probe, "k")
    assert d.path == "linear"
    assert "fits" in d.reason


def test_selector_predicts_spill_under_pressure():
    build, probe = _tables(200_000)
    sel = PathSelector(work_mem=1 << 20)
    d = sel.choose_join(build, probe, "k")
    assert d.predicted_spill_bytes > 0
    assert d.t_linear > 0 and d.t_tensor > 0


def test_selector_forced_paths():
    build, probe = _tables(1000)
    for force in ("linear", "tensor"):
        sel = PathSelector(work_mem=1 << 20, force=force)
        assert sel.choose_join(build, probe, "k").path == force
        assert sel.choose_sort(build, ["k"]).path == force


def test_executor_policies_agree_semantically():
    build, probe = _tables(20_000)
    plan = lambda: Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"])
    results = {}
    for policy in ("linear", "tensor", "auto"):
        ex = Executor(work_mem=128 * 1024, policy=policy)
        results[policy] = ex.execute(plan()).relation.sort_canonical()
    assert results["linear"].equals(results["tensor"])
    assert results["linear"].equals(results["auto"])


def test_regime_model_alpha_superlinear_in_deficit():
    """α(N, M) grows superlinearly as memory pressure increases (§VI)."""
    model = CostModel()
    n = 1_000_000
    spills = []
    for mem in (1 << 26, 1 << 23, 1 << 20):  # 64MB, 8MB, 1MB
        s, _ = model.join_spill_bytes(n, n, 16, 16, mem)
        spills.append(s)
    assert spills[0] <= spills[1] <= spills[2]
    assert spills[2] > 0
    # sort spill passes grow as memory shrinks
    p_small = model.sort_spill_bytes(n, 24, 1 << 20)[1]
    p_large = model.sort_spill_bytes(n, 24, 1 << 26)[1]
    assert p_small >= p_large


def test_table_bytes_monotonic():
    assert table_bytes_estimate(10) <= table_bytes_estimate(1000)
    assert table_bytes_estimate(1000) <= table_bytes_estimate(10**6)
