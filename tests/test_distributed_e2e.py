"""End-to-end DISTRIBUTED execution test: a tiny model actually runs (not
just compiles) on an 8-device host mesh in a subprocess (device count must be
set before jax initializes, hence the isolation)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import json
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.distributed.sharding import batch_specs, param_specs, tree_shardings
    from repro.launch.mesh import make_local_mesh
    from repro.models import init_model
    from repro.train.optimizer import make_optimizer
    from repro.train.trainer import TrainPolicy, make_train_step

    mesh = make_local_mesh(data=2, model=4)
    cfg = dataclasses.replace(get_smoke_config("phi3.5-moe-42b-a6.6b"),
                              vocab_pad_multiple=8)
    # make dims divide the (2, 4) mesh
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", lr=1e-2)
    policy = TrainPolicy(remat=True, microbatches=2,
                         logits_sharding=NamedSharding(mesh, P(("data",), None, "model")))
    step = make_train_step(cfg, opt, policy)

    batch = {
        "tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (8, 32)), jnp.int32),
        "labels": jnp.asarray(np.random.default_rng(1).integers(
            0, cfg.vocab_size, (8, 32)), jnp.int32),
    }
    p_specs = param_specs(jax.eval_shape(lambda: params), cfg)
    opt_state = opt.init(params)
    o_specs = param_specs(jax.eval_shape(lambda: opt_state), cfg)
    b_specs = batch_specs(jax.eval_shape(lambda: batch), mesh)
    with mesh:
        p_sh = tree_shardings(mesh, p_specs)
        o_sh = tree_shardings(mesh, o_specs)
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, tree_shardings(mesh, b_specs)),
                     out_shardings=(p_sh, o_sh, None))
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        batch = jax.device_put(batch, tree_shardings(mesh, b_specs))
        losses = []
        for _ in range(3):
            params, opt_state, metrics = fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses
    print(json.dumps({"losses": losses, "devices": jax.device_count()}))
""")


def test_sharded_train_step_runs_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # the suite-wide 8-device mesh flag lives in tests/conftest.py (and the
    # CI env) and is inherited here; pin the child's copy anyway because
    # THIS test asserts exactly 8 devices even under a user-customized
    # XLA_FLAGS, and the subprocess exists precisely to own its jax init
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["losses"][-1] < out["losses"][0]
