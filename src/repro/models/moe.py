"""Mixture-of-Experts with DUAL DISPATCH PATHS — the paper's technique
applied to the canonical LM instance of premature dimensional collapse.

Token→expert dispatch can be executed two ways, exactly mirroring the paper's
linear vs. tensor execution paths for relational joins:

  * **linear path** (`dispatch="sort"`): flatten the (token, expert) structure,
    ``argsort`` tokens by expert id, and *materialize* the permuted
    ``(E·C, d)`` buffer (scatter), compute experts, inverse-gather.  This is
    the classic CPU/GPU "megablocks-style" dispatch: an early linearization
    whose materialized permutation is the hash-table analogue.

  * **tensor path** (`dispatch="einsum"`): keep (expert, capacity) as explicit
    tensor axes and dispatch with a one-hot contraction
    ``x[t,d], mask[t,e,c] → buf[e,c,d]`` — dimension-preserving, deterministic
    traffic, MXU-shaped.  The Pallas kernel (repro.kernels.moe_dispatch)
    implements the same contract without materializing the one-hot.

  * **runtime selection** (`dispatch="auto"`): a simple execution-time policy
    (§III.C analogue) picks a path from the *static* step shapes: the tensor
    path's one-hot working set (T·E·C) is compared against a memory budget —
    the accelerator-side work_mem — and falls back to the linear path when it
    would not fit.

Both paths drop the same overflow tokens (identical capacity semantics), so
results are bit-comparable — the tests assert exact agreement.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import init_dense
from .pspec import constrain

__all__ = ["init_moe", "moe_forward", "select_dispatch_path", "DispatchDecision"]


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    path: str
    reason: str
    onehot_bytes: int
    capacity: int


def capacity_per_expert(num_tokens: int, num_experts: int, k: int,
                        capacity_factor: float) -> int:
    c = int(math.ceil(num_tokens * k * capacity_factor / num_experts))
    # multiple of 16: TPU lane alignment AND divisibility by the "data" mesh
    # axis (the capacity dim is FSDP-sharded through the expert FFN)
    return max(16, -(-c // 16) * 16)


def select_dispatch_path(num_tokens: int, num_experts: int, capacity: int,
                         d_model: int, k: int,
                         budget_bytes: int = 2 << 30,
                         force: Optional[str] = None) -> DispatchDecision:
    """Execution-time path choice from static step shapes (paper §III.C).

    The one-hot working set is evaluated PER DEVICE: under a mesh the
    [T, E, C] mask shards over (dp × model).  (§Perf iteration 1: comparing
    global bytes against the budget mis-routed mesh-scale steps to the sort
    path, whose cross-shard scatter all-reduces the full (T·k, d) payload —
    the dominant collective in the MoE-train baseline.)
    """
    from .pspec import ambient_mesh
    mesh = ambient_mesh()
    shards = int(mesh.devices.size) if mesh is not None else 1
    onehot_bytes = num_tokens * num_experts * capacity * 4 // max(1, shards)
    if force in ("sort", "einsum"):
        return DispatchDecision(force, "forced", onehot_bytes, capacity)
    if onehot_bytes > budget_bytes:
        return DispatchDecision(
            "sort",
            f"one-hot dispatch tensor {onehot_bytes/1e9:.2f} GB/device exceeds "
            f"budget {budget_bytes/1e9:.2f} GB — linearized dispatch avoids "
            f"the memory-regime shift",
            onehot_bytes, capacity)
    return DispatchDecision(
        "einsum",
        f"one-hot dispatch tensor {onehot_bytes/1e6:.1f} MB/device fits budget; "
        f"dimension-preserving contraction is MXU-shaped",
        onehot_bytes, capacity)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_moe(key, cfg, dtype=jnp.float32):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": init_dense(ks[0], d, E, jnp.float32),  # router kept in f32
        "wg": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dtype),
        "wi": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
               * (1.0 / math.sqrt(ff))).astype(dtype),
    }
    if cfg.num_shared_experts:
        sh_ff = cfg.moe_d_ff * cfg.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": init_dense(kk[0], d, sh_ff, dtype),
            "wi": init_dense(kk[1], d, sh_ff, dtype),
            "wo": init_dense(kk[2], sh_ff, d, dtype),
        }
    return p


# ---------------------------------------------------------------------------
# routing (common to both paths)
# ---------------------------------------------------------------------------

def _route(params, x_flat, cfg):
    """x_flat [T, d] → (topk_idx [T,k], topk_w [T,k], aux_loss)."""
    logits = (x_flat.astype(jnp.float32) @ params["router"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.experts_per_token
    topk_p, topk_idx = jax.lax.top_k(probs, k)
    if cfg.norm_topk:
        topk_w = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)
    else:
        topk_w = topk_p
    # Switch-style load-balance loss
    E = cfg.num_experts
    me = probs.mean(axis=0)                                   # mean router prob
    onehot = jax.nn.one_hot(topk_idx[:, 0], E, dtype=jnp.float32)
    ce = onehot.mean(axis=0)                                  # fraction routed (top-1)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight
    return topk_idx, topk_w, aux


def _expert_ffn(params, buf, cfg):
    """buf [E, C, d] → [E, C, d] via per-expert gated FFN (stacked einsum).

    Expert weights are FSDP-sharded on d over "data"; WITHOUT the constraints
    below GSPMD keeps them sharded through the einsum and ALL-REDUCES the
    (E, C, ff) activation over the data axis instead — measured 2.9 TB/device
    of f32 all-reduce on jamba-train (§Perf H3c).  Gathering the per-device
    expert slice (E/16 · d · ff bf16) once per use is ~5× cheaper."""
    wg = constrain(params["wg"], "model", None, None)
    wi = constrain(params["wi"], "model", None, None)
    wo = constrain(params["wo"], "model", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ---------------------------------------------------------------------------
# the two dispatch paths
# ---------------------------------------------------------------------------

def _dispatch_einsum(params, x_flat, topk_idx, topk_w, cfg, capacity):
    """TENSOR path: (expert, capacity) kept as explicit axes; one-hot einsum."""
    T, d = x_flat.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    flat_e = topk_idx.reshape(-1)                             # [T*k]
    onehot_e = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # [T*k, E]
    pos = jnp.cumsum(onehot_e, axis=0) - onehot_e             # rank within expert
    slot = jnp.sum(pos * onehot_e, axis=-1)                   # [T*k]
    keep = slot < capacity
    # dispatch mask [T*k, E, C]: assignment j occupies (e_j, slot_j);
    # overflow slots map to index `capacity` → all-zero one-hot row (dropped)
    onehot_c = jax.nn.one_hot(jnp.where(keep, slot, capacity), capacity,
                              dtype=x_flat.dtype)
    mask = (jax.nn.one_hot(flat_e, E, dtype=x_flat.dtype)[:, :, None]
            * onehot_c[:, None, :])
    mask = mask.reshape(T, k, E, capacity)
    dispatch = mask.sum(axis=1)                               # [T, E, C]
    dispatch = constrain(dispatch, "dp", "model", None)
    combine = (mask * topk_w.astype(x_flat.dtype)[..., None, None]).sum(axis=1)
    combine = constrain(combine, "dp", "model", None)
    buf = jnp.einsum("tec,td->ecd", dispatch, x_flat)         # dimension-preserving
    # EP on experts + FSDP on capacity rows: each data shard computes C/16
    # rows against the gathered weight slice (no activation all-reduce, no
    # redundant compute — see _expert_ffn)
    buf = constrain(buf, "model", "data", None)
    out_buf = _expert_ffn(params, buf, cfg)
    out_buf = constrain(out_buf, "model", "data", None)
    return constrain(jnp.einsum("tec,ecd->td", combine, out_buf), "dp", None)


def _dispatch_sort(params, x_flat, topk_idx, topk_w, cfg, capacity):
    """LINEAR path: flatten + argsort by expert + materialized (E·C, d) buffer."""
    T, d = x_flat.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    flat_e = topk_idx.reshape(-1)                             # [T*k]
    flat_w = topk_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    # premature linearization: collapse (token, expert) structure into a
    # sorted 1-D order (stable → within-expert order matches einsum path)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    w_sorted = flat_w[order]
    # position within expert segment
    start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - start[e_sorted]
    keep = pos < capacity
    slot = e_sorted * capacity + jnp.where(keep, pos, 0)
    gathered = x_flat[t_sorted] * keep[:, None].astype(x_flat.dtype)
    # the materialized permutation is the hot buffer of this path — pin it to
    # the dp axis or GSPMD replicates all T·k rows on every device
    gathered = constrain(gathered, "dp", None)
    buf = jnp.zeros((E * capacity, d), x_flat.dtype).at[slot].add(
        gathered, mode="drop")                                # materialized buffer
    buf = constrain(buf, "model", None)                       # E·C rows: EP-sharded
    out_buf = _expert_ffn(params, constrain(
        buf.reshape(E, capacity, d), "model", "data", None), cfg)
    y_sorted = constrain(out_buf, "model", "data", None).reshape(E * capacity, d)[slot]
    y_sorted = constrain(y_sorted, "dp", None)
    y_sorted = y_sorted * (w_sorted.astype(x_flat.dtype) * keep.astype(x_flat.dtype))[:, None]
    # inverse scatter back to token space
    return constrain(
        jnp.zeros((T, d), x_flat.dtype).at[t_sorted].add(y_sorted), "dp", None)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def _moe_tokens(params, x_flat, cfg, dispatch: str, budget_bytes: int):
    """Core MoE over a flat token block [T, d] → (y [T, d], aux)."""
    T, d = x_flat.shape
    topk_idx, topk_w, aux = _route(params, x_flat, cfg)
    capacity = capacity_per_expert(T, cfg.num_experts, cfg.experts_per_token,
                                   cfg.capacity_factor)
    decision = select_dispatch_path(
        T, cfg.num_experts, capacity, d, cfg.experts_per_token,
        budget_bytes=budget_bytes,
        force=None if dispatch == "auto" else dispatch)
    if decision.path == "einsum":
        y = _dispatch_einsum(params, x_flat, topk_idx, topk_w, cfg, capacity)
    else:
        y = _dispatch_sort(params, x_flat, topk_idx, topk_w, cfg, capacity)
    if "shared" in params:
        sh = params["shared"]
        h = jax.nn.silu(x_flat @ sh["wg"]) * (x_flat @ sh["wi"])
        y = y + h @ sh["wo"]
    return y, aux


def moe_forward(params, x, cfg, *, dispatch: str = "auto",
                budget_bytes: int = 2 << 30,
                token_chunk: int = 32_768) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, d] → (y [B, S, d], aux_loss scalar).

    Token blocks above ``token_chunk`` are processed through a scan —
    capacity (and drops) become per-chunk, and the (E, C, ff) expert hidden
    stays bounded regardless of B·S (at 32k-prefill scale the unchunked
    hidden is tens of GB).  The same "delay the full materialization"
    principle as the relational core, applied to the dispatch buffers.
    """
    B, S, d = x.shape
    # chunk along S (keeps every chunk spread over the batch/dp shards)
    sc = max(1, token_chunk // B)
    if S > sc and S % sc == 0:
        nc = S // sc
        xs = x.reshape(B, nc, sc, d).transpose(1, 0, 2, 3)  # [nc, B, sc, d]

        def body(aux_acc, xc):
            y, aux = _moe_tokens(params, xc.reshape(B * sc, d), cfg,
                                 dispatch, budget_bytes)
            return aux_acc + aux, y.reshape(B, sc, d)

        aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
        return ys.transpose(1, 0, 2, 3).reshape(B, S, d), aux / nc
    y, aux = _moe_tokens(params, x.reshape(B * S, d), cfg, dispatch,
                         budget_bytes)
    return y.reshape(B, S, d), aux
