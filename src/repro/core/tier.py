"""Tiered spill hierarchy: the paper's binary spill cliff as a priced staircase.

PR 4 reproduced the cliff: a degraded grant means partition-and-spill
straight to local disk, and fig11 measures the resulting ~30× P99/P50
phase transition.  REMOP's argument (PAPERS.md) is that operators should
price memory *tiers* rather than one budget, and Szépkúti's results show
compressed layouts beat raw ones at scale.  This module turns the cliff
into that staircase:

  * **T0 — compressed host RAM.**  A capacity-capped in-memory buffer pool
    holding dictionary-encoded + bit-packed columns (:func:`encode_column`).
    Spilling here costs a codec pass, not an fsync.
  * **T1 — emulated remote/slow tier.**  An in-memory store behind a
    deterministic, seeded per-byte latency + bandwidth cap — the model of a
    disaggregated-memory or network-attached spill target.
  * **T2 — local disk.**  The existing crash-consistent
    :class:`~repro.core.spill.SpillManager`, unchanged.

:class:`TierManager` owns the ordered hierarchy behind the same
``write_relation`` / ``read_relation`` / ``open_run_reader`` / ``delete``
interface as ``SpillManager``, so ``linear_engine``'s Grace-join and
external-sort loops route through tiers without rewriting their pass
structure.  Writes land in the highest tier with room (capped by the
operator's :class:`~repro.core.memory_governor.TieredGrant` quota); demand
reads fail over DOWN the hierarchy on injected I/O faults or CRC
corruption (retried per :class:`~repro.core.faults.RetryPolicy`); an async
prefetcher streams spilled build partitions back UP (T2→T0) while the
probe side is still being consumed, overlapping re-read latency with join
compute.

Every tier keeps exact byte accounting (:class:`TierStats`); a
session-lifetime :class:`TierLedger` aggregates per-query managers so the
fig16 gate can assert the books balance — per tier, freed == written,
live == 0, and a drained pool at quiesce.
"""
from __future__ import annotations

import dataclasses
import queue
import random
import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .faults import (FaultInjector, RetryPolicy, SimulatedCrash,
                     SpillCorruptionError, TransientError)
from .metrics import SpillAccount
from .relation import Relation
from .spill import SpillManager, column_crc32

__all__ = [
    "TierConfig", "TierStats", "TierLedger", "TierManager",
    "EncodedColumn", "encode_column", "decode_column",
]

MB = 1 << 20
TIER_NAMES = ("t0", "t1", "t2")


# ---------------------------------------------------------------------------
# Compressed-tier codec: dictionary encoding + bit packing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EncodedColumn:
    """One column, losslessly encoded for the compressed RAM tier.

    ``kind`` is one of:
      * ``"dict"`` — dictionary of unique *bit patterns* (so float NaN
        payloads and negative ints round-trip exactly) + bit-packed codes;
      * ``"pack"`` — frame-of-reference: minimum subtracted in wrapping
        uint64 arithmetic, deltas bit-packed (integer columns whose range
        is narrow but cardinality is high);
      * ``"raw"`` — verbatim copy (incompressible data, non-1-D arrays,
        exotic dtypes).

    ``crc`` is the CRC32 of the ORIGINAL bytes; :func:`decode_column`
    re-verifies it, so a bit flip inside the pool surfaces as a typed
    :class:`~repro.core.faults.SpillCorruptionError`, never silent rows.
    """

    kind: str
    dtype: np.dtype
    n: int
    width: int                      # bits per packed code (dict/pack)
    base: int                       # frame-of-reference minimum (pack)
    payload: Tuple[np.ndarray, ...]
    crc: int

    @property
    def nbytes(self) -> int:
        return sum(int(p.nbytes) for p in self.payload)


def _bitpack(codes: np.ndarray, width: int) -> np.ndarray:
    """Pack nonnegative uint64 codes (< 2**width) into a uint8 bitstream."""
    if width == 0 or len(codes) == 0:
        return np.zeros(0, dtype=np.uint8)
    shifts = np.arange(width, dtype=np.uint64)
    bits = ((codes[:, None] >> shifts) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits, axis=None)


def _bitunpack(packed: np.ndarray, n: int, width: int) -> np.ndarray:
    if width == 0 or n == 0:
        return np.zeros(n, dtype=np.uint64)
    bits = np.unpackbits(packed, count=n * width).reshape(n, width)
    shifts = np.arange(width, dtype=np.uint64)
    return (bits.astype(np.uint64) << shifts).sum(axis=1, dtype=np.uint64)


def _bit_patterns(arr: np.ndarray) -> np.ndarray:
    """The column's raw bit patterns as an unsigned array (exact, total
    order irrelevant — only equality matters for dictionary encoding)."""
    return arr.view(f"u{arr.dtype.itemsize}")


def raw_column(arr: np.ndarray, copy: bool = True) -> EncodedColumn:
    """A verbatim (codec-free) T0 column: at most one copy plus a CRC32.

    This is the pool's fast path — no candidate search — used whenever the
    raw bytes fit the pool's remaining room.  ``copy=False`` adopts the
    caller's array without copying: spill writes hand OWNERSHIP of freshly
    materialized partition arrays to the spill layer (the same contract the
    disk tier has — the caller drops its reference after the write), so the
    pool can keep the buffer itself instead of a memcpy of it.
    """
    arr = np.ascontiguousarray(arr)
    return EncodedColumn("raw", arr.dtype, len(arr), 0, 0,
                         (arr.copy() if copy else arr,), column_crc32(arr))


def encode_column(arr: np.ndarray) -> EncodedColumn:
    """Encode one column for T0; picks the smallest of dict/pack/raw."""
    arr = np.ascontiguousarray(arr)
    crc = column_crc32(arr)
    n = len(arr)
    raw = EncodedColumn("raw", arr.dtype, n, 0, 0, (arr.copy(),), crc)
    if n == 0 or arr.ndim != 1 or arr.dtype.kind not in "iuf":
        return raw
    candidates = [raw]

    u = _bit_patterns(arr)
    # The dict candidate costs an O(n log n) np.unique — real CPU on the
    # spill path.  A strided cardinality probe skips it for columns that
    # are obviously high-cardinality (e.g. float measures), where dict
    # payload (uniques + codes) can never beat raw anyway.
    try_dict = True
    if n > 4096:
        sample = u[:: max(1, n // 1024)]
        try_dict = len(np.unique(sample)) <= len(sample) // 2
    if try_dict:
        uniq, codes = np.unique(u, return_inverse=True)
        width = max(0, int(len(uniq) - 1).bit_length())
        if width < arr.dtype.itemsize * 8:
            packed = _bitpack(codes.astype(np.uint64), width)
            candidates.append(EncodedColumn(
                "dict", arr.dtype, n, width, 0, (uniq, packed), crc))

    if arr.dtype.kind in "iu":
        lo = int(arr.min())
        span = int(arr.max()) - lo
        pwidth = max(0, span.bit_length())
        if pwidth < arr.dtype.itemsize * 8:
            # wrapping subtraction of bit patterns == true delta whenever the
            # span fits 64 bits, which pwidth < 64 guarantees
            with np.errstate(over="ignore"):
                deltas = (u.astype(np.uint64)
                          - np.uint64(lo & 0xFFFFFFFFFFFFFFFF))
            candidates.append(EncodedColumn(
                "pack", arr.dtype, n, pwidth, lo,
                (_bitpack(deltas, pwidth),), crc))

    return min(candidates, key=lambda c: c.nbytes)


def decode_column(enc: EncodedColumn) -> np.ndarray:
    """Exact inverse of :func:`encode_column`; CRC-verified."""
    if enc.kind == "raw":
        out = enc.payload[0]
    elif enc.kind == "dict":
        uniq, packed = enc.payload
        codes = _bitunpack(packed, enc.n, enc.width)
        out = uniq[codes].view(enc.dtype)
    elif enc.kind == "pack":
        deltas = _bitunpack(enc.payload[0], enc.n, enc.width)
        with np.errstate(over="ignore"):
            u = deltas + np.uint64(enc.base & 0xFFFFFFFFFFFFFFFF)
        out = u.astype(f"u{np.dtype(enc.dtype).itemsize}").view(enc.dtype)
    else:  # pragma: no cover - constructor controls kinds
        raise ValueError(f"unknown encoding kind {enc.kind!r}")
    got = column_crc32(out)
    if got != enc.crc:
        raise SpillCorruptionError(
            f"compressed-tier column failed CRC32 (expected {enc.crc:#010x}, "
            f"got {got:#010x}) — pool corruption")
    return out


# ---------------------------------------------------------------------------
# Configuration and accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TierConfig:
    """Capacities and the emulated remote tier's service model.

    ``t1_latency_s``/``t1_gbps`` define T1's deterministic transfer time
    (``latency + bytes/bandwidth``, with a seeded ±10% jitter so repeated
    runs replay the same schedule).  ``t0_byte_s``/``t1_byte_s``/
    ``t2_byte_s`` are the MODELED per-byte service times the pricing stack
    folds into quotes and fragment estimates; ``None`` for T2 means "use
    the cost model's calibrated ``io_byte_cost``".
    """

    t0_capacity: int = 32 * MB
    t1_capacity: Optional[int] = 256 * MB
    t1_latency_s: float = 2e-4
    t1_gbps: float = 1.0
    seed: int = 0
    prefetch: bool = True
    t0_byte_s: float = 1.5e-9
    t2_byte_s: Optional[float] = None

    def t1_byte_s(self, chunk_bytes: int = 256 * 1024) -> float:
        """Modeled seconds per byte through T1 (latency amortized over a
        typical partition-sized transfer)."""
        return 1.0 / (self.t1_gbps * 1e9) + self.t1_latency_s / chunk_bytes

    def byte_costs(self) -> Tuple[float, float, Optional[float]]:
        """(t0, t1, t2) per-byte service times for the pricing stack."""
        return (self.t0_byte_s, self.t1_byte_s(), self.t2_byte_s)


@dataclasses.dataclass
class TierStats:
    """Exact byte books for one tier.  The balance invariant the fig16
    gate asserts: ``bytes_freed == bytes_written`` and ``live_bytes == 0``
    once every partition/run has been consumed — no unaccounted spill."""

    bytes_written: int = 0   # authoritative spill placements (logical bytes)
    bytes_read: int = 0      # demand reads served from this tier
    bytes_freed: int = 0     # returned by delete()
    bytes_promoted: int = 0  # prefetcher promotions INTO this tier (T0 only)
    writes: int = 0
    reads: int = 0
    read_faults: int = 0     # injected/transient read errors survived
    corruptions: int = 0     # CRC failures that triggered failover

    @property
    def live_bytes(self) -> int:
        return max(0, self.bytes_written - self.bytes_freed)

    def as_dict(self) -> Dict[str, int]:
        d = dataclasses.asdict(self)
        d["live_bytes"] = self.live_bytes
        return d

    def merge(self, other: "TierStats") -> None:
        self.bytes_written += other.bytes_written
        self.bytes_read += other.bytes_read
        self.bytes_freed += other.bytes_freed
        self.bytes_promoted += other.bytes_promoted
        self.writes += other.writes
        self.reads += other.reads
        self.read_faults += other.read_faults
        self.corruptions += other.corruptions


class TierLedger:
    """Session-lifetime aggregation of per-query :class:`TierManager` books
    (managers are per-query; the serving report needs totals)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._tiers = {t: TierStats() for t in TIER_NAMES}
        self.pool_leaked_bytes = 0   # T0 pool bytes still resident at cleanup
        self.prefetches = 0          # promotions completed
        self.managers = 0

    def absorb(self, stats: Mapping[str, TierStats], pool_leftover: int,
               prefetches: int) -> None:
        with self._lock:
            for name, s in stats.items():
                self._tiers[name].merge(s)
            self.pool_leaked_bytes += int(pool_leftover)
            self.prefetches += int(prefetches)
            self.managers += 1

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            out: Dict[str, object] = {
                t: self._tiers[t].as_dict() for t in TIER_NAMES}
            out["pool_leaked_bytes"] = self.pool_leaked_bytes
            out["prefetches"] = self.prefetches
            out["managers"] = self.managers
            return out

    def verify_balanced(self) -> None:
        """Raise AssertionError unless every tier's books balance exactly."""
        snap = self.snapshot()
        for t in TIER_NAMES:
            s = snap[t]
            if s["bytes_freed"] != s["bytes_written"] or s["live_bytes"] != 0:
                raise AssertionError(
                    f"tier {t} books do not balance: written="
                    f"{s['bytes_written']} freed={s['bytes_freed']} "
                    f"live={s['live_bytes']}")
        if snap["pool_leaked_bytes"] != 0:
            raise AssertionError(
                f"{snap['pool_leaked_bytes']} T0 pool bytes leaked at quiesce")


# ---------------------------------------------------------------------------
# In-memory run reader (T0/T1 residents)
# ---------------------------------------------------------------------------

class _MemoryRunReader:
    """RunReader-compatible chunked reader over an in-memory relation."""

    def __init__(self, rel: Relation, account: SpillAccount):
        if not rel.columns:
            raise ValueError(
                "spill run contains no column files; cannot determine row "
                "count")
        self.account = account
        self.cols = rel.columns
        self.n = len(next(iter(rel.columns.values())))
        self.pos = 0

    @property
    def exhausted(self) -> bool:
        return self.pos >= self.n

    def read_rows(self, nrows: int) -> Relation:
        end = min(self.n, self.pos + nrows)
        out = {}
        for name, col in self.cols.items():
            chunk = np.asarray(col[self.pos:end])
            out[name] = chunk
            self.account.read(chunk.nbytes)
        self.pos = end
        return Relation(out)


# ---------------------------------------------------------------------------
# TierManager
# ---------------------------------------------------------------------------

class TierManager:
    """Ordered spill-tier hierarchy behind the SpillManager interface.

    Placement: a write lands in the highest tier whose remaining capacity
    (tier capacity ∩ the current operator's grant quota) holds it —
    T0 compressed RAM, then T1 emulated remote, then T2 disk.  Reads prefer
    the highest resident copy and fail over DOWN the hierarchy: a CRC
    failure drops that tier's copy and moves on immediately; a transient
    I/O fault retries per ``retry`` before moving on.  ``prefetch()``
    promotes T1/T2 residents into spare T0 capacity in the background
    (copies, not moves — the authoritative copy stays put, which is what
    makes failover possible).
    """

    def __init__(self, root: Optional[str] = None,
                 config: Optional[TierConfig] = None,
                 faults: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 ledger: Optional[TierLedger] = None):
        self.config = config or TierConfig()
        self.faults = faults
        self.retry = retry or RetryPolicy()
        self.ledger = ledger
        self.disk = SpillManager(root, faults=faults)
        self.dir = self.disk.dir
        self._lock = threading.RLock()
        self._rng = random.Random((self.config.seed, "tier").__hash__()
                                  & 0x7FFFFFFF)
        # base -> {col: EncodedColumn} (T0) / {col: (ndarray, crc)} (T1)
        self._t0: Dict[str, Dict[str, EncodedColumn]] = {}
        self._t1: Dict[str, Dict[str, Tuple[np.ndarray, int]]] = {}
        self._t0_bytes = 0          # encoded pool occupancy
        self._t1_bytes = 0          # logical occupancy
        self._sizes: Dict[str, int] = {}   # logical bytes per live base
        self._home: Dict[str, str] = {}    # authoritative tier per base
        self._stats = {t: TierStats() for t in TIER_NAMES}
        self._quota: Dict[str, Optional[int]] = {"t0": None, "t1": None}
        self._prefetches = 0
        self._closed = False
        # lazy single background promoter; _inflight counts queued+running
        self._pq: "queue.Queue[Optional[str]]" = queue.Queue()
        self._pf_thread: Optional[threading.Thread] = None
        self._inflight = 0
        self._idle = threading.Condition(self._lock)

    # -- lifecycle -----------------------------------------------------------
    def cleanup(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if self._pf_thread is not None:
            self._pq.put(None)
            self._pf_thread.join(timeout=5.0)
        with self._lock:
            leftover = self._t0_bytes + self._t1_bytes
            if self.ledger is not None:
                self.ledger.absorb(self._stats, leftover, self._prefetches)
            self._t0.clear()
            self._t1.clear()
            self._t0_bytes = 0
            self._t1_bytes = 0
        self.disk.cleanup()

    def __enter__(self) -> "TierManager":
        return self

    def __exit__(self, *exc) -> None:
        self.cleanup()

    # -- quota ----------------------------------------------------------------
    def set_op_quota(self, quotas: Optional[Mapping[str, Optional[int]]]) -> None:
        """Apply a :class:`TieredGrant`'s per-tier spill quotas for the
        operator about to run (None → tier capacity alone caps it)."""
        with self._lock:
            if quotas is None:
                self._quota = {"t0": None, "t1": None}
            else:
                self._quota = {"t0": quotas.get("t0"), "t1": quotas.get("t1")}

    def _cap(self, tier: str) -> Optional[int]:
        cap = (self.config.t0_capacity if tier == "t0"
               else self.config.t1_capacity)
        q = self._quota.get(tier)
        if cap is None:
            return q
        return cap if q is None else min(cap, q)

    # -- T1 service model -----------------------------------------------------
    def _t1_transfer(self, nbytes: int) -> None:
        cfg = self.config
        base = cfg.t1_latency_s + nbytes / (cfg.t1_gbps * 1e9)
        with self._lock:
            jitter = 0.9 + 0.2 * self._rng.random()  # seeded, replayable
        if self.faults is not None:
            self.faults.on_remote_read(nbytes)
        time.sleep(base * jitter)

    # -- writes ---------------------------------------------------------------
    # best plausible codec ratio; below 1/this of a write left in the pool,
    # paying the encode just to discover it cannot fit is wasted CPU
    _MAX_RATIO = 16

    def write_relation(self, rel: Relation, tag: str,
                       account: SpillAccount) -> str:
        logical = sum(int(c.nbytes) for c in rel.columns.values())

        # T0: admission is on ENCODED bytes, the pool's real footprint.
        # The codec is real CPU, so it is paid only when it BUYS something:
        # a pool with room for the verbatim bytes takes a raw (memcpy-speed)
        # store — that is what makes T0 faster than page-cached disk — and
        # the dict/pack codec runs only when the raw bytes would not fit
        # but a compressed write still might (it buys admission, the
        # staircase's capacity step, not speed).
        with self._lock:
            cap0 = self._cap("t0")
            room = -1 if cap0 is None else cap0 - self._t0_bytes
        enc: Dict[str, EncodedColumn] = {}
        enc_bytes = logical + 1
        if room < 0 or logical <= room:
            enc = {name: raw_column(col, copy=False)
                   for name, col in rel.columns.items()}
            enc_bytes = sum(e.nbytes for e in enc.values())
        elif logical // self._MAX_RATIO <= room:
            enc = {name: encode_column(col)
                   for name, col in rel.columns.items()}
            enc_bytes = sum(e.nbytes for e in enc.values())
        with self._lock:
            cap0 = self._cap("t0")
            if enc and (cap0 is None
                        or self._t0_bytes + enc_bytes <= cap0):
                base = self.disk._next_path(tag)
                self._t0[base] = enc
                self._t0_bytes += enc_bytes
                self._register(base, "t0", logical, len(rel.columns), account)
                return base
            cap1 = self._cap("t1")
            t1_ok = cap1 is None or self._t1_bytes + logical <= cap1
        if t1_ok and self.config.t1_capacity != 0:
            staged: Dict[str, Tuple[np.ndarray, int]] = {}
            for name, col in rel.columns.items():
                if self.faults is not None:
                    # T1 is an I/O tier: the write-fault site applies
                    self.faults.on_spill_column(f"t1:{tag}/{name}")
                col = np.ascontiguousarray(col)
                staged[name] = (col.copy(), column_crc32(col))
            self._t1_transfer(logical)
            with self._lock:
                base = self.disk._next_path(tag)
                self._t1[base] = staged   # publish complete or not at all
                self._t1_bytes += logical
                self._register(base, "t1", logical, len(rel.columns), account)
            return base

        base = self.disk.write_relation(rel, tag, account)  # accounts itself
        with self._lock:
            self._sizes[base] = logical
            self._home[base] = "t2"
            s = self._stats["t2"]
            s.bytes_written += logical
            s.writes += 1
        return base

    def _register(self, base: str, tier: str, logical: int, ncols: int,
                  account: SpillAccount) -> None:
        """Book a completed T0/T1 placement (lock held)."""
        self._sizes[base] = logical
        self._home[base] = tier
        s = self._stats[tier]
        s.bytes_written += logical
        s.writes += 1
        account.write(logical)
        account.files_created += ncols

    # -- reads ----------------------------------------------------------------
    def _resident_tiers(self, base: str) -> List[str]:
        out = []
        if base in self._t0:
            out.append("t0")
        home = self._home.get(base)
        if home in ("t1", "t2"):
            out.append(home)
        return out

    def _read_tier(self, tier: str, base: str) -> Relation:
        """One read attempt from one tier; raises on fault/corruption."""
        if tier == "t0":
            with self._lock:
                enc = dict(self._t0[base])
            return Relation({name: decode_column(e)
                             for name, e in enc.items()})
        if tier == "t1":
            with self._lock:
                staged = dict(self._t1[base])
                logical = self._sizes.get(base, 0)
            if self.faults is not None:
                self.faults.on_spill_read(f"t1:{base}")
            self._t1_transfer(logical)
            cols = {}
            for name, (col, crc) in staged.items():
                if column_crc32(col) != crc:
                    raise SpillCorruptionError(
                        f"remote-tier column {name!r} at {base!r} failed "
                        f"CRC32 — torn or bit-flipped transfer")
                cols[name] = col
            return Relation(cols)
        # t2: the disk manager injects read faults and verifies CRCs itself
        return self.disk.read_relation(base, SpillAccount())

    def _drop_copy(self, tier: str, base: str,
                   logical: Optional[int] = None) -> None:
        with self._lock:
            if tier == "t0":
                enc = self._t0.pop(base, None)
                if enc is not None:
                    self._t0_bytes -= sum(e.nbytes for e in enc.values())
            elif tier == "t1":
                staged = self._t1.pop(base, None)
                if staged is not None:
                    if logical is None:
                        logical = self._sizes.get(base, 0)
                    self._t1_bytes -= logical

    def _read_with_failover(self, base: str) -> Tuple[Relation, str]:
        """Read ``base`` from the highest resident tier, retrying transient
        faults per policy and failing over down the hierarchy on exhausted
        retries or corruption."""
        with self._lock:
            tiers = self._resident_tiers(base)
        if not tiers:
            raise KeyError(f"no resident spill copy for {base!r}")
        last: Optional[BaseException] = None
        for idx, tier in enumerate(tiers):
            is_last_tier = idx == len(tiers) - 1
            for attempt in range(1, self.retry.max_attempts + 1):
                try:
                    return self._read_tier(tier, base), tier
                except SimulatedCrash:
                    raise
                except SpillCorruptionError as e:
                    # this copy is damaged: retrying the same bytes cannot
                    # help — drop it and fail over immediately
                    last = e
                    with self._lock:
                        self._stats[tier].corruptions += 1
                    if not (is_last_tier and tier == self._home.get(base)):
                        self._drop_copy(tier, base)
                    break
                except TransientError as e:
                    last = e
                    with self._lock:
                        self._stats[tier].read_faults += 1
                    if attempt < self.retry.max_attempts:
                        time.sleep(self.retry.backoff(attempt))
        assert last is not None
        raise last

    def read_relation(self, base: str, account: SpillAccount) -> Relation:
        rel, tier = self._read_with_failover(base)
        logical = sum(int(c.nbytes) for c in rel.columns.values())
        account.read(logical)
        with self._lock:
            s = self._stats[tier]
            s.bytes_read += logical
            s.reads += 1
        return rel

    def open_run_reader(self, base: str, account: SpillAccount):
        with self._lock:
            tiers = self._resident_tiers(base)
        if tiers == ["t2"]:
            return self.disk.open_run_reader(base, account)
        rel, tier = self._read_with_failover(base)
        with self._lock:
            s = self._stats[tier]
            s.bytes_read += sum(int(c.nbytes) for c in rel.columns.values())
            s.reads += 1
        # account counts incrementally as read_rows() consumes, matching
        # the disk RunReader's accounting contract
        return _MemoryRunReader(rel, account)

    # -- deletes --------------------------------------------------------------
    def delete(self, base: str, account: Optional[SpillAccount] = None) -> None:
        with self._lock:
            # unregister FIRST: an in-flight promotion re-checks _sizes
            # before publishing into the pool, so popping here closes the
            # promote-after-delete leak window
            logical = self._sizes.pop(base, None)
            home = self._home.pop(base, None)
            if logical is not None:
                self._drop_copy("t0", base)
                self._drop_copy("t1", base, logical)
                if home in self._stats:
                    self._stats[home].bytes_freed += logical
        if logical is None:
            self.disk.delete(base, account)
            return
        if home == "t2":
            self.disk.delete(base, account)
        elif account is not None:
            account.free(logical)

    # -- prefetch -------------------------------------------------------------
    def prefetch(self, bases: Sequence[str]) -> None:
        """Queue T1/T2 residents for background promotion into spare T0
        capacity (best-effort, ordered; no-op on T0 residents)."""
        if not self.config.prefetch or not bases:
            return
        with self._lock:
            if self._closed:
                return
            if self._pf_thread is None:
                self._pf_thread = threading.Thread(
                    target=self._pf_loop, name="tier-prefetch", daemon=True)
                self._pf_thread.start()
            for b in bases:
                self._inflight += 1
                self._pq.put(b)

    def drain_prefetch(self, timeout_s: float = 10.0) -> None:
        """Block until every queued promotion has been attempted (tests and
        quiesce barriers)."""
        deadline = time.monotonic() + timeout_s
        with self._idle:
            while self._inflight > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return
                self._idle.wait(remaining)

    def _pf_loop(self) -> None:
        while True:
            base = self._pq.get()
            if base is None:
                return
            try:
                self._promote(base)
            except BaseException:
                pass  # best-effort: the authoritative copy is untouched
            finally:
                with self._idle:
                    self._inflight -= 1
                    self._idle.notify_all()

    def _promote(self, base: str) -> None:
        with self._lock:
            if (self._closed or base in self._t0
                    or self._home.get(base) not in ("t1", "t2")):
                return
        # read outside the lock: promotion I/O must overlap foreground work
        rel, _tier = self._read_with_failover(base)
        enc = {name: encode_column(col) for name, col in rel.columns.items()}
        enc_bytes = sum(e.nbytes for e in enc.values())
        with self._lock:
            # re-check: the partition may have been consumed+deleted while
            # we were reading, and the pool may have filled
            cap0 = self._cap("t0")
            if (self._closed or base not in self._sizes or base in self._t0
                    or cap0 is None or self._t0_bytes + enc_bytes > cap0):
                return
            self._t0[base] = enc
            self._t0_bytes += enc_bytes
            self._stats["t0"].bytes_promoted += self._sizes[base]
            self._prefetches += 1

    # -- observability --------------------------------------------------------
    def tier_stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {t: self._stats[t].as_dict() for t in TIER_NAMES}

    @property
    def pool_bytes(self) -> int:
        with self._lock:
            return self._t0_bytes

    @property
    def prefetches(self) -> int:
        with self._lock:
            return self._prefetches
