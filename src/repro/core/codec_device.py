"""Per-column device codecs: dictionary and frame-of-reference packing.

Device-resident columns (PR 2's table cache, PR 7's sharded partition
layouts) were stored at their logical width — int64 almost everywhere —
so both the cold host→device transfer and the warm HBM footprint paid
8 bytes/value regardless of the actual value domain.  This module picks
a *packed* physical layout per column:

  * ``dict``  — dictionary encoding: the column's sorted unique values
    are uploaded once (the dictionary) and the column itself is stored
    as narrow integer *codes* (ranks into the dictionary).  Eligible for
    low-cardinality integer columns (string surrogates, enum-like
    domains).
  * ``for``   — frame-of-reference: ``code = value - min(column)``
    stored at the narrowest signed width that fits the span.  Eligible
    for dense or clustered integer domains (timestamps, sequential ids).
  * ``raw``   — the logical representation, when neither codec wins
    (floats, already-narrow columns, wide sparse domains).

Both codecs are **order-preserving**: ``code_a < code_b`` iff
``value_a < value_b``.  That is what lets the tensor engine sort,
factorize and equi-join directly in the code domain and decode only the
values that survive to the single device→host fetch (the decode-at-fetch
rule; see docs/ARCHITECTURE.md "Compressed device layouts").

The widest code dtype's maximum value is *reserved*: packed code domains
exclude ``iinfo(code_dtype).max`` so the sorted-join cores can keep
using dtype-max as their padding sentinel, exactly as the int64 paths
reserve ``_I64_MAX``.

``REPRO_DEVICE_COMPRESS=0`` disables the codecs globally (every layout
degrades to ``raw``); the toggle is read at call time so tests and
benchmarks can flip it per cell.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import numpy as np
import jax.numpy as jnp

__all__ = [
    "DeviceColumnLayout",
    "DeviceCodes",
    "choose_layout",
    "compress_enabled",
    "decode_device",
    "decode_host",
    "dict_bucket",
    "encode_host",
    "pad_dictionary",
]

#: dictionaries above this cardinality never pay for themselves against
#: frame-of-reference at the same width (and would blow the int16 code
#: domain the Pallas probe kernels tile over)
DICT_MAX_CARD = 1 << 16

#: sample size for the cheap cardinality pre-check before committing to a
#: full ``np.unique`` over the column
_SAMPLE = 4096

_CODE_DTYPES = ("int8", "int16", "int32")


def compress_enabled() -> bool:
    """Packed device layouts on?  Default yes; ``REPRO_DEVICE_COMPRESS=0``
    restores the logical-width uploads."""
    return os.environ.get("REPRO_DEVICE_COMPRESS", "1") != "0"


def _fit_dtype(span: int) -> Optional[str]:
    """Narrowest signed code dtype holding ``[0, span]`` with the dtype
    maximum left free for the join cores' padding sentinel."""
    for name in _CODE_DTYPES:
        if 0 <= span <= np.iinfo(name).max - 1:
            return name
    return None


@dataclass(frozen=True)
class DeviceColumnLayout:
    """Descriptor for one column's physical device representation.

    ``ref``/``card`` are data-dependent and deliberately excluded from
    :meth:`signature` — compiled programs close over the *shape* of the
    codec (encoding + dtypes) and take the reference point / dictionary
    as runtime inputs, so refreshing a table does not recompile.
    """

    encoding: str        # "raw" | "for" | "dict"
    code_dtype: str      # numpy dtype name of the stored codes
    logical_dtype: str   # numpy dtype name of the decoded values
    n: int               # rows described (diagnostics only)
    ref: int = 0         # frame-of-reference base (== column min)
    card: int = 0        # dictionary cardinality (dict only)

    @property
    def code_itemsize(self) -> int:
        return np.dtype(self.code_dtype).itemsize

    @property
    def logical_itemsize(self) -> int:
        return np.dtype(self.logical_dtype).itemsize

    def upload_bytes(self, rows: Optional[int] = None) -> int:
        """Physical H2D bytes to place ``rows`` values (default: all) on
        device under this layout — codes plus, for ``dict``, the
        bucket-padded dictionary itself."""
        rows = self.n if rows is None else rows
        total = rows * self.code_itemsize
        if self.encoding == "dict":
            total += dict_bucket(self.card) * self.logical_itemsize
        return total

    def signature(self) -> Tuple[str, str, str]:
        """Static part of the layout — safe to fold into compiled-program
        cache keys (never changes when the data is refreshed in place)."""
        return (self.encoding, self.code_dtype, self.logical_dtype)


def dict_bucket(card: int) -> int:
    """Power-of-two padding bucket for device dictionaries, so compiled
    programs keep their shapes across dictionary-size drift."""
    return max(16, 1 << max(0, int(card) - 1).bit_length())


def _raw_layout(col: np.ndarray) -> DeviceColumnLayout:
    name = col.dtype.name
    return DeviceColumnLayout("raw", name, name, len(col))


def choose_layout(col: np.ndarray
                  ) -> Tuple[DeviceColumnLayout, Optional[np.ndarray]]:
    """Pick the cheapest physical layout for ``col``.

    Returns ``(layout, dictionary)`` where ``dictionary`` is the sorted
    unique values for ``dict`` layouts and ``None`` otherwise.  Only
    integer columns wider than one byte are candidates; everything else
    (floats, bools, bytes) stays ``raw``.
    """
    if not compress_enabled():
        return _raw_layout(col), None
    if col.dtype.kind not in "iu" or len(col) == 0 or col.dtype.itemsize <= 1:
        return _raw_layout(col), None
    n = len(col)
    kmin, kmax = int(col.min()), int(col.max())
    fdt = _fit_dtype(kmax - kmin)
    best, aux = _raw_layout(col), None
    if fdt is not None and np.dtype(fdt).itemsize < col.dtype.itemsize:
        best = DeviceColumnLayout("for", fdt, col.dtype.name, n, ref=kmin)
    if best.code_itemsize > 1:
        # dictionary can still beat FOR when the domain is wide but sparse
        sample = col if n <= _SAMPLE else col[:: max(1, n // _SAMPLE)]
        if len(np.unique(sample)) <= max(2, len(sample) // 2):
            uniq = np.unique(col)
            card = len(uniq)
            ddt = _fit_dtype(card)  # codes live in [0, card); card = miss slot
            if card <= DICT_MAX_CARD and ddt is not None:
                cand = DeviceColumnLayout("dict", ddt, col.dtype.name, n,
                                          card=card)
                if cand.upload_bytes() < best.upload_bytes():
                    best, aux = cand, uniq
    return best, aux


def encode_host(col: np.ndarray, layout: DeviceColumnLayout,
                dictionary: Optional[np.ndarray] = None) -> np.ndarray:
    """Column values → packed codes (host side, before upload)."""
    if layout.encoding == "raw":
        return col
    if layout.encoding == "for":
        # col - ref stays within [0, span] so the subtraction cannot
        # overflow in the column's own dtype, signed or unsigned
        return (col - col.dtype.type(layout.ref)).astype(layout.code_dtype)
    return np.searchsorted(dictionary, col).astype(layout.code_dtype)


def decode_host(codes: np.ndarray, layout: DeviceColumnLayout,
                dictionary: Optional[np.ndarray] = None) -> np.ndarray:
    """Packed codes → logical values (host side; CRC-free inverse of
    :func:`encode_host`, used by tests and the numpy oracle checks)."""
    if layout.encoding == "raw":
        return codes
    ldt = np.dtype(layout.logical_dtype)
    if layout.encoding == "for":
        return codes.astype(ldt) + ldt.type(layout.ref)
    return dictionary[codes.astype(np.int64)]


def decode_device(codes, encoding: str, logical_dtype: str,
                  ref=None, dict_values=None):
    """Traced device-side decode: packed codes → logical values.

    ``encoding``/``logical_dtype`` are static (baked into the compiled
    program); ``ref`` and ``dict_values`` are runtime inputs so data
    refreshes never recompile.
    """
    if encoding == "raw":
        return codes
    ldt = jnp.dtype(logical_dtype)
    if encoding == "for":
        return codes.astype(ldt) + jnp.asarray(ref, dtype=ldt)
    return jnp.take(dict_values, codes.astype(jnp.int32))


def pad_dictionary(dictionary: np.ndarray, bucket: int) -> np.ndarray:
    """Pad a sorted dictionary to ``bucket`` entries by repeating its last
    value.  ``searchsorted(..., side='left')`` still returns the *first*
    occurrence for a probe equal to the last value (a real code), and any
    probe greater than every dictionary entry still misses — so remapping
    against the padded dictionary is exact while the padded shape keeps
    compiled programs stable across dictionary-size drift."""
    if len(dictionary) >= bucket:
        return dictionary
    pad = np.full(bucket - len(dictionary), dictionary[-1],
                  dtype=dictionary.dtype)
    return np.concatenate([dictionary, pad])


@dataclass(frozen=True)
class DeviceCodes:
    """One device-resident packed column: codes + how to read them.

    ``codes`` may be bucket-padded (padding rows are zeros — never decoded
    thanks to the engines' row-count masks).  ``dict_values`` is the
    device-resident dictionary, padded to a power-of-two bucket via
    :func:`pad_dictionary` (``None`` unless ``layout.encoding == 'dict'``).
    """

    codes: Any
    layout: DeviceColumnLayout
    dict_values: Any = None

    @property
    def encoding(self) -> str:
        return self.layout.encoding

    def decode(self, arr=None):
        """Decode ``arr`` (default: the full code array) to logical
        values on device."""
        target = self.codes if arr is None else arr
        return decode_device(target, self.layout.encoding,
                             self.layout.logical_dtype,
                             ref=self.layout.ref,
                             dict_values=self.dict_values)
