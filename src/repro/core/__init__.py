"""Core of the reproduction: tensor-based execution paths for high-dimensional
relational operations, with execution-time path selection (the paper's
contribution), plus the faithful linear (spilling) baseline it is measured
against."""
from .cost_model import CostConstants, CostModel
from .aggregate import (group_aggregate_device, group_aggregate_linear,
                        group_aggregate_tensor)
from .device_relation import DeviceColumn, DeviceRelation
from .executor import Aggregate, Executor, Filter, GroupBy, Join, QueryResult, Scan, Sort
from .fused import (FusedSpec, match_fragment, pipeline_cache_clear,
                    pipeline_cache_info, run_fused)
from .linear_engine import HashTable, hash_join_linear, sort_linear, table_bytes_estimate
from .metrics import BLOCK_BYTES, LatencyStats, OpMetrics, SpillAccount, latency_stats
from .path_selector import Decision, PathSelector
from .relation import Relation
from .spill import SpillManager
from .tensor_engine import (
    aligned_join_indices,
    capacity_bucket,
    join_capacity,
    tensor_join,
    tensor_join_aggregate,
    tensor_join_device,
    tensor_sort,
    tensor_sort_device,
)

__all__ = [
    "Aggregate", "BLOCK_BYTES", "CostConstants", "CostModel", "Decision",
    "DeviceColumn", "DeviceRelation", "Executor", "Filter", "FusedSpec",
    "GroupBy", "HashTable", "Join", "LatencyStats", "OpMetrics",
    "PathSelector", "QueryResult", "Relation", "Scan", "Sort", "SpillAccount",
    "SpillManager", "aligned_join_indices", "capacity_bucket",
    "hash_join_linear", "join_capacity",
    "group_aggregate_device", "group_aggregate_linear", "group_aggregate_tensor",
    "latency_stats", "match_fragment", "pipeline_cache_clear",
    "pipeline_cache_info", "run_fused", "sort_linear", "table_bytes_estimate",
    "tensor_join", "tensor_join_aggregate", "tensor_join_device",
    "tensor_sort", "tensor_sort_device",
]
