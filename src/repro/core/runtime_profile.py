"""Runtime feedback profile: observed wall times blended into cost predictions.

The cost model's constants are host-dependent: seconds/row on *this* CPU,
seconds/byte through *this* temp filesystem, dispatch overhead of *this* JAX
backend.  Shipped defaults are fit on one development machine and drift
everywhere else — which is exactly how plan choices that are optimal under
stale cost assumptions become brittle under actual run-time conditions
(Graefe's robustness maps; the ROADMAP's N=50k selector regret).

Instead of trusting plan-time constants forever, the :class:`Executor`
records what each ``(op, path, size-bucket)`` actually cost, and the
:class:`PathSelector` pulls its predictions toward those observations with a
confidence-weighted blend.  Two properties matter:

  * the crossover point **self-corrects on any host**: a mispredicted path
    gets observed as slow, its blended estimate rises, and the selector
    switches — without anyone re-running ``calibrate()``;
  * selection never changes operator semantics — both paths produce
    identical result sets; only the timing estimates adapt.

Observations are EWMA-smoothed per cell so a one-off stall (compile, GC,
page cache miss) cannot permanently poison a bucket, and bucketing by input
scale (one bucket per octave) keeps observations from one size regime from
leaking into another.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

__all__ = ["Observation", "RuntimeProfile", "size_bucket", "DEFAULT_PROFILE"]


def size_bucket(rows: int) -> int:
    """Log2 bucket of the input scale: one feedback cell per octave."""
    return max(1, int(rows)).bit_length()


@dataclasses.dataclass
class Observation:
    wall_s: float = 0.0  # EWMA of observed wall seconds
    count: int = 0
    warmups_seen: int = 0  # discarded warmup (likely-compiling) samples


class RuntimeProfile:
    """Observed ``(op, path, size-bucket) → wall_s`` feedback store.

    ``blend(predicted, ...)`` returns the prediction when a cell is cold and
    converges to the observed EWMA as evidence accumulates:
    ``w = count / (count + confidence)``.
    """

    def __init__(self, alpha: float = 0.35, confidence: int = 2):
        self.alpha = float(alpha)
        self.confidence = int(confidence)
        self._cells: Dict[Tuple[str, str, int], Observation] = {}
        self._lock = threading.Lock()

    def record(self, op: str, path: str, rows: int, wall_s: float,
               warmup_discard: bool = False) -> None:
        """Record one observation.  ``warmup_discard=True`` drops the FIRST
        sample a cold cell ever sees: callers pass it when the sample may
        include one-time jit compilation they cannot detect precisely (the
        per-operator tensor path), so a multi-second compile never enters
        the blend as a steady-state cost."""
        key = (op, path, size_bucket(rows))
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                cell = self._cells[key] = Observation()
            if warmup_discard and cell.count == 0 and cell.warmups_seen == 0:
                cell.warmups_seen += 1
                return
            cell.count += 1
            if cell.count == 1:
                cell.wall_s = float(wall_s)
            else:
                cell.wall_s += self.alpha * (float(wall_s) - cell.wall_s)

    def observed(self, op: str, path: str, rows: int) -> Optional[Observation]:
        """Snapshot (copy) of a cell — safe to read while concurrent
        executors record into the live cell."""
        with self._lock:
            cell = self._cells.get((op, path, size_bucket(rows)))
            return None if cell is None else dataclasses.replace(cell)

    def blend(self, predicted: float, op: str, path: str, rows: int) -> float:
        cell = self.observed(op, path, rows)
        if cell is None or cell.count == 0:
            return float(predicted)
        w = cell.count / (cell.count + self.confidence)
        return (1.0 - w) * float(predicted) + w * cell.wall_s

    def clear(self) -> None:
        with self._lock:
            self._cells.clear()

    def snapshot(self) -> Dict[Tuple[str, str, int], Observation]:
        """Copy of the cells (diagnostics / benchmark reporting)."""
        with self._lock:
            return {k: dataclasses.replace(v) for k, v in self._cells.items()}

    def __len__(self) -> int:
        return len(self._cells)


# Opt-in process-wide profile.  PathSelector defaults to a *fresh* profile
# per selector (deterministic tests, no cross-query-stream pollution); pass
# this explicitly to share observations across executors in one process.
DEFAULT_PROFILE = RuntimeProfile()
