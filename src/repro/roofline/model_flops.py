"""Analytic MODEL_FLOPS per (arch × shape): the "useful" compute.

Convention (recorded in EXPERIMENTS.md):
  * parameter-matmul term: 2·N_active per token (forward), ×3 for training
    (fwd+bwd), embedding lookups excluded;
  * attention term: 2 matmuls (QK^T, PV) = 4·S_kv·H·Dh per query token per
    attention layer, halved for causal masking in full-sequence passes;
  * SSD term: intra-chunk matmuls ≈ attention over chunk length + state
    updates (small; included via the chunked formula).

The ratio MODEL_FLOPS / HLO_FLOPs then exposes remat recompute, dispatch
overheads and padding waste in the compiled program.
"""
from __future__ import annotations

from ..configs.base import ArchConfig
from ..configs.shapes import ShapeSpec

__all__ = ["model_flops"]


def _attn_layer_flops(cfg: ArchConfig, s_q: int, s_kv: int, causal_half: bool):
    if cfg.attn_type == "mla":
        dh = cfg.qk_nope_dim + cfg.qk_rope_dim
        dv = cfg.v_head_dim
    else:
        dh = dv = cfg.head_dim
    f = 2.0 * s_q * s_kv * cfg.num_heads * (dh + dv)
    return f * (0.5 if causal_half else 1.0)


def _layer_counts(cfg: ArchConfig):
    specs = list(cfg.prefix) + list(cfg.pattern) * cfg.num_periods
    n_attn_g = sum(1 for m, _ in specs if m == "attn:global")
    n_attn_l = sum(1 for m, _ in specs if m == "attn:local")
    n_mamba = sum(1 for m, _ in specs if m == "mamba")
    return n_attn_g, n_attn_l, n_mamba


def _ssd_layer_flops(cfg: ArchConfig, s: int, chunk: int = 128):
    d_inner = cfg.ssm_expand * cfg.d_model
    h = d_inner // cfg.ssm_headdim
    n = cfg.ssm_state
    c = min(chunk, s)
    # intra: G matmul (c×c×n per head-group) + y_intra (c×c×p); inter: state ops
    per_chunk = 2 * c * c * cfg.ssm_groups * n + 2 * c * c * h * cfg.ssm_headdim \
        + 2 * c * h * cfg.ssm_headdim * n * 2
    return (s // c) * per_chunk if c else 0.0


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    n_g, n_l, n_m = _layer_counts(cfg)

    if shape.kind == "train":
        tokens = B * S
        param_f = 6.0 * n_active * tokens
        attn_f = 3.0 * B * (
            n_g * _attn_layer_flops(cfg, S, S, causal_half=cfg.causal)
            + n_l * _attn_layer_flops(cfg, S, min(S, cfg.sliding_window or S),
                                      causal_half=False)
            + n_m * _ssd_layer_flops(cfg, S))
        return param_f + attn_f
    if shape.kind == "prefill":
        tokens = B * S
        param_f = 2.0 * n_active * tokens
        attn_f = B * (
            n_g * _attn_layer_flops(cfg, S, S, causal_half=cfg.causal)
            + n_l * _attn_layer_flops(cfg, S, min(S, cfg.sliding_window or S),
                                      causal_half=False)
            + n_m * _ssd_layer_flops(cfg, S))
        return param_f + attn_f
    # decode: one token against seq_len of context
    param_f = 2.0 * n_active * B
    attn_f = B * (
        n_g * _attn_layer_flops(cfg, 1, S, causal_half=False)
        + n_l * _attn_layer_flops(cfg, 1, min(S, cfg.sliding_window or S),
                                  causal_half=False)
        + n_m * _ssd_layer_flops(cfg, 1, chunk=1))
    return param_f + attn_f
