"""Execution-time path selection (§III.C) and regime-shift model (§VI)."""
import numpy as np
import pytest

from repro.core import (
    Aggregate,
    CostModel,
    Executor,
    Join,
    PathSelector,
    Relation,
    RuntimeProfile,
    Scan,
    Sort,
    match_fragment,
    table_bytes_estimate,
)


def _tables(n=40_000, seed=0):
    rng = np.random.default_rng(seed)
    build = Relation({"k": rng.permutation(n).astype(np.int64),
                      "v": rng.integers(0, 99, n).astype(np.int64)})
    probe = Relation({"k": rng.integers(0, n, n).astype(np.int64),
                      "w": rng.integers(0, 99, n).astype(np.int64)})
    return build, probe


def test_selector_prefers_linear_when_fits():
    build, probe = _tables(1000)
    sel = PathSelector(work_mem=1 << 30)
    d = sel.choose_join(build, probe, "k")
    assert d.path == "linear"
    assert "fits" in d.reason


def test_selector_predicts_spill_under_pressure():
    build, probe = _tables(200_000)
    sel = PathSelector(work_mem=1 << 20)
    d = sel.choose_join(build, probe, "k")
    assert d.predicted_spill_bytes > 0
    assert d.t_linear > 0 and d.t_tensor > 0


def test_selector_forced_paths():
    build, probe = _tables(1000)
    for force in ("linear", "tensor"):
        sel = PathSelector(work_mem=1 << 20, force=force)
        assert sel.choose_join(build, probe, "k").path == force
        assert sel.choose_sort(build, ["k"]).path == force


def test_executor_policies_agree_semantically():
    build, probe = _tables(20_000)
    plan = lambda: Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"])
    results = {}
    for policy in ("linear", "tensor", "auto"):
        ex = Executor(work_mem=128 * 1024, policy=policy)
        results[policy] = ex.execute(plan()).relation.sort_canonical()
    assert results["linear"].equals(results["tensor"])
    assert results["linear"].equals(results["auto"])


def test_auto_picks_fused_path_at_50k_regret_case():
    """PR 2 regression for the ROADMAP open item: at N=50k / work_mem=1MB the
    fused device-resident path beats the spilling linear path, but the seed's
    per-operator costing still picked linear.  A COLD (no feedback) selector
    with the retuned plan-level model must choose tensor, and the auto
    executor must actually dispatch the fused program."""
    build, probe = _tables(50_000)
    plan = Sort(Join(Scan(build), Scan(probe), "k"), ["k", "w"])
    spec, b, p = match_fragment(plan)
    sel = PathSelector(work_mem=1 << 20, profile=RuntimeProfile())
    d = sel.choose_fragment(spec, b, p)
    assert d.path == "tensor", d.reason
    assert d.t_tensor < d.t_linear
    ex = Executor(work_mem=1 << 20, policy="auto",
                  selector=PathSelector(1 << 20, profile=RuntimeProfile()))
    q = ex.execute(plan)
    assert any(m.op == "fused_pipeline" for m in q.metrics), \
        [m.op for m in q.metrics]


def test_auto_still_picks_linear_at_small_n():
    """The crossover's other side: small inputs that comfortably fit
    work_mem stay on the linear path (paper §V.B)."""
    build, probe = _tables(1000, seed=2)
    plan = Sort(Join(Scan(build), Scan(probe), "k"), ["k"])
    spec, b, p = match_fragment(plan)
    sel = PathSelector(work_mem=1 << 30, profile=RuntimeProfile())
    assert sel.choose_fragment(spec, b, p).path == "linear"
    ex = Executor(work_mem=1 << 30, policy="auto",
                  selector=PathSelector(1 << 30, profile=RuntimeProfile()))
    q = ex.execute(plan)
    assert all(m.path == "linear" for m in q.metrics), \
        [(m.op, m.path) for m in q.metrics]


def test_fragment_costing_amortizes_fixed_cost_and_charges_h2d():
    """Plan-level costing (PR 2): ONE fused dispatch for the fragment must
    be cheaper than per-operator tensor dispatches summed, and pending H2D
    bytes must appear as an explicit, monotonic term."""
    model = CostModel()
    n = 50_000
    frag = model.estimate_fragment(n, n, 16, 16, n, 1 << 20,
                                   num_sort_keys=2, has_agg=True)
    ej = model.estimate_join(n, n, 16, 16, n, 1 << 20)
    es = model.estimate_sort(n, 32, 2, 1 << 20)
    assert frag.t_tensor < ej.t_tensor + es.t_tensor
    cold = model.estimate_fragment(n, n, 16, 16, n, 1 << 20,
                                   num_sort_keys=2, has_agg=True,
                                   h2d_bytes=1 << 30)
    assert cold.t_tensor > frag.t_tensor
    assert cold.t_tensor - frag.t_tensor == \
        pytest.approx(model.c.h2d_byte_cost * (1 << 30))
    # the linear side of the fragment includes the downstream sort's spill
    join_only = model.estimate_join(n, n, 16, 16, n, 1 << 20)
    assert frag.t_linear > join_only.t_linear


def test_calibrate_fits_fused_and_transfer_constants():
    model = CostModel()
    c = model.calibrate(n=30_000)
    assert c.fused_row_cost > 0
    assert c.fused_fixed_cost > 0
    assert c.host_sync_cost > 0
    assert c.h2d_byte_cost > 0
    assert c.linear_row_cost > 0
    # the fitted model must still resolve the documented regret case
    build, probe = _tables(50_000, seed=3)
    spec, b, p = match_fragment(
        Aggregate(Sort(Join(Scan(build), Scan(probe), "k"), ["k"]),
                  "b_v", "sum"))
    sel = PathSelector(work_mem=1 << 20, cost_model=model,
                       profile=RuntimeProfile())
    assert sel.choose_fragment(spec, b, p).path == "tensor"


def test_regime_model_alpha_superlinear_in_deficit():
    """α(N, M) grows superlinearly as memory pressure increases (§VI)."""
    model = CostModel()
    n = 1_000_000
    spills = []
    for mem in (1 << 26, 1 << 23, 1 << 20):  # 64MB, 8MB, 1MB
        s, _ = model.join_spill_bytes(n, n, 16, 16, mem)
        spills.append(s)
    assert spills[0] <= spills[1] <= spills[2]
    assert spills[2] > 0
    # sort spill passes grow as memory shrinks
    p_small = model.sort_spill_bytes(n, 24, 1 << 20)[1]
    p_large = model.sort_spill_bytes(n, 24, 1 << 26)[1]
    assert p_small >= p_large


def test_table_bytes_monotonic():
    assert table_bytes_estimate(10) <= table_bytes_estimate(1000)
    assert table_bytes_estimate(1000) <= table_bytes_estimate(10**6)
