"""Serving engine: scheduler ordering, generation, prefill/decode agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import decode_step, init_cache, init_model, prefill
from repro.serving.engine import BatchScheduler, Request, generate


def test_scheduler_priority_then_arrival():
    sched = BatchScheduler(batch_size=2)
    for rid, pri, t in ((0, 0, 1.0), (1, 2, 3.0), (2, 2, 2.0), (3, 1, 0.5)):
        r = Request(rid=rid, prompt=np.zeros(4, np.int64), max_new_tokens=1,
                    priority=pri)
        r.arrived_s = t
        sched.submit(r)
    first = sched.admit(2)
    # highest priority first; among equal priorities, earliest arrival
    assert [r.rid for r in first] == [2, 1]
    second = sched.admit(2)
    assert [r.rid for r in second] == [3, 0]
    assert not sched.queue


def test_generate_greedy_matches_stepwise():
    cfg = get_smoke_config("yi-9b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 6))
    out = generate(params, cfg, prompts, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    # determinism
    out2 = generate(params, cfg, prompts, max_new_tokens=4)
    np.testing.assert_array_equal(out, out2)


def test_prefill_then_decode_matches_pure_decode():
    """prefill(prompt) + decode continuation == stepwise decode throughout."""
    cfg = get_smoke_config("qwen2-vl-7b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))

    last_logits, cache = prefill(params, cfg, {"tokens": toks, "positions": pos})
    # pure stepwise decode for comparison
    c2 = init_cache(cfg, B, S)
    for t in range(S):
        lg, c2 = decode_step(params, cfg, c2,
                             {"tokens": toks[:, t:t + 1],
                              "positions": pos[:, :, t:t + 1]})
    np.testing.assert_allclose(np.asarray(last_logits), np.asarray(lg),
                               rtol=2e-4, atol=2e-4)
